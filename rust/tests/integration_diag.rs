//! Diagnostics integration: the flight recorder, critical-path
//! attribution, and per-class SLO engine acceptance surface.
//!
//! * quarantine — inducing a breaker open via
//!   [`RolloutService::quarantine_replica`] writes exactly one
//!   rate-limited flight dump whose span tail, gauge history, and queue
//!   sections reconstruct the failure window;
//! * critical path — a mock multi-turn episode's attributed segments
//!   partition its wall time exactly, and a cache-hit turn lands in
//!   `resume`, not `prefill`;
//! * SLO — the burn rate goes positive only for the class whose latency
//!   target is actually violated;
//! * disabled — without the diagnostics plane the run is byte-identical
//!   and no dump files are written.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::buffer::Experience;
use trinity_rft::explorer::{
    AlfworldWorkflow, MockModel, RolloutEndpoint, RolloutModel, SamplingArgs, Task, Workflow,
    WorkflowCtx,
};
use trinity_rft::obs::{
    attribute, class_summary, FlightConfig, FlightRecorder, Gauges, SloConfig, SloEngine, Span,
    SpanKind, SpanRecorder, TelemetryHub,
};
use trinity_rft::qos::RequestClass;
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::{Tokenizer, EOS};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

/// A mock whose response is a pure function of the prompt, so two
/// identical call sequences produce byte-identical outputs.
fn deterministic_mock(seed: u64) -> MockModel {
    let tok = Tokenizer::new();
    let look = tok.encode("look");
    MockModel::new(seed, Duration::ZERO, 0.0).with_response(move |_prompt, _rng| {
        let mut r = look.clone();
        r.push(EOS);
        r
    })
}

fn alfworld_task(seed: i64, repeat: usize) -> Task {
    let mut t = Task::new("diag-ep", "alfworld", Value::obj(vec![("seed", Value::int(seed))]));
    t.repeat_times = repeat;
    t
}

/// Run the multi-turn workflow against a service handle, single-file,
/// so the request order is deterministic.
fn run_episodes(svc: &Arc<RolloutService>, seed: i64, repeat: usize) -> Vec<Experience> {
    let tok = Tokenizer::new();
    let task = alfworld_task(seed, repeat);
    let sampling = SamplingArgs { max_new_tokens: 8, ..Default::default() };
    let model: &dyn RolloutModel = svc.as_ref();
    let mut ctx = WorkflowCtx { model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(7) };
    let wf =
        AlfworldWorkflow { max_env_steps: 3, env_init_cost: Duration::ZERO, max_seq_tokens: 200 };
    wf.run(&mut ctx).unwrap()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("trft_diag_{tag}_{}", std::process::id()))
}

#[test]
fn induced_quarantine_dumps_one_bundle_reconstructing_the_window() {
    let dir = temp_dir("quarantine");
    let _ = std::fs::remove_dir_all(&dir);
    let recorder = Arc::new(SpanRecorder::new(1 << 12));
    let hub = Arc::new(TelemetryHub::with_history(Duration::from_millis(1), 16));
    let flight = Arc::new(FlightRecorder::new(FlightConfig {
        dir: Some(dir.clone()),
        min_interval: Duration::from_secs(3600),
        ..Default::default()
    }));
    flight.connect_spans(Arc::clone(&recorder));
    flight.connect_hub(Arc::clone(&hub));
    flight.set_config_digest("cafe0123cafe0123");

    let mut cfg = ServiceConfig::default();
    cfg.cache.enabled = true;
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> = vec![Arc::new(deterministic_mock(3))];
    let svc = Arc::new(
        RolloutService::over_models_diag(
            endpoints,
            cfg,
            Some(Arc::clone(&recorder)),
            Some(Arc::clone(&flight)),
        )
        .unwrap(),
    );

    // traffic before the failure: the span ring and gauge history now
    // hold the window the dump must reconstruct
    let exps = run_episodes(&svc, 5, 2);
    assert!(!exps.is_empty());
    hub.publish(Gauges { queued: 1.0, ..Default::default() });
    hub.publish(Gauges { queued: 4.0, ..Default::default() });

    // two induced quarantines: the first dumps, the second is inside
    // min_interval and is suppressed (counted, not written)
    assert!(svc.quarantine_replica(0, Duration::from_secs(60)));
    assert!(svc.quarantine_replica(0, Duration::from_secs(60)));
    assert_eq!(flight.triggers(), 2);
    assert_eq!(flight.dumps(), 1, "rate limit allows exactly one dump");
    assert_eq!(flight.suppressed(), 1);
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1);

    let doc =
        Value::parse(&std::fs::read_to_string(dir.join("flight-0.json")).unwrap()).unwrap();
    assert_eq!(doc.get("anomaly").and_then(Value::as_str), Some("breaker_open"));
    assert_eq!(doc.get("config_digest").and_then(Value::as_str), Some("cafe0123cafe0123"));
    let detail = doc.get("detail").and_then(Value::as_str).unwrap();
    assert!(detail.contains("replica 0"), "{detail}");
    // the gauge history reconstructs the pre-failure trend
    let history = doc.get("gauge_history").and_then(Value::as_array).unwrap();
    assert_eq!(history.len(), 2, "both published samples embedded");
    assert_eq!(history[0].get("queued").and_then(Value::as_f64), Some(1.0));
    assert_eq!(history[1].get("queued").and_then(Value::as_f64), Some(4.0));
    // the span tail reconstructs the episodes' serve pipeline
    let events = doc.get("traceEvents").and_then(Value::as_array).unwrap();
    for name in ["queue_wait", "decode"] {
        assert!(
            events.iter().any(|e| e.get("name").and_then(Value::as_str) == Some(name)),
            "missing {name} span in dump"
        );
    }
    // the service contributed its per-class queue section
    assert!(doc.path("sections.queues.replicas").is_some(), "{doc:?}");
    assert!(doc.path("sections.queues.classes.train.completed").is_some(), "{doc:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Regression: the worker's failure path fires the breaker-open trigger
/// while the flight recorder's queue source reads replica health via the
/// same breaker mutex — triggering under the guard self-deadlocked the
/// worker.  This drives a real quarantine through `WorkerCtl::fail`
/// (not `quarantine_replica`, whose guard is a released temporary) and
/// proves the dump lands with the re-locking section intact.
#[test]
fn worker_path_quarantine_dumps_without_deadlocking() {
    let dir = temp_dir("worker_quarantine");
    let _ = std::fs::remove_dir_all(&dir);
    let recorder = Arc::new(SpanRecorder::new(1 << 10));
    let flight = Arc::new(FlightRecorder::new(FlightConfig {
        dir: Some(dir.clone()),
        ..Default::default()
    }));
    flight.connect_spans(Arc::clone(&recorder));

    let mut cfg = ServiceConfig::default();
    cfg.breaker_failures = 2;
    cfg.max_attempts = 2;
    cfg.retry_backoff = Duration::from_millis(1);
    cfg.quarantine = Duration::from_millis(20);
    // failure rate 1.0: every row fails, the second failure opens the
    // breaker from inside the serve loop
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        vec![Arc::new(MockModel::new(3, Duration::ZERO, 1.0))];
    let svc = Arc::new(
        RolloutService::over_models_diag(
            endpoints,
            cfg,
            Some(Arc::clone(&recorder)),
            Some(Arc::clone(&flight)),
        )
        .unwrap(),
    );
    let tok = Tokenizer::new();
    let args = SamplingArgs { max_new_tokens: 4, ..Default::default() };
    let model: &dyn RolloutModel = svc.as_ref();
    // would hang here if the trigger fired under the breaker guard
    model.chat(&tok.encode("go"), 1, &args).unwrap_err();

    assert_eq!(flight.dumps(), 1, "worker-path quarantine must dump");
    let doc =
        Value::parse(&std::fs::read_to_string(dir.join("flight-0.json")).unwrap()).unwrap();
    assert_eq!(doc.get("anomaly").and_then(Value::as_str), Some("breaker_open"));
    // the queue section re-locks the breaker to report health: its
    // presence (with the replica reported not-ready) is the proof the
    // trigger ran outside the guard
    let replicas = doc.path("sections.queues.replicas").and_then(Value::as_array).unwrap();
    assert_eq!(replicas[0].get("ready").and_then(Value::as_bool), Some(false), "{doc:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn critical_path_partitions_episode_wall_and_credits_cache_hits_to_resume() {
    // real multi-turn service episodes: the attributed segments must
    // partition each episode's wall time exactly
    let recorder = Arc::new(SpanRecorder::new(1 << 12));
    let mut cfg = ServiceConfig::default();
    cfg.cache.enabled = true;
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> = vec![Arc::new(deterministic_mock(7))];
    let svc = Arc::new(
        RolloutService::over_models_obs(endpoints, cfg, Some(Arc::clone(&recorder))).unwrap(),
    );
    run_episodes(&svc, 11, 2);
    let spans = recorder.drain();
    assert!(spans.iter().any(|s| s.kind == SpanKind::Resume), "cache-hit turns must resume");
    let breakdowns = attribute(&spans);
    assert_eq!(breakdowns.len(), 2, "one breakdown per episode");
    for b in &breakdowns {
        let total: u64 = b.segments().iter().map(|&(_, us)| us).sum();
        assert_eq!(total, b.wall_us, "segments must partition the wall exactly: {b:?}");
    }
    let per_class = class_summary(&breakdowns);
    assert_eq!(per_class.len(), 1);
    assert_eq!(per_class[0].0, RequestClass::TrainRollout);
    assert_eq!(per_class[0].1, 2);

    // a hand-built mock multi-turn episode pins the attribution rules:
    // turn 1 cold-prefills, turn 2 hits the cache — its serve time must
    // land in `resume`, not `prefill`
    let span = |kind, start_us, dur_us, detail| Span {
        trace: 9,
        kind,
        replica: 0,
        start_us,
        dur_us,
        detail,
    };
    let episode = vec![
        span(SpanKind::QueueWait, 0, 100, 1),
        span(SpanKind::Prefill, 100, 300, 64),
        span(SpanKind::Decode, 100, 500, 8),
        span(SpanKind::QueueWait, 800, 50, 1),
        span(SpanKind::Resume, 850, 40, 48),
        span(SpanKind::Decode, 850, 150, 8),
    ];
    let b = &attribute(&episode)[0];
    assert_eq!(b.wall_us, 1000);
    assert_eq!(b.queue_us, 150);
    assert_eq!(b.prefill_us, 300, "turn 1 is the cold prefill");
    assert_eq!(b.resume_us, 40, "the cache-hit turn is resume, not prefill");
    assert_eq!(b.decode_us, 310, "decode keeps only its remainder");
    assert_eq!(b.other_us, 200, "the inter-turn gap is residual");
    let total: u64 = b.segments().iter().map(|&(_, us)| us).sum();
    assert_eq!(total, b.wall_us);
}

#[test]
fn slo_burn_goes_positive_only_for_the_violated_class() {
    // interactive target 1µs: any measurable queue wait violates it;
    // train target 10s: sequential mock traffic never comes close;
    // eval: untracked (no target), burn must stay 0
    let engine = SloEngine::new(SloConfig {
        targets: [Duration::from_secs(10), Duration::ZERO, Duration::from_micros(1)],
        objective: 0.9,
    });
    let mut cfg = ServiceConfig::default();
    cfg.max_batch = 1;
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        vec![Arc::new(MockModel::new(5, Duration::from_millis(2), 0.0))];
    let svc = Arc::new(RolloutService::over_models(endpoints, cfg).unwrap());
    let tok = Tokenizer::new();
    let prompt = tok.encode("go");
    let call = |class: RequestClass| {
        let args = SamplingArgs { max_new_tokens: 4, class, ..Default::default() };
        let model: &dyn RolloutModel = svc.as_ref();
        model.chat(&prompt, 1, &args).unwrap();
    };
    for _ in 0..3 {
        call(RequestClass::TrainRollout);
    }
    // concurrent interactive burst against one 2ms replica: the later
    // requests queue for milliseconds, far over the 1µs target
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| call(RequestClass::Interactive));
        }
    });
    let snap = svc.snapshot();
    let burn = engine.assess(&snap.class_queue_wait);
    assert!(burn[RequestClass::Interactive.index()] > 0.0, "violated class must burn: {burn:?}");
    assert_eq!(burn[RequestClass::TrainRollout.index()], 0.0, "{burn:?}");
    assert_eq!(burn[RequestClass::Eval.index()], 0.0, "untracked class: {burn:?}");
    assert_eq!(engine.burns(), burn);
}

#[test]
fn disabled_diagnostics_are_byte_identical_and_write_nothing() {
    let dir = temp_dir("disabled");
    let _ = std::fs::remove_dir_all(&dir);
    let recorder = Arc::new(SpanRecorder::new(1 << 12));
    let flight = Arc::new(FlightRecorder::new(FlightConfig {
        dir: Some(dir.clone()),
        ..Default::default()
    }));
    flight.connect_spans(Arc::clone(&recorder));

    let service = |obs: Option<Arc<SpanRecorder>>, f: Option<Arc<FlightRecorder>>| {
        let mut cfg = ServiceConfig::default();
        cfg.cache.enabled = true;
        let endpoints: Vec<Arc<dyn RolloutEndpoint>> = vec![Arc::new(deterministic_mock(11))];
        Arc::new(RolloutService::over_models_diag(endpoints, cfg, obs, f).unwrap())
    };
    let diag = service(Some(Arc::clone(&recorder)), Some(Arc::clone(&flight)));
    let plain = service(None, None);
    assert!(plain.observer().is_none());
    assert!(plain.flight().is_none());

    let exps_diag = run_episodes(&diag, 9, 2);
    let exps_plain = run_episodes(&plain, 9, 2);
    assert_eq!(exps_diag.len(), exps_plain.len());
    for (x, y) in exps_diag.iter().zip(&exps_plain) {
        assert_eq!(x.tokens, y.tokens, "token streams diverged");
        assert_eq!(x.logprobs, y.logprobs, "logprobs diverged");
        assert_eq!(x.loss_mask, y.loss_mask, "loss masks diverged");
        assert_eq!(x.prompt_len, y.prompt_len);
        assert_eq!(x.reward, y.reward);
    }

    // the healthy diag run fired no anomaly; the dump dir was never
    // even created (dumping is the only thing that touches disk)
    assert_eq!(flight.triggers(), 0);
    assert!(!dir.exists(), "no dump files on a healthy run");
}
