//! QoS serving plane integration: the ISSUE-9 acceptance surface.
//!
//! * fairness — under a 10:1 train:interactive backlog the DRR
//!   scheduler keeps interactive queue waits below train waits, while
//!   the FIFO control run (qos off) starves the late-arriving
//!   interactive traffic,
//! * deadlines — a tight per-class interactive deadline expires only
//!   interactive rows; train rows with the fleet default complete,
//! * migration — mock-path replicas decline session extraction
//!   gracefully (cold serve, zero failures), and — artifact-gated — a
//!   real engine pool migrates a parked KV session off a quarantined
//!   holder with byte-identical output and ≥50% of the turn's prefill
//!   tokens saved.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::explorer::{MockModel, RolloutEndpoint, RolloutModel, SamplingArgs};
use trinity_rft::model::ParamStore;
use trinity_rft::qos::RequestClass;
use trinity_rft::runtime::{Manifest, ModelEngine, RuntimeClient};
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::Tokenizer;

fn service_with(cfg: ServiceConfig, models: Vec<Arc<MockModel>>) -> Arc<RolloutService> {
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        models.into_iter().map(|m| m as Arc<dyn RolloutEndpoint>).collect();
    Arc::new(RolloutService::over_models(endpoints, cfg).unwrap())
}

/// One replica, one row per session, fixed per-request latency: a
/// serial server whose dequeue order is exactly the scheduler's.
fn serial_service(qos_enabled: bool, latency: Duration) -> Arc<RolloutService> {
    let mut cfg = ServiceConfig::default();
    cfg.max_batch = 1;
    cfg.qos.enabled = qos_enabled;
    service_with(cfg, vec![Arc::new(MockModel::new(7, latency, 0.0))])
}

/// Spawn `n` concurrent single-row chats of one class; returns the
/// join handles (each chat blocks until its row completes).
fn spawn_chats(
    svc: &Arc<RolloutService>,
    n: usize,
    class: RequestClass,
) -> Vec<std::thread::JoinHandle<anyhow::Result<()>>> {
    (0..n)
        .map(|i| {
            let svc = Arc::clone(svc);
            std::thread::spawn(move || {
                let args = SamplingArgs {
                    max_new_tokens: 2,
                    seed: i as u64,
                    class,
                    ..Default::default()
                };
                svc.chat(&[1, 40 + i as i32], 1, &args)?;
                Ok(())
            })
        })
        .collect()
}

/// 10:1 train:interactive backlog on a serial replica.  Returns
/// (mean train wait, mean interactive wait) in seconds.
fn class_waits(qos_enabled: bool) -> (f64, f64) {
    let svc = serial_service(qos_enabled, Duration::from_millis(2));
    let train = spawn_chats(&svc, 30, RequestClass::TrainRollout);
    // let the train backlog build before interactive traffic arrives
    std::thread::sleep(Duration::from_millis(8));
    let interactive = spawn_chats(&svc, 3, RequestClass::Interactive);
    for h in train.into_iter().chain(interactive) {
        h.join().unwrap().unwrap();
    }
    let s = svc.snapshot();
    assert_eq!(s.class_completed[RequestClass::TrainRollout.index()], 30);
    assert_eq!(s.class_completed[RequestClass::Interactive.index()], 3);
    assert_eq!(s.failed + s.expired, 0, "{s:?}");
    (
        s.class_queue_wait[RequestClass::TrainRollout.index()].mean(),
        s.class_queue_wait[RequestClass::Interactive.index()].mean(),
    )
}

#[test]
fn drr_keeps_interactive_waits_below_train_under_backlog() {
    let (train, interactive) = class_waits(true);
    assert!(
        interactive < train,
        "DRR must serve the interactive class ahead of the train backlog: \
         interactive mean wait {interactive:.4}s vs train {train:.4}s"
    );
}

#[test]
fn fifo_control_run_starves_late_interactive_traffic() {
    let (train, interactive) = class_waits(false);
    assert!(
        interactive > train,
        "FIFO drains in arrival order, so the late interactive rows must \
         wait out the whole train backlog: interactive mean wait \
         {interactive:.4}s vs train {train:.4}s"
    );
}

#[test]
fn per_class_deadline_expires_only_its_class() {
    let mut cfg = ServiceConfig::default();
    cfg.max_batch = 1;
    cfg.qos.enabled = true;
    cfg.qos.deadlines[RequestClass::Interactive.index()] = Duration::from_millis(15);
    let svc = service_with(cfg, vec![Arc::new(MockModel::new(8, Duration::from_millis(60), 0.0))]);

    // row 1 occupies the serial replica for 60ms; the tight-deadline
    // interactive row queued behind it must expire at pop time, while
    // the train row with the fleet-default deadline completes
    let first = spawn_chats(&svc, 1, RequestClass::TrainRollout);
    std::thread::sleep(Duration::from_millis(5));
    let queued_train = spawn_chats(&svc, 1, RequestClass::TrainRollout);
    let queued_interactive = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            let args = SamplingArgs {
                max_new_tokens: 2,
                class: RequestClass::Interactive,
                ..Default::default()
            };
            svc.chat(&[1, 2, 3], 1, &args)
        })
    };

    assert!(
        queued_interactive.join().unwrap().is_err(),
        "the interactive row must expire, not wait out the train rollout"
    );
    for h in first.into_iter().chain(queued_train) {
        h.join().unwrap().unwrap();
    }
    let s = svc.snapshot();
    assert_eq!(s.class_expired[RequestClass::Interactive.index()], 1, "{s:?}");
    assert_eq!(s.class_expired[RequestClass::TrainRollout.index()], 0, "{s:?}");
    assert_eq!(s.class_completed[RequestClass::TrainRollout.index()], 2, "{s:?}");
    assert_eq!(s.failed, 0, "expiry is not a failure: {s:?}");
}

#[test]
fn mock_replicas_decline_migration_and_cold_serve() {
    // mock-path replicas have no extractable KV sessions (the trait
    // default declines): a migration-eligible turn must fall back to a
    // cold serve on the healthy peer with zero failures
    let mut cfg = ServiceConfig::default();
    cfg.cache.min_prefix = 2;
    cfg.qos.enabled = true;
    cfg.qos.migrate_min_tokens = 2;
    let svc = service_with(
        cfg,
        vec![
            Arc::new(MockModel::new(11, Duration::ZERO, 0.0)),
            Arc::new(MockModel::new(12, Duration::from_millis(1), 0.0)),
        ],
    );

    let args = SamplingArgs { session: Some(404), ..Default::default() };
    let turn1 = svc.chat(&[1, 30, 31, 32], 1, &args).unwrap().remove(0);
    assert!(svc.quarantine_replica(0, Duration::from_secs(30)));

    let mut prompt = turn1.tokens.clone();
    prompt.extend([33, 34]);
    let turn2 = svc.chat(&prompt, 1, &args).unwrap().remove(0);
    assert!(turn2.tokens.len() > prompt.len(), "fallback turn must still generate");

    let s = svc.snapshot();
    assert_eq!(s.failed, 0, "{s:?}");
    let cache = s.cache.expect("cache enabled");
    assert_eq!(cache.migrations, 0, "mocks cannot hand over sessions: {cache:?}");
    assert!(s.replicas[1].rows >= 1, "peer must have served the turn: {s:?}");
}

// ---------------------------------------------------------------------------
// artifact-gated: live migration over real GenerationEngine replicas

fn engine_service(replicas: usize, qos_on: bool, seed: u64) -> anyhow::Result<Arc<RolloutService>> {
    let manifest = Manifest::load_default().expect("caller checks artifacts");
    let client = RuntimeClient::global();
    let engine = Arc::new(ModelEngine::new(client, &manifest, "tiny")?);
    engine.warmup()?;
    let mut engines = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        // same init seed on every replica: one logical model behind N
        // serving replicas, exactly like the scheduler's pool
        let params = ParamStore::init(&engine.model, seed)?;
        engines.push(Arc::new(trinity_rft::explorer::GenerationEngine::new(
            Arc::clone(&engine),
            params,
        )));
    }
    let mut cfg = ServiceConfig::default();
    cfg.cache.enabled = qos_on;
    cfg.cache.min_prefix = 2;
    cfg.qos.enabled = qos_on;
    cfg.qos.migrate_min_tokens = 4;
    Ok(Arc::new(RolloutService::over_engines(engines, cfg)?))
}

#[test]
fn engine_migration_is_byte_identical_and_saves_prefill() {
    if Manifest::load_default().is_none() {
        return; // no artifacts in this environment
    }
    let warm = engine_service(2, true, 23).unwrap();
    let cold = engine_service(1, false, 23).unwrap();
    let tok = Tokenizer::new();

    let args = SamplingArgs {
        max_new_tokens: 4,
        temperature: 1.0,
        seed: 99,
        session: Some(888),
        ..Default::default()
    };
    // turn 1: least-loaded ties break to replica 0, which parks the
    // episode's KV session
    let prompt1 = tok.encode_prompt("open the red chest");
    let w1 = warm.chat(&prompt1, 1, &args).unwrap().remove(0);
    let c1 = cold.chat(&prompt1, 1, &args).unwrap().remove(0);
    assert_eq!(w1.tokens, c1.tokens, "turn 1 diverged before any migration");

    // drain the holder: turn 2 now sees Cold(Quarantined) and must
    // migrate the parked session to replica 1 instead of re-prefilling
    assert!(warm.quarantine_replica(0, Duration::from_secs(30)));
    let mut prompt2 = w1.tokens.clone();
    prompt2.extend(tok.encode("north"));
    let w2 = warm.chat(&prompt2, 1, &args).unwrap().remove(0);
    let c2 = cold.chat(&prompt2, 1, &args).unwrap().remove(0);

    assert_eq!(w2.tokens, c2.tokens, "migrated turn must be byte-identical");
    assert_eq!(w2.prompt_len, c2.prompt_len);
    for (lw, lc) in w2.logprobs.iter().zip(&c2.logprobs) {
        assert!((lw - lc).abs() < 1e-4, "migrated logprobs diverged: {lw} vs {lc}");
    }
    assert_eq!(w2.loss_mask, c2.loss_mask);

    let cache = warm.snapshot().cache.expect("cache enabled");
    assert!(cache.migrations >= 1, "turn 2 must migrate the parked session: {cache:?}");
    assert!(
        cache.migration_saved_tokens as usize * 2 >= prompt2.len(),
        "migration must save >=50% of the turn's prefill: saved \
         {} of {} prompt tokens: {cache:?}",
        cache.migration_saved_tokens,
        prompt2.len()
    );
    assert!(cache.resumed >= 1, "the migrated session must resume on the peer: {cache:?}");
    let s = warm.snapshot();
    assert_eq!(s.failed, 0, "{s:?}");
    assert!(s.replicas[1].rows >= 1, "replica 1 must have served the migrated turn: {s:?}");
}
