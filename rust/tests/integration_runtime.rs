//! Integration: rust PJRT runtime executing the real AOT artifacts.
//!
//! These tests require `make artifacts` (they skip gracefully otherwise)
//! and cover the full L3<->L2 contract: logprob semantics, prefill/decode
//! consistency, train-step state threading, dummy learning, and checkpoint
//! round-trips through the engine.

use trinity_rft::model::{ParamStore, WeightSync};
use trinity_rft::runtime::{Manifest, ModelEngine, RuntimeClient, Tensor, TrainState};
use trinity_rft::util::rng::Rng;

fn engine() -> Option<(std::sync::Arc<RuntimeClient>, ModelEngine)> {
    let manifest = Manifest::load_default()?;
    let client = RuntimeClient::global();
    let engine = ModelEngine::new(client.clone(), &manifest, "tiny").unwrap();
    Some((client, engine))
}

fn random_tokens(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Tensor {
    let data: Vec<i32> = (0..b * t).map(|_| rng.below(vocab as u64) as i32).collect();
    Tensor::from_i32(vec![b, t], data)
}

fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = logits.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    logits.iter().map(|&x| x - lse).collect()
}

#[test]
fn manifest_validates_against_model() {
    let Some((_c, engine)) = engine() else { return };
    engine.validate_manifest().unwrap();
    assert!(engine.has_algorithm("grpo"));
    assert!(engine.has_algorithm("opmd_simple"));
}

#[test]
fn logprobs_semantics() {
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 1).unwrap();
    let (b, t) = engine.seq_shape();
    let mut rng = Rng::new(2);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let (lp, ent) = engine.token_logprobs(&params, &tokens).unwrap();
    assert_eq!(lp.shape(), &[b, t]);
    assert_eq!(ent.shape(), &[b, t]);
    let lp_data = lp.f32_data().unwrap();
    // column 0 is defined as 0; all logprobs <= 0
    for i in 0..b {
        assert_eq!(lp_data[i * t], 0.0);
    }
    assert!(lp_data.iter().all(|&x| x <= 1e-5));
    // entropy bounded by log(V)
    let max_ent = (engine.model.vocab_size as f32).ln();
    assert!(ent.f32_data().unwrap().iter().all(|&e| (-1e-4..=max_ent + 1e-3).contains(&e)));
}

#[test]
fn prefill_decode_matches_logprobs() {
    // The generation path (prefill + decode with KV cache) must produce the
    // same conditional distribution as the full-sequence logprobs artifact.
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 3).unwrap();
    let (b, t) = engine.seq_shape();
    let (gb, gp, _cache) = engine.gen_shape();
    assert_eq!(b, gb);
    let mut rng = Rng::new(4);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let (lp, _) = engine.token_logprobs(&params, &tokens).unwrap();

    // prompts = first `plen` tokens of each row
    let plen = gp.min(16);
    let mut prompt = Tensor::zeros(trinity_rft::runtime::DType::I32, &[b, gp]);
    if let Tensor::I32 { data, .. } = &mut prompt {
        for i in 0..b {
            for j in 0..plen {
                data[i * gp + j] = tokens.row_i32(i).unwrap()[j];
            }
        }
    }
    let lens = Tensor::from_i32(vec![b], vec![plen as i32; b]);
    let mut state = engine.prefill(&params, &prompt, &lens).unwrap();

    // prefill last-logits predict token at index plen
    for i in 0..b {
        let ls = log_softmax(state.logits.row_f32(i).unwrap());
        let target = tokens.row_i32(i).unwrap()[plen] as usize;
        let expected = lp.row_f32(i).unwrap()[plen];
        assert!(
            (ls[target] - expected).abs() < 1e-3,
            "prefill row {i}: {} vs {}",
            ls[target],
            expected
        );
    }

    // decode 4 steps feeding the true tokens; logits must match lp columns
    for s in 0..4usize {
        let pos = plen + s;
        let step_tokens =
            Tensor::from_i32(vec![b], (0..b).map(|i| tokens.row_i32(i).unwrap()[pos]).collect());
        let pos_t = Tensor::from_i32(vec![b], vec![pos as i32; b]);
        let logits = engine.decode(&params, &mut state, &step_tokens, &pos_t).unwrap();
        for i in 0..b {
            let ls = log_softmax(logits.row_f32(i).unwrap());
            let target = tokens.row_i32(i).unwrap()[pos + 1] as usize;
            let expected = lp.row_f32(i).unwrap()[pos + 1];
            assert!(
                (ls[target] - expected).abs() < 1e-3,
                "decode step {s} row {i}: {} vs {}",
                ls[target],
                expected
            );
        }
    }
}

#[test]
fn embed_is_normalized_and_mask_sensitive() {
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 5).unwrap();
    let (b, t) = engine.seq_shape();
    let mut rng = Rng::new(6);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let full = Tensor::from_f32(vec![b, t], vec![1.0; b * t]);
    let emb = engine.embed(&params, &tokens, &full).unwrap();
    assert_eq!(emb.shape(), &[b, engine.model.d_model]);
    for i in 0..b {
        let row = emb.row_f32(i).unwrap();
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }
    // half mask changes the embedding
    let mut half = vec![1.0f32; b * t];
    for i in 0..b {
        for j in t / 2..t {
            half[i * t + j] = 0.0;
        }
    }
    let emb2 = engine.embed(&params, &tokens, &Tensor::from_f32(vec![b, t], half)).unwrap();
    let d: f32 = emb
        .f32_data()
        .unwrap()
        .iter()
        .zip(emb2.f32_data().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-4);
}

#[test]
fn train_step_dummy_learning_freezes_params() {
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 7).unwrap();
    let snap_before = params.snapshot().unwrap();
    let mut state = TrainState::new(params).unwrap();
    let (b, t, _) = engine.train_shape("grpo").unwrap();
    let mut rng = Rng::new(8);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let mut mask = vec![1.0f32; b * t];
    for i in 0..b {
        mask[i * t] = 0.0;
    }
    let mask = Tensor::from_f32(vec![b, t], mask);
    let (lp, _) = engine.token_logprobs(&state.params, &tokens).unwrap();
    let adv = Tensor::from_f32(vec![b], vec![1.0, -1.0, 0.5, -0.5]);
    // hyper: lr=0 (dummy learning)
    let hyper = [0.0, 0.9, 0.999, 1e-8, 0.2, 1.0, 0.1, 0.0];
    let metrics = engine.train_step("grpo", &mut state, &hyper, &[&tokens, &mask, &adv, &lp]).unwrap();
    assert!(metrics.iter().all(|(_, v)| v.is_finite()), "{metrics:?}");
    let snap_after = state.params.snapshot().unwrap();
    for (a, b) in snap_before.iter().zip(&snap_after) {
        assert_eq!(a, b, "lr=0 must freeze params");
    }
    assert_eq!(state.step, 1);
}

#[test]
fn train_step_sft_reduces_nll() {
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 9).unwrap();
    let mut state = TrainState::new(params).unwrap();
    let (b, t, _) = engine.train_shape("sft").unwrap();
    let mut rng = Rng::new(10);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let mut mask = vec![1.0f32; b * t];
    for i in 0..b {
        mask[i * t] = 0.0;
    }
    let mask = Tensor::from_f32(vec![b, t], mask);
    let hyper = [5e-3, 0.9, 0.999, 1e-8, 0.2, 1.0, 0.1, 0.0];
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for i in 0..5 {
        let metrics = engine.train_step("sft", &mut state, &hyper, &[&tokens, &mask]).unwrap();
        let loss = metrics.iter().find(|(n, _)| n == "loss").unwrap().1;
        if i == 0 {
            first_loss = loss;
        }
        last_loss = loss;
    }
    assert!(last_loss < first_loss, "SFT loss should fall: {first_loss} -> {last_loss}");
    assert_eq!(state.step, 5);
}

#[test]
fn grpo_raises_positively_advantaged_logprob() {
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 11).unwrap();
    let (b, t, _) = engine.train_shape("grpo").unwrap();
    let mut rng = Rng::new(12);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let mut mask = vec![1.0f32; b * t];
    for i in 0..b {
        mask[i * t] = 0.0;
    }
    let mask = Tensor::from_f32(vec![b, t], mask);
    let (lp0, _) = engine.token_logprobs(&params, &tokens).unwrap();
    let seq_lp = |lp: &Tensor| -> Vec<f32> {
        (0..b)
            .map(|i| {
                lp.row_f32(i)
                    .unwrap()
                    .iter()
                    .zip(mask.row_f32(i).unwrap())
                    .map(|(l, m)| l * m)
                    .sum()
            })
            .collect()
    };
    let before = seq_lp(&lp0);
    let mut state = TrainState::new(params).unwrap();
    let adv = Tensor::from_f32(vec![b], vec![2.0, -2.0, 0.0, 0.0]);
    let hyper = [5e-3, 0.9, 0.999, 1e-8, 0.2, 1.0, 0.1, 0.0];
    engine.train_step("grpo", &mut state, &hyper, &[&tokens, &mask, &adv, &lp0]).unwrap();
    let (lp1, _) = engine.token_logprobs(&state.params, &tokens).unwrap();
    let after = seq_lp(&lp1);
    assert!(after[0] > before[0], "+adv seq should rise: {} -> {}", before[0], after[0]);
    assert!(after[1] < before[1], "-adv seq should fall: {} -> {}", before[1], after[1]);
}

#[test]
fn weight_sync_roundtrip_through_engine() {
    let Some((_c, engine)) = engine() else { return };
    let trainer_params = ParamStore::init(&engine.model, 13).unwrap();
    let mut explorer_params = ParamStore::init(&engine.model, 14).unwrap();
    assert!(trainer_params.l2_distance(&explorer_params).unwrap() > 0.0);

    let sync = trinity_rft::model::MemorySync::new();
    let snap = trainer_params.to_snapshot(None).unwrap();
    sync.publish(1, 100, snap).unwrap();
    let update = sync.fetch_if_newer(0).unwrap().unwrap();
    explorer_params.apply_snapshot(&update.snapshot, update.version).unwrap();
    assert_eq!(trainer_params.l2_distance(&explorer_params).unwrap(), 0.0);

    // both produce identical logprobs now
    let (b, t) = engine.seq_shape();
    let mut rng = Rng::new(15);
    let tokens = random_tokens(&mut rng, b, t, engine.model.vocab_size);
    let (lp_a, _) = engine.token_logprobs(&trainer_params, &tokens).unwrap();
    let (lp_b, _) = engine.token_logprobs(&explorer_params, &tokens).unwrap();
    assert_eq!(lp_a.f32_data().unwrap(), lp_b.f32_data().unwrap());
}

#[test]
fn checkpoint_roundtrip_through_engine() {
    let Some((_c, engine)) = engine() else { return };
    let params = ParamStore::init(&engine.model, 16).unwrap();
    let dir = std::env::temp_dir().join(format!("trft_it_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.ckpt");
    let snap = params.snapshot().unwrap();
    let leaves: Vec<(String, Vec<usize>, &[f32])> = engine
        .model
        .params
        .iter()
        .zip(&snap)
        .map(|(p, w)| (p.name.clone(), p.shape.clone(), w.as_slice()))
        .collect();
    trinity_rft::model::save_checkpoint(&path, "tiny", 7, 3, &leaves).unwrap();
    let ck = trinity_rft::model::load_checkpoint(&path).unwrap();
    assert_eq!(ck.step, 7);
    let restored = ParamStore::from_snapshot(&engine.model, &ck.weights()).unwrap();
    assert_eq!(params.l2_distance(&restored).unwrap(), 0.0);
    std::fs::remove_dir_all(&dir).unwrap();
}
