//! End-to-end integration over the full trinity: every RFT mode running
//! real PJRT rollouts + train steps on the tiny preset.
//! Requires `make artifacts` (skips gracefully otherwise).

use std::sync::Arc;

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::data::{ExperienceProcessor, QualityRewardProcessor};
use trinity_rft::runtime::Manifest;

fn base_cfg() -> Option<RftConfig> {
    Manifest::load_default()?;
    let mut cfg = RftConfig::default();
    cfg.model_preset = "tiny".into();
    cfg.total_steps = 3;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4; // matches tiny grpo batch of 4
    cfg.max_new_tokens = 6;
    cfg.hyper.lr = 1e-4;
    cfg.explorer_threads = 2;
    cfg.seed = 11;
    Some(cfg)
}

#[test]
fn synchronous_mode_runs_and_is_on_policy() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.sync_interval = 1;
    cfg.sync_offset = 0;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 3);
    assert_eq!(report.explore_batches, 3);
    assert_eq!(report.sync_count, 3);
    // strictly on-policy: the trainer's KL to the rollout policy is ~0 on
    // the FIRST step (weights identical)
    let kl0 = report.trainer_metrics[0].get("kl").unwrap();
    assert!(kl0.abs() < 1e-3, "on-policy first-step KL should be ~0, got {kl0}");
    // timeline has both rollout spans and sync points
    assert!(report.timeline.iter().any(|e| e.kind == "rollout"));
    assert!(report.timeline.iter().any(|e| e.kind == "weight_sync"));
}

#[test]
fn sync_interval_reduces_sync_count() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.total_steps = 4;
    cfg.sync_interval = 2;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 4);
    assert_eq!(report.sync_count, 2);
}

#[test]
fn one_step_offpolicy_overlaps_pipeline() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.sync_interval = 1;
    cfg.sync_offset = 1;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 3);
    assert_eq!(report.explore_batches, 3);
}

#[test]
fn async_mode_with_multi_explorer() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "async".into();
    cfg.explorer_count = 2;
    cfg.sync_interval = 2;
    cfg.total_steps = 3;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 3);
    assert!(report.explore_batches >= 1);
    assert!(report.mode.contains("x2"));
}

#[test]
fn dummy_learning_freezes_weights_across_modes() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.dummy_learning = true;
    cfg.sync_interval = 1;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let before = session.trainer.as_ref().unwrap().params().snapshot().unwrap();
    let report = session.run().unwrap();
    let after = session.trainer.as_ref().unwrap().params().snapshot().unwrap();
    for (a, b) in before.iter().zip(&after) {
        assert_eq!(a, b);
    }
    assert_eq!(report.train_steps, 3);
}

#[test]
fn train_only_mode_on_prefilled_buffer() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "train".into();
    cfg.algorithm = "sft".into();
    cfg.total_steps = 2;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    // pre-fill the buffer with expert experiences (offline SFT)
    let formatter = trinity_rft::data::formatter::Formatter {
        spec: Default::default(),
        tokenizer: Arc::clone(&session.tokenizer),
    };
    let mut exps = vec![];
    for i in 0..8 {
        let raw = trinity_rft::util::json::Value::obj(vec![
            ("question", trinity_rft::util::json::Value::str(format!("what is {i} + 1 ?"))),
            ("answer", trinity_rft::util::json::Value::str((i + 1).to_string())),
        ]);
        exps.push(formatter.to_expert_experience(&raw).unwrap());
    }
    session.buffer.write(exps).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
    assert_eq!(report.explore_batches, 0);
    assert_eq!(report.sync_count, 0); // offline policy never publishes
    assert_eq!(report.mode, "train");
}

#[test]
fn custom_registered_algorithm_trains_end_to_end() {
    use trinity_rft::trainer::{
        AlgorithmRegistry, AlgorithmSpec, GroupBaseline, GroupingPolicy, LossSpec,
    };
    let Some(mut cfg) = base_cfg() else { return };
    // a custom algorithm = one registration reusing the grpo artifact;
    // no trainer/ source is touched
    AlgorithmRegistry::global().register(
        AlgorithmSpec::new("custom_grpo_e2e", "grpo")
            .advantage(GroupBaseline { std_normalize: true })
            .grouping(GroupingPolicy::GroupBaseline)
            .old_logprobs(true)
            .loss(LossSpec::pg_clip())
            .about("externally registered GRPO variant"),
    );
    cfg.mode = "both".into();
    cfg.algorithm = "custom_grpo_e2e".into();
    cfg.total_steps = 2;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
    // the batch-builder diagnostic threads through to step metrics
    assert!(report.trainer_metrics[0].get("truncated_seqs").is_some());
}

#[test]
fn unregistered_algorithm_fails_session_build_with_catalog() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.algorithm = "no_such_alg".into();
    let err = format!("{:#}", RftSession::build(cfg, None, None).unwrap_err());
    assert!(err.contains("unknown algorithm 'no_such_alg'"), "{err}");
    assert!(err.contains("grpo"), "error should list the registry: {err}");
}

#[test]
fn bench_mode_reports_tiers() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "bench".into();
    let session = RftSession::build(cfg, None, None).unwrap();
    let reports = session.run_bench(&["math500s", "amcs"], 2, 2, 0.6).unwrap();
    assert_eq!(reports.len(), 2);
    for (tier, r) in &reports {
        assert!(!tier.is_empty());
        assert_eq!(r.tasks, 2);
        assert_eq!(r.rollouts, 4);
        assert!((0.0..=1.0).contains(&r.avg_reward));
    }
}

#[test]
fn quality_shaping_pipeline_changes_rewards() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.total_steps = 2;
    let processor: Arc<dyn ExperienceProcessor> = Arc::new(QualityRewardProcessor { weight: 1.0 });
    let mut session = RftSession::build(cfg, None, Some(processor)).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
    // shaped rewards are no longer exactly {0, 1}: base + quality in [-.5,.5]
    let rewards = report.reward_series();
    assert!(rewards.iter().any(|r| r.fract().abs() > 1e-6), "rewards look unshaped: {rewards:?}");
}

#[test]
fn eval_snapshots_collected_and_loadable() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.total_steps = 4;
    cfg.eval_every = 2;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.snapshots.len(), 2);
    assert_eq!(report.snapshots[0].0, 2);
    assert_eq!(report.snapshots[1].0, 4);
    // snapshots load back into the explorer for bench-over-checkpoints
    session.load_explorer_weights(&report.snapshots[0].1, 100).unwrap();
    assert_eq!(session.explorers[0].weight_version(), 100);
}
