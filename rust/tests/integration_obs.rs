//! Observability integration: the ISSUE-6 acceptance surface.
//!
//! * reconstruction — a mock multi-turn service run with tracing enabled
//!   yields a `trace.json` from which each episode reads end-to-end:
//!   queue wait → prefill/resume marker → decode, per turn, with
//!   cache-hit turns showing resume markers instead of cold prefills;
//! * percentiles — the service snapshot carries queue-wait and rollout
//!   latency histograms with usable p50/p95/p99;
//! * disabled — without a span recorder the run produces byte-identical
//!   experiences and zero spans (observability is a pure read).

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::buffer::Experience;
use trinity_rft::explorer::{
    AlfworldWorkflow, MockModel, RolloutEndpoint, RolloutModel, SamplingArgs, Task, Workflow,
    WorkflowCtx,
};
use trinity_rft::obs::{load_trace, summarize_trace, write_trace, Span, SpanKind, SpanRecorder};
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::{Tokenizer, EOS};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

/// A mock whose response is a pure function of the prompt, so two
/// identical call sequences produce byte-identical outputs.
fn deterministic_mock(seed: u64) -> MockModel {
    let tok = Tokenizer::new();
    let look = tok.encode("look");
    MockModel::new(seed, Duration::ZERO, 0.0).with_response(move |_prompt, _rng| {
        let mut r = look.clone();
        r.push(EOS);
        r
    })
}

fn alfworld_task(seed: i64, repeat: usize) -> Task {
    let mut t = Task::new("obs-ep", "alfworld", Value::obj(vec![("seed", Value::int(seed))]));
    t.repeat_times = repeat;
    t
}

/// Run the multi-turn workflow against a service handle, single-file,
/// so the request order is deterministic.
fn run_episodes(svc: &Arc<RolloutService>, seed: i64, repeat: usize) -> Vec<Experience> {
    let tok = Tokenizer::new();
    let task = alfworld_task(seed, repeat);
    let sampling = SamplingArgs { max_new_tokens: 8, ..Default::default() };
    let model: &dyn RolloutModel = svc.as_ref();
    let mut ctx = WorkflowCtx { model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(7) };
    let wf =
        AlfworldWorkflow { max_env_steps: 3, env_init_cost: Duration::ZERO, max_seq_tokens: 200 };
    wf.run(&mut ctx).unwrap()
}

fn traced_service(recorder: &Arc<SpanRecorder>, seed: u64) -> Arc<RolloutService> {
    let mut cfg = ServiceConfig::default();
    cfg.cache.enabled = true;
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> = vec![Arc::new(deterministic_mock(seed))];
    Arc::new(
        RolloutService::over_models_obs(endpoints, cfg, Some(Arc::clone(recorder))).unwrap(),
    )
}

fn spans_of<'a>(spans: &'a [Span], trace: u64) -> Vec<&'a Span> {
    spans.iter().filter(|s| s.trace == trace).collect()
}

#[test]
fn multi_turn_trace_reconstructs_each_episode_end_to_end() {
    let recorder = Arc::new(SpanRecorder::new(1 << 12));
    let svc = traced_service(&recorder, 3);

    // 2 episodes x 3 turns through the session-keyed chat path
    let exps = run_episodes(&svc, 5, 2);
    assert!(!exps.is_empty());

    let spans = recorder.drain();
    let mut traces: Vec<u64> = spans.iter().map(|s| s.trace).filter(|&t| t != 0).collect();
    traces.sort_unstable();
    traces.dedup();
    assert_eq!(traces.len(), 2, "one trace id per episode: {traces:?}");

    for &trace in &traces {
        let ep = spans_of(&spans, trace);
        let count = |kind: SpanKind| ep.iter().filter(|s| s.kind == kind).count();
        // every turn queues once and decodes once
        assert_eq!(count(SpanKind::QueueWait), 3, "trace {trace}: {ep:?}");
        assert_eq!(count(SpanKind::Decode), 3, "trace {trace}: {ep:?}");
        // every turn serves exactly once — cold (prefill) or via the
        // prefix cache (resume); turn 1 is always cold and later turns
        // extend the served transcript, so resumes must appear
        assert_eq!(
            count(SpanKind::Prefill) + count(SpanKind::Resume),
            3,
            "trace {trace}: {ep:?}"
        );
        assert!(count(SpanKind::Prefill) >= 1, "turn 1 is cold: {ep:?}");
        assert!(count(SpanKind::Resume) >= 1, "cache-hit turns must resume: {ep:?}");
        // drain() orders by start time: the episode must begin with its
        // queue wait and every prefill/resume marker must precede the
        // decode it belongs to
        assert_eq!(ep[0].kind, SpanKind::QueueWait, "trace {trace}: {ep:?}");
        let first_decode =
            ep.iter().position(|s| s.kind == SpanKind::Decode).expect("decode span");
        let first_serve = ep
            .iter()
            .position(|s| matches!(s.kind, SpanKind::Prefill | SpanKind::Resume))
            .expect("serve marker");
        assert!(first_serve < first_decode, "trace {trace}: {ep:?}");
        // resume markers carry the reused-prefix length
        assert!(
            ep.iter().filter(|s| s.kind == SpanKind::Resume).all(|s| s.detail > 0),
            "resume detail must carry reused tokens: {ep:?}"
        );
    }

    // the exported file round-trips and summarizes both episodes
    let dir = std::env::temp_dir().join(format!("trft_obs_{}", std::process::id()));
    let path = dir.join("trace.json");
    write_trace(&path, &spans).unwrap();
    let summary = summarize_trace(&load_trace(&path).unwrap()).unwrap();
    assert!(summary.contains("2 episode(s)"), "{summary}");
    for kind in ["queue_wait", "prefill", "resume", "decode"] {
        assert!(summary.contains(kind), "missing {kind} in:\n{summary}");
    }
    std::fs::remove_dir_all(&dir).unwrap();

    // latency histograms ride the same run: both distributions have one
    // observation per row and usable tail percentiles
    let snap = svc.snapshot();
    assert_eq!(snap.queue_wait.count, 6, "{snap:?}");
    assert_eq!(snap.rollout.count, 6, "{snap:?}");
    let (p50, p95, p99) = snap.rollout.p50_p95_p99();
    assert!(p50 > 0.0 && p95 >= p50 && p99 >= p95, "{p50} {p95} {p99}");
}

#[test]
fn disabled_observability_is_byte_identical_with_zero_spans() {
    let recorder = Arc::new(SpanRecorder::new(1 << 12));
    let traced = traced_service(&recorder, 11);

    let mut cfg = ServiceConfig::default();
    cfg.cache.enabled = true;
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> = vec![Arc::new(deterministic_mock(11))];
    let plain = Arc::new(RolloutService::over_models(endpoints, cfg).unwrap());
    assert!(plain.observer().is_none());

    let exps_traced = run_episodes(&traced, 9, 2);
    let exps_plain = run_episodes(&plain, 9, 2);
    assert_eq!(exps_traced.len(), exps_plain.len());
    for (x, y) in exps_traced.iter().zip(&exps_plain) {
        assert_eq!(x.tokens, y.tokens, "token streams diverged");
        assert_eq!(x.logprobs, y.logprobs, "logprobs diverged");
        assert_eq!(x.loss_mask, y.loss_mask, "loss masks diverged");
        assert_eq!(x.prompt_len, y.prompt_len);
        assert_eq!(x.reward, y.reward);
    }

    // tracing observed the run; the plain service recorded nothing at all
    assert!(recorder.recorded() > 0);
    let fresh = SpanRecorder::new(64);
    assert_eq!(fresh.recorded(), 0);
    assert!(fresh.drain().is_empty());
}
