//! Integration: the zero-copy weight distribution plane.
//!
//! Ungated tests cover the pure snapshot/sync layer (no PJRT): a
//! published `Arc<WeightSnapshot>` must reach every concurrent fetcher
//! as the SAME allocation — fetch is a refcount bump, never a copy.
//! Artifact-gated tests (skip without `make artifacts`) cover the delta
//! apply against real engine literals: unchanged leaves are skipped by
//! fingerprint, and the result is byte-identical to a full rebuild.

use std::sync::Arc;

use trinity_rft::explorer::GenerationEngine;
use trinity_rft::model::{MemorySync, ParamStore, WeightSnapshot, WeightSync};
use trinity_rft::runtime::{Manifest, ModelEngine, RuntimeClient};

fn engine() -> Option<(Arc<RuntimeClient>, ModelEngine)> {
    let manifest = Manifest::load_default()?;
    let client = RuntimeClient::global();
    let engine = ModelEngine::new(client.clone(), &manifest, "tiny").unwrap();
    Some((client, engine))
}

// ---------------------------------------------------------------------------
// ungated: snapshot sharing through MemorySync

#[test]
fn concurrent_fetches_share_the_published_allocation() {
    let sync = MemorySync::new();
    let published = WeightSnapshot::of(vec![vec![1.0; 64], vec![2.0; 32]]);
    sync.publish(1, 10, Arc::clone(&published)).unwrap();

    // N threads fetch the same version concurrently; every one must get
    // the identical Arc — pointer equality, not just equal bytes.
    let updates: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| s.spawn(|| sync.fetch_if_newer(0).unwrap().unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(updates.len(), 4);
    for u in &updates {
        assert_eq!(u.version, 1);
        assert!(
            Arc::ptr_eq(&u.snapshot, &published),
            "fetch_if_newer must hand out the published Arc, not a copy"
        );
        for i in 0..published.leaf_count() {
            assert!(Arc::ptr_eq(u.snapshot.leaf_arc(i), published.leaf_arc(i)));
        }
    }
}

#[test]
fn latest_version_probe_short_circuits_stale_fetches() {
    let sync = MemorySync::new();
    assert_eq!(sync.latest_version(), 0);
    assert!(sync.fetch_if_newer(0).unwrap().is_none());
    sync.publish(1, 5, WeightSnapshot::of(vec![vec![0.5]])).unwrap();
    sync.publish(2, 6, WeightSnapshot::of(vec![vec![0.7]])).unwrap();
    assert_eq!(sync.latest_version(), 2);
    assert!(sync.fetch_if_newer(2).unwrap().is_none(), "probe says current");
    let u = sync.fetch_if_newer(1).unwrap().unwrap();
    assert_eq!(u.version, 2);
    assert_eq!(u.snapshot.leaf(0)[0], 0.7);
}

#[test]
fn republish_shares_unchanged_leaf_buffers() {
    // The trainer-side reuse contract at the snapshot level: a second
    // snapshot built against the first shares every unchanged buffer.
    let a = WeightSnapshot::of(vec![vec![1.0; 16], vec![2.0; 8], vec![3.0; 4]]);
    let mut w = a.to_weights();
    w[1][0] = 9.0;
    let fresh = WeightSnapshot::from_weights(&w);
    assert_eq!(a.shared_leaves(&fresh), 0, "independent builds share nothing");
    assert_eq!(fresh.fingerprint(0), a.fingerprint(0));
    assert_ne!(fresh.fingerprint(1), a.fingerprint(1));
}

// ---------------------------------------------------------------------------
// artifact-gated: delta apply against real engine literals

#[test]
fn delta_apply_is_byte_identical_and_skips_clean_leaves() {
    let Some((_c, engine)) = engine() else { return };
    let src = ParamStore::init(&engine.model, 21).unwrap();
    let mut dst = ParamStore::init(&engine.model, 22).unwrap();
    assert!(src.l2_distance(&dst).unwrap() > 0.0);

    // full first apply: every leaf dirty
    let snap1 = src.to_snapshot(None).unwrap();
    let n = snap1.leaf_count();
    assert_eq!(dst.apply_snapshot(&snap1, 1).unwrap(), n);
    assert_eq!(src.l2_distance(&dst).unwrap(), 0.0, "byte-identical after apply");

    // perturb exactly one leaf and republish
    let mut weights = snap1.to_weights();
    weights[0][0] += 1.0;
    let snap2 = WeightSnapshot::from_weights(&weights);
    assert_eq!(dst.plan_delta(&snap2).unwrap(), vec![0], "only leaf 0 dirty");

    let hits_before = dst.fingerprint_hits();
    let rebuilt = dst.apply_snapshot(&snap2, 2).unwrap();
    assert_eq!(rebuilt, 1, "K of N leaves unchanged -> rebuild exactly N-K");
    assert_eq!(dst.fingerprint_hits() - hits_before, (n - 1) as u64);

    // the delta-applied store matches a from-scratch rebuild exactly
    let full = ParamStore::from_weight_snapshot(&engine.model, &snap2).unwrap();
    assert_eq!(dst.l2_distance(&full).unwrap(), 0.0);
}

#[test]
fn prepared_commit_matches_one_shot_apply() {
    let Some((_c, engine)) = engine() else { return };
    let src = ParamStore::init(&engine.model, 23).unwrap();
    let snap = src.to_snapshot(None).unwrap();

    let mut inline = ParamStore::init(&engine.model, 24).unwrap();
    inline.apply_snapshot(&snap, 1).unwrap();

    let mut staged = ParamStore::init(&engine.model, 25).unwrap();
    let dirty = staged.plan_delta(&snap).unwrap();
    let prepared = ParamStore::prepare_leaves(&engine.model, &snap, &dirty).unwrap();
    assert_eq!(prepared.len(), dirty.len());
    staged.commit_prepared(&snap, prepared, 1).unwrap();

    assert_eq!(inline.l2_distance(&staged).unwrap(), 0.0);
    assert_eq!(staged.version(), 1);
}

#[test]
fn generation_engine_delta_syncs_through_memory_sync() {
    let Some((_c, engine)) = engine() else { return };
    let engine = Arc::new(engine);
    let trainer = ParamStore::init(&engine.model, 31).unwrap();
    let gen =
        GenerationEngine::new(Arc::clone(&engine), ParamStore::init(&engine.model, 32).unwrap());

    let sync = MemorySync::new();
    let snap1 = trainer.to_snapshot(None).unwrap();
    sync.publish(1, 10, Arc::clone(&snap1)).unwrap();
    assert!(gen.try_sync(&sync).unwrap());
    assert_eq!(gen.params_version(), 1);
    assert!(!gen.try_sync(&sync).unwrap(), "already current");

    // republish identical content at a newer version: the apply must be
    // all fingerprint hits, no leaf rebuilds
    let snap2 = trainer.to_snapshot(Some(&snap1)).unwrap();
    assert_eq!(snap2.shared_leaves(&snap1), snap1.leaf_count(), "publish-side reuse");
    sync.publish(2, 20, snap2).unwrap();
    let hits_before = gen.fingerprint_hits();
    assert!(gen.try_sync(&sync).unwrap());
    assert_eq!(gen.params_version(), 2);
    assert_eq!(
        gen.fingerprint_hits() - hits_before,
        snap1.leaf_count() as u64,
        "identical republish applies via fingerprint hits only"
    );
}
