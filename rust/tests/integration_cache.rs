//! Prefix-reuse cache integration: the ISSUE-5 acceptance surface.
//!
//! * correctness — a multi-turn ALFWorld-style episode produces
//!   byte-identical experiences with the cache on vs. off (the cache is
//!   a pure speedup, never a behavior change),
//! * reuse — the prefix index reports hits from turn 2 onward,
//! * pressure — trie eviction under a tiny token budget keeps outputs
//!   identical, and a quarantined affinity replica falls back cleanly
//!   to a cold serve on a healthy peer,
//! * engine resume — artifact-gated: a real `GenerationEngine` replica
//!   parks and resumes KV sessions with byte-identical outputs and
//!   nonzero prefill tokens saved.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::buffer::Experience;
use trinity_rft::explorer::{
    AlfworldWorkflow, MockModel, RolloutEndpoint, RolloutModel, SamplingArgs, Task, Workflow,
    WorkflowCtx,
};
use trinity_rft::model::ParamStore;
use trinity_rft::runtime::{Manifest, ModelEngine, RuntimeClient};
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::{Tokenizer, EOS};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

/// A mock whose response is a pure function of the prompt, so two
/// identical call sequences produce byte-identical outputs.
fn deterministic_mock(seed: u64) -> MockModel {
    let tok = Tokenizer::new();
    let look = tok.encode("look");
    MockModel::new(seed, Duration::ZERO, 0.0).with_response(move |_prompt, _rng| {
        let mut r = look.clone();
        r.push(EOS);
        r
    })
}

fn alfworld_task(seed: i64, repeat: usize) -> Task {
    let mut t = Task::new("cache-ep", "alfworld", Value::obj(vec![("seed", Value::int(seed))]));
    t.repeat_times = repeat;
    t
}

/// Run the multi-turn workflow against a service handle, single-file
/// (no runner pool), so the request order is deterministic.
fn run_episodes(svc: &Arc<RolloutService>, seed: i64, repeat: usize) -> Vec<Experience> {
    let tok = Tokenizer::new();
    let task = alfworld_task(seed, repeat);
    let sampling = SamplingArgs { max_new_tokens: 8, ..Default::default() };
    let model: &dyn RolloutModel = svc.as_ref();
    let mut ctx = WorkflowCtx { model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(7) };
    let wf =
        AlfworldWorkflow { max_env_steps: 3, env_init_cost: Duration::ZERO, max_seq_tokens: 200 };
    wf.run(&mut ctx).unwrap()
}

fn service_with(cfg: ServiceConfig, models: Vec<Arc<MockModel>>) -> Arc<RolloutService> {
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        models.into_iter().map(|m| m as Arc<dyn RolloutEndpoint>).collect();
    Arc::new(RolloutService::over_models(endpoints, cfg).unwrap())
}

fn assert_identical(a: &[Experience], b: &[Experience]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.tokens, y.tokens, "token streams diverged");
        assert_eq!(x.logprobs, y.logprobs, "logprobs diverged");
        assert_eq!(x.loss_mask, y.loss_mask, "loss masks diverged");
        assert_eq!(x.prompt_len, y.prompt_len);
        assert_eq!(x.reward, y.reward);
    }
}

#[test]
fn multi_turn_episode_byte_identical_cache_on_vs_off_with_hits_from_turn_2() {
    let mut on = ServiceConfig::default();
    on.cache.enabled = true;
    let mut off = ServiceConfig::default();
    off.cache.enabled = false;

    let svc_on = service_with(on, vec![Arc::new(deterministic_mock(3))]);
    let svc_off = service_with(off, vec![Arc::new(deterministic_mock(3))]);

    let exps_on = run_episodes(&svc_on, 5, 2);
    let exps_off = run_episodes(&svc_off, 5, 2);
    assert_identical(&exps_on, &exps_off);

    // 2 episodes x 3 turns: every turn after the first of each episode
    // extends the episode's served transcript, so it must hit
    let cache = svc_on.snapshot().cache.expect("cache enabled");
    assert_eq!(cache.lookups, 6, "{cache:?}");
    assert!(cache.hits >= 2, "no reuse from turn 2: {cache:?}");
    assert!(cache.reused_tokens > 0, "{cache:?}");
    assert!(
        cache.hits + cache.misses == cache.lookups,
        "hit/miss accounting drifted: {cache:?}"
    );
    assert!(svc_off.snapshot().cache.is_none());
}

#[test]
fn trie_eviction_under_pressure_keeps_outputs_identical() {
    // a trie budget smaller than any transcript: every admit evicts,
    // every lookup misses — pure pressure, zero behavior change
    let mut tiny = ServiceConfig::default();
    tiny.cache.trie_tokens = 4;
    let mut off = ServiceConfig::default();
    off.cache.enabled = false;

    let svc_tiny = service_with(tiny, vec![Arc::new(deterministic_mock(4))]);
    let svc_off = service_with(off, vec![Arc::new(deterministic_mock(4))]);

    let exps_tiny = run_episodes(&svc_tiny, 9, 2);
    let exps_off = run_episodes(&svc_off, 9, 2);
    assert_identical(&exps_tiny, &exps_off);

    let cache = svc_tiny.snapshot().cache.unwrap();
    assert!(cache.trie_evictions >= 1, "budget pressure must evict: {cache:?}");
    assert!(cache.trie_tokens <= 4, "{cache:?}");
}

#[test]
fn quarantined_affinity_replica_falls_back_to_cold_serve_on_peer() {
    let broken = Arc::new(MockModel::new(11, Duration::ZERO, 0.0));
    let healthy = Arc::new(MockModel::new(12, Duration::from_millis(1), 0.0));
    let mut cfg = ServiceConfig::default();
    cfg.breaker_failures = 2;
    cfg.quarantine = Duration::from_secs(30); // stays dark for the test
    cfg.max_attempts = 5;
    cfg.retry_backoff = Duration::from_millis(1);
    cfg.cache.min_prefix = 2;
    let svc = service_with(cfg, vec![Arc::clone(&broken), Arc::clone(&healthy)]);

    // turn 1: both replicas idle, least-loaded ties break to replica 0,
    // which becomes the episode's prefix holder
    let args = SamplingArgs { session: Some(404), ..Default::default() };
    let turn1 = svc.chat(&[1, 30, 31, 32], 1, &args).unwrap().remove(0);

    // break replica 0 until its breaker opens
    broken.set_fail_rate(1.0);
    for i in 0..2 {
        svc.chat(&[1, 90 + i], 1, &SamplingArgs::default()).unwrap();
    }
    let snap = svc.snapshot();
    assert!(snap.replicas[0].quarantined, "breaker never opened: {snap:?}");

    // turn 2 extends the transcript held by the quarantined replica: the
    // affinity router must fall back cleanly to a cold serve on the peer
    let mut prompt = turn1.tokens.clone();
    prompt.extend([33, 34]);
    let turn2 = svc.chat(&prompt, 1, &args).unwrap().remove(0);
    assert!(turn2.tokens.len() > prompt.len(), "fallback turn must still generate");

    let cache = svc.snapshot().cache.unwrap();
    assert!(cache.affinity_fallbacks >= 1, "{cache:?}");
    let snap = svc.snapshot();
    assert_eq!(snap.failed, 0, "fallback must not fail requests: {snap:?}");
    assert!(snap.replicas[1].rows >= 3, "peer should have absorbed the turn: {snap:?}");
}

// ---------------------------------------------------------------------------
// artifact-gated: real KV resume over GenerationEngine replicas

fn engine_service(cache_on: bool, seed: u64) -> anyhow::Result<Arc<RolloutService>> {
    let manifest = Manifest::load_default().expect("caller checks artifacts");
    let client = RuntimeClient::global();
    let engine = Arc::new(ModelEngine::new(client, &manifest, "tiny")?);
    engine.warmup()?;
    let params = ParamStore::init(&engine.model, seed)?;
    let gen = Arc::new(trinity_rft::explorer::GenerationEngine::new(engine, params));
    let mut cfg = ServiceConfig::default();
    cfg.cache.enabled = cache_on;
    cfg.cache.min_prefix = 2;
    Ok(Arc::new(RolloutService::over_engines(vec![gen], cfg)?))
}

#[test]
fn engine_resume_is_byte_identical_and_saves_prefill() {
    if Manifest::load_default().is_none() {
        return; // no artifacts in this environment
    }
    let warm = engine_service(true, 21).unwrap();
    let cold = engine_service(false, 21).unwrap();
    let tok = Tokenizer::new();
    let obs: Vec<Vec<i32>> = ["north", "door", "key"].iter().map(|o| tok.encode(o)).collect();

    let args = SamplingArgs {
        max_new_tokens: 4,
        temperature: 1.0,
        seed: 99,
        session: Some(777),
        ..Default::default()
    };
    let mut warm_prompt = tok.encode_prompt("find the key");
    let mut cold_prompt = warm_prompt.clone();
    for turn in 0..3 {
        let w = warm.chat(&warm_prompt, 1, &args).unwrap().remove(0);
        let c = cold.chat(&cold_prompt, 1, &args).unwrap().remove(0);
        assert_eq!(w.tokens, c.tokens, "turn {turn} tokens diverged");
        assert_eq!(w.prompt_len, c.prompt_len, "turn {turn}");
        for (lw, lc) in w.logprobs.iter().zip(&c.logprobs) {
            assert!((lw - lc).abs() < 1e-4, "turn {turn} logprobs diverged: {lw} vs {lc}");
        }
        assert_eq!(w.loss_mask, c.loss_mask, "turn {turn}");
        warm_prompt = w.tokens.clone();
        warm_prompt.extend(&obs[turn]);
        cold_prompt = c.tokens.clone();
        cold_prompt.extend(&obs[turn]);
    }

    let cache = warm.snapshot().cache.expect("cache enabled");
    assert!(cache.resumed >= 1, "turn 2+ must resume a parked session: {cache:?}");
    assert!(cache.saved_prefill_tokens > 0, "{cache:?}");
    assert!(cache.parked >= 1, "{cache:?}");
    assert!(cache.hits >= 1, "{cache:?}");
    assert!(cold.snapshot().cache.is_none());
}
