//! Control-plane integration: the ISSUE-7 acceptance surface.
//!
//! * long-tail benchmark — a discrete-event co-simulation of trainer and
//!   gated explorer under a long-tail rollout workload (every 16th
//!   rollout is 12x slower) drives the *real* policy admission formulas
//!   and the *real* `StalenessCore`: `adaptive` must admit at least as
//!   many batches as the best static `BoundedStaleness` setting, finish
//!   no later, and hold the trainer's sample-wait p95 inside the
//!   `staleness_hi` band — which every narrower static setting violates;
//! * equivalence — an uncontrolled `AdaptiveStaleness` is decision-
//!   identical to `BoundedStaleness` over a sweep of (interval, lag,
//!   batch, progress) points;
//! * disabled — a session run with `[control]` absent builds no plane,
//!   reports no control snapshot, and exports zero control spans.
//!
//! The simulation uses only exact binary fractions (0.5 / 1.0 / 6.0) so
//! every quantity below is bit-exact, not tolerance-compared.

use trinity_rft::control::{AdaptiveStaleness, Controller, StalenessCore};
use trinity_rft::coordinator::{BoundedStaleness, Progress, RftConfig, RftSession, SyncPolicy};
use trinity_rft::obs::Gauges;
use trinity_rft::runtime::Manifest;

/// Nearest-rank p95 over raw samples (the sim's stand-in for the run's
/// cumulative histograms).
fn p95(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((0.95 * s.len() as f64).ceil() as usize).max(1) - 1;
    s[idx]
}

struct SimOut {
    /// Batches the gate admitted over the trainer's run.
    admitted: u64,
    /// Per-step seconds the trainer blocked waiting for its batch.
    waits: Vec<f64>,
    rollout_p95: f64,
    /// Simulated time at which the trainer finished.
    elapsed: f64,
}

/// Discrete-event co-simulation: one explorer producing batches through
/// `policy.admit` (interval 1), one trainer consuming a batch per 1.0s
/// step and publishing after each.  Batch `k` takes 6.0s every 16th
/// rollout (long tail), 0.5s otherwise — mean 0.84s, so the run is
/// trainer-bound *except* when a tail rollout lands with too little
/// admitted runway.  `on_publish` sees the cumulative wait/rollout
/// samples at each publish boundary, exactly where the real scheduler
/// publishes gauges.
fn simulate(
    policy: &dyn SyncPolicy,
    steps: u64,
    mut on_publish: impl FnMut(&[f64], &[f64], f64),
) -> SimOut {
    let lat = |k: u64| if k % 16 == 0 { 6.0 } else { 0.5 };
    let (mut e_free, mut gate_time, mut t_free) = (0.0f64, 0.0f64, 0.0f64);
    let mut batch_done: Vec<f64> = Vec::new();
    let mut rollouts: Vec<f64> = Vec::new();
    let mut waits: Vec<f64> = Vec::new();
    let (mut published, mut k, mut s) = (0u64, 0u64, 0u64);
    while s < steps {
        let progress = Progress { published_windows: published, ..Default::default() };
        if policy.admit(k, progress) {
            // explorer: runs whenever the gate is open; a batch blocked
            // on the gate starts at the publish that opened it
            let start = e_free.max(gate_time);
            let l = lat(k);
            e_free = start + l;
            batch_done.push(e_free);
            rollouts.push(l);
            k += 1;
        } else {
            // explorer gate-blocked: the trainer takes its next step
            let ready = batch_done[s as usize];
            waits.push((ready - t_free).max(0.0));
            t_free = t_free.max(ready) + 1.0;
            published += 1;
            gate_time = t_free;
            s += 1;
            on_publish(&waits, &rollouts, t_free);
        }
    }
    SimOut { admitted: k, waits, rollout_p95: p95(&rollouts), elapsed: t_free }
}

fn adaptive_cfg(max_lag: u64) -> RftConfig {
    let mut cfg = RftConfig::default();
    cfg.sync_interval = 1;
    cfg.scheduler.max_version_lag = max_lag;
    cfg.control.staleness_hi = 0.5;
    // narrowing off: the benchmark probes how fast starvation evidence
    // *earns* staleness, not the comfort give-back
    cfg.control.staleness_lo = 0.0;
    cfg.control.staleness_floor_s = 0.005;
    cfg.control.hold_ticks = 2;
    cfg
}

#[test]
fn adaptive_matches_best_static_staleness_and_holds_the_wait_band() {
    const STEPS: u64 = 96;
    let tail_of = |waits: &[f64]| p95(&waits[(STEPS / 2) as usize..]);

    // static sweep: BoundedStaleness at every lag up to the ceiling
    let mut statics = Vec::new();
    for lag in [0u64, 1, 2, 4] {
        let p = BoundedStaleness { interval: 1, max_version_lag: lag };
        let out = simulate(&p, STEPS, |_, _, _| {});
        assert_eq!(out.rollout_p95, 6.0, "long tail dominates the rollout p95");
        statics.push((lag, out));
    }

    // adaptive: slow-starts at lag 1, earns the rest from starvation
    let p = AdaptiveStaleness::from_cfg(&adaptive_cfg(4));
    p.core().enable();
    let core = std::sync::Arc::clone(p.core());
    let mut decisions: Vec<(f64, f64)> = Vec::new();
    let out = simulate(&p, STEPS, |waits, rollouts, at_s| {
        let g = Gauges {
            sample_wait_p95_s: p95(waits),
            rollout_p95_s: p95(rollouts),
            at_s,
            ..Default::default()
        };
        if let Some(d) = core.step(&g) {
            decisions.push((d.from, d.to));
        }
    });
    assert_eq!(out.rollout_p95, 6.0);
    let band = 0.5 * out.rollout_p95; // staleness_hi x rollout p95

    // band: after the transient, the trainer's wait p95 sits inside it
    assert!(
        tail_of(&out.waits) <= band,
        "adaptive tail wait p95 {} above band {band}",
        tail_of(&out.waits)
    );
    // throughput: >= every static setting on admitted batches, and the
    // trainer finishes no later — so rollout throughput (admitted over
    // elapsed) is >= the best static's
    for (lag, st) in &statics {
        assert!(
            out.admitted >= st.admitted && out.elapsed <= st.elapsed,
            "adaptive ({} batches in {}s) worse than static lag {lag} ({} in {}s)",
            out.admitted,
            out.elapsed,
            st.admitted,
            st.elapsed
        );
        if *lag < 4 {
            assert!(out.admitted > st.admitted, "must beat every narrower static");
            // ...and every narrower static violates the band: the
            // static knob cannot have both throughput and the band
            assert!(tail_of(&st.waits) > band, "static lag {lag} unexpectedly in band");
        }
    }
    assert_eq!(out.admitted, STEPS + 4, "ends at the full runway of the earned window");

    // the window was earned through the AIMD widen path, one at a time
    assert_eq!(decisions, vec![(1.0, 2.0), (2.0, 3.0), (3.0, 4.0)]);
    assert_eq!(core.lag(), 4, "converged to the ceiling with narrowing off");
}

#[test]
fn uncontrolled_adaptive_is_decision_identical_to_bounded_staleness() {
    for interval in [1u64, 2, 3] {
        for max_lag in [0u64, 1, 3] {
            let mut cfg = RftConfig::default();
            cfg.sync_interval = interval;
            cfg.scheduler.max_version_lag = max_lag;
            let adaptive = AdaptiveStaleness::from_cfg(&cfg); // no enable(): pinned
            let fixed = BoundedStaleness { interval, max_version_lag: max_lag };
            assert_eq!(adaptive.explorer_plan(9), fixed.explorer_plan(9));
            assert_eq!(adaptive.multi_explorer(), fixed.multi_explorer());
            for batch in 0..60u64 {
                for published in 0..20u64 {
                    let pr = Progress { published_windows: published, ..Default::default() };
                    assert_eq!(
                        adaptive.admit(batch, pr),
                        fixed.admit(batch, pr),
                        "admit diverged at i={interval} lag={max_lag} b={batch} w={published}"
                    );
                }
                for version in 0..10u64 {
                    assert_eq!(
                        adaptive.version_lag(batch, version),
                        fixed.version_lag(batch, version)
                    );
                }
            }
            for steps in 1..=12u64 {
                assert_eq!(adaptive.publish_after(steps), fixed.publish_after(steps));
            }
        }
    }
}

#[test]
fn stale_gauges_hold_the_last_output() {
    // a core stepped on starved gauges widens; the plane-level stale
    // gate is exercised in control::tests — here the core itself must
    // be pure (same inputs, same outputs) so holds are sound
    let cfg = adaptive_cfg(4);
    let core = StalenessCore::new(4, &cfg.control.to_control_config());
    core.enable();
    let starved =
        Gauges { sample_wait_p95_s: 4.0, rollout_p95_s: 6.0, at_s: 1.0, ..Default::default() };
    assert!(core.step(&starved).is_none(), "hold_ticks=2: first sample held");
    assert!(core.step(&starved).is_some());
    let lag = core.lag();
    // no new gauge sample -> no step -> output holds by construction
    assert_eq!(core.lag(), lag);
}

fn artifact_cfg() -> Option<RftConfig> {
    Manifest::load_default()?;
    let mut cfg = RftConfig::default();
    cfg.model_preset = "tiny".into();
    cfg.mode = "both".into();
    cfg.total_steps = 2;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.seed = 31;
    Some(cfg)
}

#[test]
fn disabled_control_reports_no_plane_and_exports_no_control_spans() {
    let Some(mut cfg) = artifact_cfg() else { return };
    let dir = std::env::temp_dir().join(format!("trft_ctl_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    cfg.observability.enabled = true;
    cfg.observability.trace_path = Some(dir.join("trace.json").to_string_lossy().into_owned());
    assert!(!cfg.control.enabled, "[control] must default off");

    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
    assert!(report.control.is_none(), "no [control] -> no plane, no snapshot");
    let trace = std::fs::read_to_string(report.trace_path.expect("trace exported")).unwrap();
    assert!(
        !trace.contains("control_decision"),
        "disabled control must emit zero control spans"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn adaptive_session_with_control_enabled_reports_a_snapshot() {
    let Some(mut cfg) = artifact_cfg() else { return };
    cfg.scheduler.policy = Some("adaptive".into());
    cfg.scheduler.max_version_lag = 2;
    cfg.control.enabled = true;

    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
    assert!(report.mode.contains("adaptive"), "policy label: {}", report.mode);
    let ctl = report.control.expect("[control] enabled -> snapshot on the report");
    assert!(ctl.admission_open, "nothing pressures a 2-step tiny-scale run");
    assert!(ctl.batch_tasks >= 1);
    let lag = ctl.staleness_lag.expect("adaptive core adopted by the plane");
    assert!(lag <= session.cfg.scheduler.max_version_lag, "lag clamped to the ceiling");
}
