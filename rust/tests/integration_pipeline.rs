//! Integration: data pipelines composed with the real engine — diversity
//! rewards through the embed artifact, curriculum task ordering feeding a
//! session, human-in-the-loop -> DPO train-only.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::buffer::ExperienceBuffer;
use trinity_rft::coordinator::{PrioritizedTaskSource, RftConfig, RftSession, TaskSource};
use trinity_rft::data::formatter::Formatter;
use trinity_rft::data::human::{
    results_to_preference_pairs, AnnotationItem, AnnotationService, AnnotatorConfig,
};
use trinity_rft::data::{DiversityRewardProcessor, ExperienceProcessor, TaskPipeline};
use trinity_rft::envs::math::MathTaskGen;
use trinity_rft::explorer::Task;
use trinity_rft::runtime::Manifest;

fn base_cfg() -> Option<RftConfig> {
    Manifest::load_default()?;
    let mut cfg = RftConfig::default();
    cfg.model_preset = "tiny".into();
    cfg.total_steps = 2;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.seed = 23;
    Some(cfg)
}

#[test]
fn diversity_reward_through_embed_artifact() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    // the diversity processor embeds through a direct engine handle,
    // so opt out of the (default-on) rollout service
    cfg.service.enabled = false;
    // build the session first to get the generation engine for embeddings
    let mut session = RftSession::build(cfg.clone(), None, None).unwrap();
    let gen = Arc::clone(session.explorers[0].engine());
    let processor: Arc<dyn ExperienceProcessor> =
        Arc::new(DiversityRewardProcessor::new(gen, 0.5, 0.3, 10));
    // interpose manually on the session's buffer
    let shaped = trinity_rft::data::ShapingBuffer::new(Arc::clone(&session.buffer), processor);
    // run a rollout through the explorer and shape it
    let tasks = session.task_source.next_batch(1);
    let outs = {
        session.explorers[0].explore_batch(tasks).unwrap();
        session.buffer.read(4, Duration::from_secs(5)).unwrap()
    };
    assert_eq!(outs.len(), 4);
    shaped.write(outs).unwrap();
    let shaped_out = session.buffer.read(4, Duration::from_secs(5)).unwrap();
    for e in &shaped_out {
        let d = e.meta_f64("diversity").unwrap();
        assert!((0.0..=2.0).contains(&d), "diversity {d} out of range");
        assert_eq!(e.meta_f64("diversity_weight"), Some(0.5));
    }
    // rollouts within one group should not all have identical diversity
    // unless they are token-identical
    let unique_tokens: std::collections::HashSet<Vec<i32>> =
        shaped_out.iter().map(|e| e.tokens.clone()).collect();
    if unique_tokens.len() > 1 {
        let divs: Vec<f64> = shaped_out.iter().map(|e| e.meta_f64("diversity").unwrap()).collect();
        let spread = divs.iter().cloned().fold(f64::MIN, f64::max)
            - divs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread >= 0.0);
    }
}

#[test]
fn curriculum_source_drives_session() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.total_steps = 2;
    // curate: generate mixed difficulties, order easy->hard
    let mut gen = MathTaskGen::new(5, "curr");
    let raw: Vec<Task> = gen
        .gen_batch(12, 1, 8)
        .into_iter()
        .map(|mt| {
            let mut t = Task::new(&mt.id, "math", mt.to_payload());
            t.difficulty = mt.difficulty as f64;
            t.repeat_times = 4;
            t
        })
        .collect();
    let curated = TaskPipeline::easy_to_hard().run(raw).unwrap();
    assert!(curated.windows(2).all(|w| w[0].difficulty <= w[1].difficulty));
    let eval = curated[..4].to_vec();
    let source: Arc<dyn TaskSource> = Arc::new(PrioritizedTaskSource::new(curated, eval));
    let mut session = RftSession::build(cfg, Some(source), None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
}

#[test]
fn human_annotation_to_dpo_training() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "train".into();
    cfg.algorithm = "dpo".into();
    cfg.dpo.beta = 0.5;
    cfg.total_steps = 1;
    let mut session = RftSession::build(cfg, None, None).unwrap();

    // 1. simulated annotators produce preferences
    let items: Vec<AnnotationItem> = (0..2)
        .map(|i| AnnotationItem {
            prompt: format!("what is 2 + {i} ?"),
            answer_a: (2 + i as i64).to_string(),
            answer_b: "0".to_string(),
            gold_answer: 2 + i as i64,
        })
        .collect();
    let svc = AnnotationService::new(
        AnnotatorConfig { mean_latency: Duration::from_millis(1), ..Default::default() },
        2,
        7,
    );
    let id = svc.post_batch(items.clone());
    let results = svc.wait_for_batch(id, Duration::from_secs(5)).unwrap();
    assert_eq!(results.len(), 2);

    // 2. results -> DPO pairs -> buffer (tiny dpo artifact trains 2 pairs
    //    = 4 experiences per step)
    let formatter = Formatter { spec: Default::default(), tokenizer: Arc::clone(&session.tokenizer) };
    let pairs = results_to_preference_pairs(&items, &results, &formatter).unwrap();
    assert_eq!(pairs.len(), 4);
    session.buffer.write(pairs).unwrap();

    // 3. train-only DPO step consumes them
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 1);
    let margin = report.trainer_metrics[0].get("margin").unwrap();
    assert!(margin.is_finite());
}
