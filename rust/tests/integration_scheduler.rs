//! Scheduler-level integration: mode equivalence under the unified
//! engine.  Windowed{1,0} enforces the strict on-policy ping-pong,
//! Offline matches the seed's train-only behavior on a pre-filled
//! buffer, BoundedStaleness caps explorer weight-version lag, and async
//! runs now record weight-sync spans and trainer compute_s (the seed
//! `run_async` dropped both).  Requires `make artifacts` (skips
//! gracefully otherwise).

use std::sync::Arc;

use trinity_rft::coordinator::{RftConfig, RftSession, SyncPolicy, Windowed};
use trinity_rft::runtime::Manifest;

fn base_cfg() -> Option<RftConfig> {
    Manifest::load_default()?;
    let mut cfg = RftConfig::default();
    cfg.model_preset = "tiny".into();
    cfg.total_steps = 3;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4; // matches tiny grpo batch of 4
    cfg.max_new_tokens = 6;
    cfg.hyper.lr = 1e-4;
    cfg.explorer_threads = 2;
    cfg.seed = 31;
    Some(cfg)
}

#[test]
fn windowed_ping_pong_never_starts_batch_before_its_window() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.sync_interval = 1;
    cfg.sync_offset = 0;
    cfg.total_steps = 4;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.explore_batches, 4);
    assert_eq!(report.sync_count, 4);
    // strict on-policy: rollout batch e starts only after weight window
    // e is published (weight_sync indices are 1-based publish counts)
    for rollout in report.timeline.iter().filter(|e| e.kind == "rollout") {
        if rollout.index == 0 {
            continue; // first batch needs no window
        }
        let window = report
            .timeline
            .iter()
            .find(|e| e.kind == "weight_sync" && e.index == rollout.index)
            .unwrap_or_else(|| panic!("no weight_sync #{}", rollout.index));
        assert!(
            window.end_s <= rollout.start_s,
            "batch {} started at {:.6}s before window {} published at {:.6}s",
            rollout.index,
            rollout.start_s,
            rollout.index,
            window.end_s
        );
    }
    // ping-pong weights are never stale
    assert_eq!(report.max_version_lag, 0);
}

#[test]
fn offline_policy_matches_seed_train_only_on_prefilled_buffer() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "train".into();
    cfg.algorithm = "sft".into();
    cfg.total_steps = 2;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let formatter = trinity_rft::data::formatter::Formatter {
        spec: Default::default(),
        tokenizer: Arc::clone(&session.tokenizer),
    };
    let mut exps = vec![];
    for i in 0..8 {
        let raw = trinity_rft::util::json::Value::obj(vec![
            ("question", trinity_rft::util::json::Value::str(format!("what is {i} + 2 ?"))),
            ("answer", trinity_rft::util::json::Value::str((i + 2).to_string())),
        ]);
        exps.push(formatter.to_expert_experience(&raw).unwrap());
    }
    session.buffer.write(exps).unwrap();
    let report = session.run().unwrap();
    // seed `train` mode shape: steps consumed, no explorers, no syncs
    assert_eq!(report.mode, "train");
    assert_eq!(report.train_steps, 2);
    assert_eq!(report.explore_batches, 0);
    assert_eq!(report.sync_count, 0);
    assert!(report.timeline.iter().all(|e| e.role == "trainer"));
    assert_eq!(report.trainer_metrics.len(), 2);
}

#[test]
fn bounded_staleness_caps_explorer_version_lag() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "async".into();
    cfg.scheduler.policy = Some("bounded_staleness".into());
    cfg.scheduler.max_version_lag = 1;
    cfg.sync_interval = 1;
    cfg.total_steps = 4;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    assert!(report.mode.starts_with("staleness"), "{}", report.mode);
    assert_eq!(report.train_steps, 4);
    assert!(report.explore_batches >= 1);
    assert!(
        report.max_version_lag <= 1,
        "version lag {} exceeded max_version_lag=1",
        report.max_version_lag
    );
}

#[test]
fn async_runs_record_weight_sync_spans_and_compute_s() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "async".into();
    cfg.sync_interval = 2;
    cfg.total_steps = 4;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let report = session.run().unwrap();
    // the seed's run_async recorded neither of these
    assert_eq!(
        report.timeline.iter().filter(|e| e.kind == "weight_sync").count() as u64,
        report.sync_count
    );
    assert_eq!(report.sync_count, 2);
    assert_eq!(session.monitor.series("trainer/compute_s").len(), 4);
    // and rollouts log their off-policyness
    assert!(!session.monitor.series("explorer-0/version_lag").is_empty());
}

#[test]
fn explicit_policy_object_bypasses_config_resolution() {
    let Some(mut cfg) = base_cfg() else { return };
    cfg.mode = "both".into();
    cfg.total_steps = 4;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    let policy: Arc<dyn SyncPolicy> = Arc::new(Windowed { interval: 2, offset: 0 });
    let report = session.run_policy(policy).unwrap();
    assert_eq!(report.mode, "both(i=2,o=0)");
    assert_eq!(report.sync_count, 2);
    assert_eq!(report.explore_batches, 4);
}
