//! Rollout-service integration: the ISSUE-4 acceptance surface.
//!
//! * microbatching — requests from >= 8 concurrent workflow runners
//!   coalesce into shared engine sessions (mean occupancy > 1, fewer
//!   engine calls than rows),
//! * robustness — deadline expiry, retry-then-succeed, circuit-breaker
//!   quarantine draining traffic to healthy replicas and probing back,
//! * scheduler wiring — a service-backed `RftSession` end to end
//!   (artifact-gated; skips without `make artifacts`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use trinity_rft::buffer::Experience;
use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::exec::ThreadPool;
use trinity_rft::explorer::{
    AlfworldWorkflow, MockModel, RolloutEndpoint, RolloutModel, RunnerConfig, SamplingArgs, Task,
    Workflow, WorkflowCtx, WorkflowRegistry, WorkflowRunner,
};
use trinity_rft::model::{MemorySync, WeightSync};
use trinity_rft::runtime::Manifest;
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::{Tokenizer, EOS};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

fn math_tasks(n: usize, repeat: usize) -> Vec<Task> {
    (0..n)
        .map(|i| {
            let mut t = Task::new(
                &format!("t{i}"),
                "math",
                Value::obj(vec![
                    ("question", Value::str(format!("what is {i} + 4 ?"))),
                    ("answer", Value::str((i + 4).to_string())),
                ]),
            );
            t.repeat_times = repeat;
            t
        })
        .collect()
}

fn service_over(models: Vec<MockModel>, cfg: ServiceConfig) -> Arc<RolloutService> {
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        models.into_iter().map(|m| Arc::new(m) as Arc<dyn RolloutEndpoint>).collect();
    Arc::new(RolloutService::over_models(endpoints, cfg).unwrap())
}

/// A mock whose response is a pure function of the prompt, so identical
/// call sequences are byte-identical regardless of the serving path.
fn deterministic_mock(seed: u64) -> MockModel {
    let tok = Tokenizer::new();
    let look = tok.encode("look");
    MockModel::new(seed, Duration::ZERO, 0.0).with_response(move |_prompt, _rng| {
        let mut r = look.clone();
        r.push(EOS);
        r
    })
}

/// Multi-turn episodes against any model handle, single-file, so the
/// request order is deterministic across serving paths.
fn episodes_via(model: &dyn RolloutModel, seed: i64, repeat: usize) -> Vec<Experience> {
    let tok = Tokenizer::new();
    let mut task = Task::new("eq-ep", "alfworld", Value::obj(vec![("seed", Value::int(seed))]));
    task.repeat_times = repeat;
    let sampling = SamplingArgs { max_new_tokens: 8, ..Default::default() };
    let mut ctx = WorkflowCtx { model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(7) };
    let wf =
        AlfworldWorkflow { max_env_steps: 3, env_init_cost: Duration::ZERO, max_seq_tokens: 200 };
    wf.run(&mut ctx).unwrap()
}

#[test]
fn single_replica_service_is_byte_identical_to_direct_handles() {
    // `service.enabled` now defaults on, folding the direct-handle
    // wiring into the single-replica service — which must therefore be
    // a pure routing layer: same model, same episodes, same bytes
    let direct = deterministic_mock(21);
    let direct_exps = episodes_via(&direct, 13, 2);

    let svc = service_over(vec![deterministic_mock(21)], ServiceConfig::default());
    let svc_exps = episodes_via(svc.as_ref(), 13, 2);

    assert_eq!(direct_exps.len(), svc_exps.len());
    assert!(!direct_exps.is_empty());
    for (x, y) in direct_exps.iter().zip(&svc_exps) {
        assert_eq!(x.tokens, y.tokens, "token streams diverged");
        assert_eq!(x.logprobs, y.logprobs, "logprobs diverged");
        assert_eq!(x.loss_mask, y.loss_mask, "loss masks diverged");
        assert_eq!(x.prompt_len, y.prompt_len);
        assert_eq!(x.reward, y.reward);
    }
}

#[test]
fn microbatcher_coalesces_requests_from_concurrent_runners() {
    // 8 runner threads x 8 tasks x 2 rollouts = 16 row requests arriving
    // together; the admission window must fuse them into shared sessions
    let mut cfg = ServiceConfig::default();
    cfg.max_batch = 16;
    cfg.admission_window = Duration::from_millis(25);
    let svc = service_over(vec![MockModel::new(1, Duration::from_millis(5), 0.0)], cfg);

    let pool = Arc::new(ThreadPool::new("svc-runners", 8));
    let runner = WorkflowRunner::new(
        pool,
        RunnerConfig {
            timeout: Duration::from_secs(10),
            max_attempts: 1,
            retry_delay: Duration::ZERO,
            seed: 3,
        },
    );
    let (exps, stats) = runner.run_collect(
        math_tasks(8, 2),
        Arc::new(WorkflowRegistry::with_builtins()),
        Arc::clone(&svc) as Arc<dyn RolloutModel>,
        Arc::new(Tokenizer::new()),
        SamplingArgs::default(),
    );
    assert_eq!(stats.completed, 8, "{stats:?}");
    assert_eq!(exps.len(), 16);

    let snap = svc.snapshot();
    assert_eq!(snap.completed, 16);
    assert!(
        snap.occupancy() > 1.0,
        "requests never shared a session: occupancy {:.2} over {} sessions",
        snap.occupancy(),
        snap.sessions
    );
    assert!(
        snap.sessions < 16,
        "expected fewer engine sessions than the 16 rows, got {}",
        snap.sessions
    );
    // coalescing across DIFFERENT tasks implies fewer sessions than tasks
    assert!(snap.sessions < 8, "expected < 8 sessions for 8 tasks, got {}", snap.sessions);
}

#[test]
fn deadline_expiry_fails_queued_requests_without_stalling_served_ones() {
    let mut cfg = ServiceConfig::default();
    cfg.max_batch = 1; // no coalescing: the second request must queue
    cfg.admission_window = Duration::ZERO;
    cfg.request_timeout = Duration::from_millis(15);
    cfg.max_attempts = 1;
    let svc = service_over(vec![MockModel::new(2, Duration::from_millis(60), 0.0)], cfg);

    let first = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || svc.chat(&[1, 2], 1, &SamplingArgs::default()))
    };
    // let the worker claim the first request, then queue a second that
    // can only be popped after its deadline
    std::thread::sleep(Duration::from_millis(10));
    let second = svc.chat(&[1, 3], 1, &SamplingArgs::default());

    assert!(first.join().unwrap().is_ok(), "in-flight request must not be expired");
    let err = second.unwrap_err();
    let chain = format!("{err:#}"); // full context chain
    assert!(chain.contains("deadline exceeded"), "unexpected error: {chain}");
    let snap = svc.snapshot();
    assert_eq!(snap.expired, 1, "{snap:?}");
    assert_eq!(snap.completed, 1);
}

#[test]
fn transient_failures_retry_until_success() {
    let mut cfg = ServiceConfig::default();
    cfg.max_attempts = 20;
    cfg.retry_backoff = Duration::from_millis(1);
    cfg.breaker_failures = 10_000; // keep the breaker out of this test
    let svc = service_over(vec![MockModel::new(4, Duration::ZERO, 0.5)], cfg);
    for i in 0..6 {
        let outs = svc.chat(&[1, 10 + i], 2, &SamplingArgs::default()).unwrap();
        assert_eq!(outs.len(), 2);
    }
    let snap = svc.snapshot();
    assert_eq!(snap.completed, 12);
    assert_eq!(snap.failed, 0);
    assert!(snap.retried > 0, "fail_rate=0.5 must have triggered retries: {snap:?}");
}

#[test]
fn quarantined_replica_drains_to_healthy_peer_and_probes_back() {
    let broken = Arc::new(MockModel::new(5, Duration::ZERO, 1.0));
    let healthy = Arc::new(MockModel::new(6, Duration::from_millis(1), 0.0));
    let mut cfg = ServiceConfig::default();
    cfg.breaker_failures = 2;
    cfg.quarantine = Duration::from_millis(40);
    cfg.max_attempts = 6;
    cfg.retry_backoff = Duration::from_millis(1);
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> = vec![
        Arc::clone(&broken) as Arc<dyn RolloutEndpoint>,
        Arc::clone(&healthy) as Arc<dyn RolloutEndpoint>,
    ];
    let svc = Arc::new(RolloutService::over_models(endpoints, cfg).unwrap());

    // phase 1: replica 0 fails everything -> quarantine opens, its
    // traffic drains to replica 1, and no task-level request is lost
    for i in 0..10 {
        let outs = svc.chat(&[1, 20 + i], 2, &SamplingArgs::default()).unwrap();
        assert_eq!(outs.len(), 2, "in-flight work must survive the quarantine");
    }
    let snap = svc.snapshot();
    assert_eq!(snap.completed, 20);
    assert_eq!(snap.failed, 0, "{snap:?}");
    assert!(snap.replicas[0].quarantines >= 1, "breaker never opened: {snap:?}");
    assert!(
        snap.replicas[1].rows >= 18,
        "healthy replica should have absorbed the traffic: {snap:?}"
    );
    assert_eq!(snap.replicas[0].rows, 0);

    // phase 2: heal replica 0; the health probe must close the breaker
    // and traffic must flow to it again
    broken.set_fail_rate(0.0);
    let recovered_by = Instant::now() + Duration::from_secs(5);
    loop {
        let snap = svc.snapshot();
        if !snap.replicas[0].quarantined {
            break;
        }
        assert!(Instant::now() < recovered_by, "replica never recovered: {snap:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    for i in 0..10 {
        svc.chat(&[1, 40 + i], 2, &SamplingArgs::default()).unwrap();
    }
    let snap = svc.snapshot();
    assert!(snap.probes >= 1, "{snap:?}");
    assert!(
        snap.replicas[0].rows > 0,
        "recovered replica should serve traffic again: {snap:?}"
    );
}

#[test]
fn rolling_weight_sync_and_min_version_accounting() {
    let a = MockModel::new(7, Duration::ZERO, 0.0);
    let b = MockModel::new(8, Duration::ZERO, 0.0);
    let svc = service_over(vec![a, b], ServiceConfig::default());
    let sync = MemorySync::new();
    assert_eq!(svc.weight_version(), 0);
    sync.publish(3, 30, trinity_rft::model::WeightSnapshot::of(vec![vec![1.0]])).unwrap();
    assert!(svc.sync_weights(&sync).unwrap());
    assert_eq!(svc.weight_version(), 3);
    let snap = svc.snapshot();
    assert!(snap.replicas.iter().all(|r| r.weight_version == 3), "{snap:?}");
}

// ---------------------------------------------------------------------------
// artifact-gated: the full scheduler wiring over real engines

#[test]
fn service_backed_session_runs_end_to_end() {
    if Manifest::load_default().is_none() {
        return; // no artifacts in this environment
    }
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.model_preset = "tiny".into();
    cfg.total_steps = 2;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.explorer_threads = 2;
    cfg.seed = 17;
    cfg.service.enabled = true;
    cfg.service.replicas = 2;
    cfg.service.admission_window_ms = 5;
    let mut session = RftSession::build(cfg, None, None).unwrap();
    assert!(session.service.is_some());
    let report = session.run().unwrap();
    assert_eq!(report.train_steps, 2);
    assert!(report.explore_batches >= 1);
    let snap = report.service.expect("service snapshot attached to the report");
    assert!(snap.completed > 0, "{snap:?}");
    assert_eq!(snap.replicas.len(), 2);
    assert!(snap.occupancy() >= 1.0);
    // telemetry reached the monitor under the service role
    assert!(!session.monitor.series("service/occupancy").is_empty());
}
