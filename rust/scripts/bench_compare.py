#!/usr/bin/env python3
"""Compare a fresh micro-bench merge against the checked-in baseline.

Usage:
  bench_compare.py BASELINE.json CURRENT.json
  bench_compare.py --emit-baseline ARTIFACT.json [OUT.json]

Both files are the merged documents the bench-baseline CI job assembles:
{"commit", "scale", "benches": {<bench file>: [rows...]}} where each row
carries a "bench" case label plus numeric metrics.  Rows are matched by
(bench file, case label, ordinal), so reordering cases within a label is
a baseline refresh, not a silent mismatch.

Report-only by design for *numbers*: drifts beyond the soft threshold
print GitHub warning annotations but never fail the build — the numbers
come from shared CI runners, so a hard gate would flake.  *Malformed
input* is different: an unreadable or non-JSON file exits 2 loudly,
because silently comparing garbage would make every future drift
invisible.  When `$GITHUB_STEP_SUMMARY` is set the comparison table
(including the baseline's provenance note) is appended to the job
summary.  Refresh the baseline with `--emit-baseline`: it takes the
BENCH_baseline artifact of a trusted run and writes a ready-to-commit
rust/BENCH_baseline.json (normalized key order, comparable metrics
only).
"""

import json
import os
import sys
from collections import defaultdict

# metric direction: a drop in these is a regression...
HIGHER_IS_BETTER = {
    "tasks_per_s",
    "occupancy",
    "hit_rate",
    "prefill_reduction",
    "prefill_reduction_total",
    "reused",
    "completed",
    "rows_per_s",
    "saved_prefill_tokens",
    "episodes_per_s",
}
# ...while growth in these is (train_wait_ms stays non-directional:
# DRR deliberately trades train waits for interactive waits)
LOWER_IS_BETTER = {
    "wall_s",
    "mb_copied",
    "interactive_wait_ms",
    "interactive_wait_p95_ms",
    "turn2_wall_ms",
    "ms_per_dump",
    "ns_per_assess",
}
SOFT_THRESHOLD = 0.25  # fraction of the baseline value


def load(path):
    """Parse a merged bench document, exiting 2 on unreadable/bad input."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::error title=bench compare::cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def write_step_summary(lines):
    """Append markdown to the GitHub job summary, when one is available."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    try:
        with open(path, "a") as f:
            f.write("\n".join(lines) + "\n")
    except OSError as e:
        print(f"step summary unavailable: {e}", file=sys.stderr)


def cases(doc):
    """(bench file, case label, ordinal) -> row."""
    out = {}
    for name, rows in sorted(doc.get("benches", {}).items()):
        seen = defaultdict(int)
        for row in rows:
            label = row.get("bench", "?")
            out[(name, label, seen[label])] = row
            seen[label] += 1
    return out


def emit_baseline(artifact_path, out_path):
    """Normalize a CI BENCH_baseline artifact into a committable baseline.

    Keeps the case labels plus the directional metrics the comparator
    reads (and any non-numeric discriminator fields, which document what
    the row measured); drops everything else so baseline diffs stay
    reviewable.
    """
    doc = load(artifact_path)
    benches = {}
    for name, rows in sorted(doc.get("benches", {}).items()):
        kept = []
        for row in rows:
            slim = {}
            for key, value in row.items():
                directional = key in HIGHER_IS_BETTER or key in LOWER_IS_BETTER
                if key == "bench" or directional or not isinstance(value, (int, float)):
                    slim[key] = value
            kept.append(slim)
        benches[name] = kept
    baseline = {
        "commit": doc.get("commit", "unknown"),
        "scale": doc.get("scale", "1.0"),
        "benches": benches,
    }
    with open(out_path, "w") as f:
        json.dump(baseline, f, indent=2, sort_keys=False)
        f.write("\n")
    n_rows = sum(len(rows) for rows in benches.values())
    print(f"wrote {out_path}: {len(benches)} bench file(s), {n_rows} row(s)")
    return 0


def main():
    if len(sys.argv) >= 3 and sys.argv[1] == "--emit-baseline":
        out = sys.argv[3] if len(sys.argv) > 3 else "BENCH_baseline.json"
        return emit_baseline(sys.argv[2], out)
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    base = load(sys.argv[1])
    cur = load(sys.argv[2])

    summary = ["### Micro-bench comparison", ""]
    if base.get("note"):
        summary += [f"> {base['note']}", ""]

    if base.get("scale") != cur.get("scale"):
        msg = (
            f"baseline scale {base.get('scale')!r} != current {cur.get('scale')!r}; "
            "numbers are not comparable — refresh the baseline"
        )
        print(msg)
        write_step_summary(summary + [msg])
        return 0

    base_cases, cur_cases = cases(base), cases(cur)
    if not set(base_cases) & set(cur_cases):
        msg = (
            "baseline has no comparable cases — seed it by committing the "
            "BENCH_baseline CI artifact as rust/BENCH_baseline.json "
            "(bench_compare.py --emit-baseline <artifact> normalizes it)"
        )
        print(msg)
        write_step_summary(summary + [msg])
        return 0

    drifts = 0
    summary += [
        "| case | metric | baseline | current | delta |",
        "| --- | --- | ---: | ---: | ---: |",
    ]
    for key in sorted(set(base_cases) & set(cur_cases)):
        b_row, c_row = base_cases[key], cur_cases[key]
        for metric in sorted(set(b_row) & set(c_row)):
            if metric not in HIGHER_IS_BETTER and metric not in LOWER_IS_BETTER:
                continue
            b, c = b_row[metric], c_row[metric]
            if not isinstance(b, (int, float)) or not isinstance(c, (int, float)):
                continue
            delta = (c - b) / b if b else (1.0 if c else 0.0)
            worse = -delta if metric in HIGHER_IS_BETTER else delta
            name = "/".join(str(k) for k in key) + f" {metric}"
            print(f"  {name:<48} {b:>10.3f} -> {c:>10.3f}  ({delta:+.1%})")
            mark = " ⚠️" if worse > SOFT_THRESHOLD else ""
            summary.append(
                f"| {'/'.join(str(k) for k in key)} | {metric} "
                f"| {b:.3f} | {c:.3f} | {delta:+.1%}{mark} |"
            )
            if worse > SOFT_THRESHOLD:
                drifts += 1
                print(
                    f"::warning title=bench drift::{name} regressed {worse:.0%} "
                    f"(soft threshold {SOFT_THRESHOLD:.0%}, report-only)"
                )
    for key in sorted(set(base_cases) - set(cur_cases)):
        print(f"  note: baseline case {key} missing from current run")
        summary.append(f"| {'/'.join(str(k) for k in key)} | — | missing from current run | | |")
    tail = f"{drifts} metric(s) beyond the {SOFT_THRESHOLD:.0%} soft threshold"
    print(tail)
    write_step_summary(summary + ["", tail])
    return 0


if __name__ == "__main__":
    sys.exit(main())
