//! Fig. 14: diversity-reward shaping against policy collapse.
//!
//! The diversity processor embeds each rollout through the policy model's
//! pooled-embedding artifact (the GTE stand-in), rewards distance from the
//! group-mean embedding, and decays the weight 0.5 -> 0.3 (the paper's
//! schedule).  Claims to reproduce: accuracy up, response length up, actor
//! entropy consistently higher than the baseline.

use std::sync::Arc;

use trinity_rft::coordinator::modes::sft_warmup_snapshot;
use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::data::{DiversityRewardProcessor, ExperienceProcessor};
use trinity_rft::util::benchkit::{scaled, sparkline, write_json};
use trinity_rft::util::json::Value;
use trinity_rft::util::timeseries::moving_average;

fn base_cfg(steps: u64) -> RftConfig {
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.total_steps = steps;
    cfg.sync_interval = 3;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.min_difficulty = 1;
    cfg.max_difficulty = 1;
    cfg.hyper.lr = 1e-3;
    cfg.adv_std_normalize = true;
    cfg.seed = 29;
    // the diversity processor embeds through a direct engine handle;
    // keep baseline and shaped runs on the same (direct) rollout path
    cfg.service.enabled = false;
    cfg
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(24) as u64;
    println!("Fig. 14 reproduction: diversity-reward shaping, {steps} steps each");

    let warm = sft_warmup_snapshot("tiny", 42, (scaled(20) as u64).max(150))?;
    // baseline
    let mut s1 = RftSession::build(base_cfg(steps), None, None)?;
    s1.load_initial_weights(&warm)?;
    let base = s1.run()?;

    // diversity-shaped: processor needs the explorer's generation engine
    // for embeddings, so build the session first, then interpose
    let mut s2 = RftSession::build(base_cfg(steps), None, None)?;
    let gen = Arc::clone(s2.explorers[0].engine());
    let processor: Arc<dyn ExperienceProcessor> =
        Arc::new(DiversityRewardProcessor::new(gen, 0.5, 0.3, steps));
    // rebuild with the processor wired in (needs the session's engine)
    let mut s2 = {
        drop(s2);
        RftSession::build(base_cfg(steps), None, Some(processor))?
    };
    s2.load_initial_weights(&warm)?;
    let shaped = s2.run()?;

    let base_ent = base.series("entropy");
    let shaped_ent = shaped.series("entropy");
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;

    println!("\nbaseline entropy {}", sparkline(&moving_average(&base_ent, 5)));
    println!("shaped   entropy {}", sparkline(&moving_average(&shaped_ent, 5)));
    println!(
        "\nmean actor entropy: baseline {:.3} vs diversity-shaped {:.3}",
        mean(&base_ent),
        mean(&shaped_ent)
    );
    println!(
        "mean response len:  baseline {:.2} vs diversity-shaped {:.2}",
        mean(&base.response_len_series()),
        mean(&shaped.response_len_series())
    );
    println!(
        "mean shaped reward: baseline {:.3} vs diversity-shaped {:.3}",
        mean(&base.reward_series()),
        mean(&shaped.reward_series())
    );

    let ser = |v: &[f64]| Value::arr(v.iter().map(|x| Value::num(*x)).collect());
    write_json(
        "fig14_diversity_reward",
        &Value::obj(vec![
            ("baseline_entropy", ser(&base_ent)),
            ("shaped_entropy", ser(&shaped_ent)),
            ("baseline_reward", ser(&base.reward_series())),
            ("shaped_reward", ser(&shaped.reward_series())),
        ]),
    );
    println!(
        "\npaper shape check: the diversity-shaped run (red in Fig. 14) keeps\n\
         entropy consistently higher — healthier exploration, no collapse."
    );
    Ok(())
}
