//! Micro-bench: the prefix-reuse cache in isolation (MockModel replicas;
//! no PJRT) — the paper's avoid-recomputation optimization measured at
//! the service boundary:
//!
//! 1. prefill-token reduction vs. turns: multi-turn episodes re-submit
//!    their growing transcript every turn; the prefix index matches the
//!    previous turn's served transcript, so from turn 2 onward most of
//!    the prompt is reused instead of re-prefilled,
//! 2. affinity vs. least-loaded routing: with the cache on, follow-up
//!    turns pin to the replica holding their prefix; with it off, rows
//!    spread wherever load balancing sends them.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::exec::ThreadPool;
use trinity_rft::explorer::{MockModel, RolloutEndpoint, RolloutModel, SamplingArgs};
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::EOS;
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;

fn mock(seed: u64, latency: Duration) -> Arc<MockModel> {
    Arc::new(MockModel::new(seed, latency, 0.0))
}

fn service(models: Vec<Arc<MockModel>>, cfg: ServiceConfig) -> Arc<RolloutService> {
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        models.into_iter().map(|m| m as Arc<dyn RolloutEndpoint>).collect();
    Arc::new(RolloutService::over_models(endpoints, cfg).unwrap())
}

fn turn_args(key: u64) -> SamplingArgs {
    SamplingArgs { session: Some(key), max_new_tokens: 6, ..Default::default() }
}

fn main() -> anyhow::Result<()> {
    let episodes = scaled(16);
    let turns = 6usize;
    let mut rows_json = vec![];

    // -- 1. prefill-token reduction vs turns --------------------------
    let mut cfg = ServiceConfig::default();
    cfg.cache.min_prefix = 2;
    let svc = service(vec![mock(1, Duration::ZERO)], cfg);
    let mut transcripts: Vec<Vec<i32>> = (0..episodes)
        .map(|e| vec![1, 40 + (e % 7) as i32, 50, 60, 70])
        .collect();
    let mut table = Table::new(
        "prefill tokens: submitted vs reused per turn (1 replica)",
        &["turn", "prompt tokens", "reused", "reduction"],
    );
    let mut reduction_from_turn_2 = (0u64, 0u64); // (reused, submitted)
    for turn in 0..turns {
        let before = svc.snapshot().cache.expect("cache on").reused_tokens;
        let mut submitted = 0u64;
        for (e, transcript) in transcripts.iter_mut().enumerate() {
            submitted += transcript.len() as u64;
            let out = svc
                .chat(transcript, 1, &turn_args(1000 + e as u64))?
                .remove(0);
            *transcript = out.tokens;
            // the environment's (masked) observation for the next turn
            transcript.extend([80 + turn as i32, EOS - 1]);
        }
        let reused = svc.snapshot().cache.unwrap().reused_tokens - before;
        if turn >= 1 {
            reduction_from_turn_2.0 += reused;
            reduction_from_turn_2.1 += submitted;
        }
        table.row(vec![
            (turn + 1).to_string(),
            submitted.to_string(),
            reused.to_string(),
            format!("{:.0}%", 100.0 * reused as f64 / submitted.max(1) as f64),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("prefill_reduction")),
            ("turn", Value::num((turn + 1) as f64)),
            ("submitted", Value::num(submitted as f64)),
            ("reused", Value::num(reused as f64)),
        ]));
    }
    table.print();
    let pct = 100.0 * reduction_from_turn_2.0 as f64 / reduction_from_turn_2.1.max(1) as f64;
    println!("prefill-token reduction from turn 2 onward: {pct:.0}% (target >= 50%)");
    rows_json.push(Value::obj(vec![
        ("bench", Value::str("prefill_reduction_total")),
        ("from_turn_2_pct", Value::num(pct)),
    ]));

    // -- 2. affinity vs least-loaded routing --------------------------
    let mut table = Table::new(
        "affinity vs least-loaded (4 replicas, concurrent episodes)",
        &["routing", "hit rate", "fallbacks", "rows per replica"],
    );
    for cache_on in [true, false] {
        let mut cfg = ServiceConfig::default();
        cfg.cache.enabled = cache_on;
        cfg.cache.min_prefix = 2;
        let svc = service(
            (0..4).map(|r| mock(20 + r, Duration::from_millis(1))).collect(),
            cfg,
        );
        let pool = ThreadPool::new("bench-cache", 8);
        let mut promises = vec![];
        for e in 0..episodes {
            let svc = Arc::clone(&svc);
            promises.push(pool.submit(move || {
                let mut transcript: Vec<i32> = vec![1, 30 + (e % 5) as i32, 40, 50, 60];
                for turn in 0..turns {
                    let out = svc
                        .chat(&transcript, 1, &turn_args(2000 + e as u64))
                        .expect("bench chat")
                        .remove(0);
                    transcript = out.tokens;
                    transcript.extend([90 + turn as i32]);
                }
            }));
        }
        for p in promises {
            p.wait().unwrap();
        }
        let snap = svc.snapshot();
        let per: Vec<String> = snap.replicas.iter().map(|r| r.rows.to_string()).collect();
        let (rate, fallbacks) = match &snap.cache {
            Some(c) => (format!("{:.0}%", 100.0 * c.hit_rate()), c.affinity_fallbacks.to_string()),
            None => ("-".into(), "-".into()),
        };
        table.row(vec![
            if cache_on { "affinity" } else { "least-loaded" }.to_string(),
            rate,
            fallbacks,
            per.join("/"),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("routing")),
            ("affinity", Value::Bool(cache_on)),
            (
                "hit_rate",
                Value::num(snap.cache.as_ref().map(|c| c.hit_rate()).unwrap_or(0.0)),
            ),
        ]));
    }
    table.print();

    write_json("micro_cache", &Value::arr(rows_json));
    println!(
        "\nexpectations: reuse is 0 on turn 1 and >= 50% of prompt tokens\n\
         from turn 2 onward (the transcript grows, the prefix is reused);\n\
         with affinity on, follow-up turns report a high hit rate and pin\n\
         to their prefix holder instead of spreading least-loaded."
    );
    Ok(())
}
