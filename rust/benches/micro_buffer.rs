//! Micro-bench: experience buffer throughput (queue vs persistent store
//! vs priority view) under concurrent writers — the substrate numbers
//! behind the modes' pipeline behavior.

use std::sync::Arc;
use std::time::{Duration, Instant};

use trinity_rft::buffer::{
    Experience, ExperienceBuffer, FileStore, PriorityBuffer, QueueBuffer, UtilityWeights,
};
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;

fn exp(i: usize) -> Experience {
    let mut e = Experience::new(&format!("t{i}"), vec![1; 64], 8, (i % 2) as f32);
    e.logprobs = vec![-0.5; 64];
    e
}

fn bench_writes(buffer: &dyn ExperienceBuffer, n: usize) -> f64 {
    let start = Instant::now();
    for i in 0..n {
        buffer.write(vec![exp(i)]).unwrap();
    }
    n as f64 / start.elapsed().as_secs_f64()
}

fn bench_reads(buffer: &dyn ExperienceBuffer, n: usize) -> f64 {
    let start = Instant::now();
    let mut got = 0;
    while got < n {
        got += buffer.read(64.min(n - got), Duration::from_secs(1)).unwrap().len();
    }
    n as f64 / start.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    let n = scaled(20_000);
    let mut table = Table::new(
        "buffer micro-benchmarks",
        &["buffer", "write/s", "read/s", "concurrent write/s"],
    );

    // queue
    let q = QueueBuffer::new(n + 1);
    let wq = bench_writes(&q, n);
    let rq = bench_reads(&q, n);
    let qc = Arc::new(QueueBuffer::new(n + 1));
    let start = Instant::now();
    let handles: Vec<_> = (0..4)
        .map(|w| {
            let q = Arc::clone(&qc);
            std::thread::spawn(move || {
                for i in 0..n / 4 {
                    q.write(vec![exp(w * 1_000_000 + i)]).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wqc = (n / 4 * 4) as f64 / start.elapsed().as_secs_f64();
    table.row(vec![
        "queue (ray.Queue analog)".into(),
        format!("{wq:.0}"),
        format!("{rq:.0}"),
        format!("{wqc:.0}"),
    ]);

    // persistent store
    let path = std::env::temp_dir().join(format!("trft_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let s = FileStore::open(&path)?;
    let ws = bench_writes(&s, n);
    let rs = bench_reads(&s, n);
    table.row(vec![
        "file store (SQLite analog)".into(),
        format!("{ws:.0}"),
        format!("{rs:.0}"),
        "-".into(),
    ]);
    let _ = std::fs::remove_file(&path);

    // priority view
    let p = PriorityBuffer::new(UtilityWeights::default(), 1_000_000);
    let start = Instant::now();
    p.insert((0..n).map(exp).collect());
    let wp = n as f64 / start.elapsed().as_secs_f64();
    let start = Instant::now();
    let mut got = 0;
    while got < n {
        got += p.sample_top(64, 0)?.len();
    }
    let rp = n as f64 / start.elapsed().as_secs_f64();
    table.row(vec![
        "priority view".into(),
        format!("{wp:.0}"),
        format!("{rp:.0}"),
        "-".into(),
    ]);

    table.print();
    write_json("micro_buffer", &table.to_json());
    Ok(())
}
