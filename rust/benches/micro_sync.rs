//! Micro-bench: the zero-copy weight distribution plane in isolation
//! (simulated consumers; no PJRT) — two questions:
//!
//! 1. publish -> all-replicas-current latency vs replica count: the old
//!    path cloned the full weight set once per consumer before applying
//!    it; the shared-snapshot path fetches one `Arc` for the whole pool
//!    and each replica copies each leaf at most once (into its local
//!    store, standing in for the literal rebuild),
//! 2. apply cost vs dirty-leaf fraction: consumers diff per-leaf content
//!    fingerprints against what they last applied and rebuild only the
//!    leaves that changed, so a publish that touches K of N leaves costs
//!    K leaf rebuilds — and an identical republish costs zero.

use std::sync::Arc;
use std::time::Instant;

use trinity_rft::exec::ThreadPool;
use trinity_rft::model::{fingerprint_f32, MemorySync, WeightSnapshot, WeightSync};
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;

const LEAVES: usize = 24;

/// A stand-in weight consumer: local leaf storage (the "device"
/// literals), last-applied fingerprints, and a copied-bytes meter.
struct SimReplica {
    leaves: Vec<Vec<f32>>,
    applied: Vec<u64>,
    version: u64,
    copied_bytes: u64,
}

impl SimReplica {
    fn new(elems: usize) -> SimReplica {
        SimReplica {
            leaves: vec![vec![0.0; elems]; LEAVES],
            applied: vec![0; LEAVES],
            version: 0,
            copied_bytes: 0,
        }
    }

    /// Legacy consumer: materialize a private copy of the full weight
    /// set (the old per-consumer fetch clone), then rebuild every leaf.
    fn apply_cloned(&mut self, snap: &WeightSnapshot, version: u64) {
        let fetched = snap.to_weights();
        self.copied_bytes += 4 * snap.total_elements() as u64;
        for (dst, src) in self.leaves.iter_mut().zip(&fetched) {
            dst.copy_from_slice(src);
        }
        self.copied_bytes += 4 * snap.total_elements() as u64;
        self.version = version;
    }

    /// Zero-copy consumer: borrow the shared snapshot and rebuild only
    /// the leaves whose fingerprints differ from the last apply.
    fn apply_shared(&mut self, snap: &WeightSnapshot, version: u64) -> usize {
        let mut rebuilt = 0;
        for i in 0..snap.leaf_count() {
            if self.applied[i] != snap.fingerprint(i) {
                self.leaves[i].copy_from_slice(snap.leaf(i));
                self.applied[i] = snap.fingerprint(i);
                self.copied_bytes += 4 * snap.leaf(i).len() as u64;
                rebuilt += 1;
            }
        }
        self.version = version;
        rebuilt
    }
}

/// Change the first `frac` of the leaves (one element is enough to
/// change a content fingerprint; copy cost per dirty leaf is the same
/// either way).
fn perturb(weights: &mut [Vec<f32>], round: usize, frac: f64) {
    let dirty = ((LEAVES as f64 * frac).round() as usize).min(LEAVES);
    for leaf in weights.iter_mut().take(dirty) {
        leaf[0] += 1.0 + round as f32 * 0.5;
    }
}

/// Publish-side reuse (what `ParamStore::to_snapshot` does): share the
/// previous snapshot's buffer for every leaf whose fingerprint matches.
fn publish_reused(weights: &[Vec<f32>], prev: Option<&WeightSnapshot>) -> Arc<WeightSnapshot> {
    let leaves = weights
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let fp = fingerprint_f32(w);
            match prev {
                Some(p) if p.leaf_count() == weights.len() && p.fingerprint(i) == fp => {
                    Arc::clone(p.leaf_arc(i))
                }
                _ => Arc::new(w.clone()),
            }
        })
        .collect();
    Arc::new(WeightSnapshot::from_leaves(leaves))
}

fn main() -> anyhow::Result<()> {
    let elems = scaled(32_768);
    let rounds = scaled(8).max(2);
    let mut rows_json = vec![];

    // -- 1. publish -> all-replicas-current vs replica count ----------
    let mut table = Table::new(
        "publish -> all replicas current (all leaves dirty each round)",
        &["replicas", "mode", "wall/round", "MB copied"],
    );
    for &replicas in &[1usize, 2, 4, 8] {
        for shared in [false, true] {
            let pool = ThreadPool::new("bench-sync", replicas);
            let sync = MemorySync::new();
            let mut weights: Vec<Vec<f32>> = vec![vec![0.1; elems]; LEAVES];
            let mut fleet: Vec<SimReplica> =
                (0..replicas).map(|_| SimReplica::new(elems)).collect();
            let mut prev: Option<Arc<WeightSnapshot>> = None;
            let t0 = Instant::now();
            for round in 0..rounds {
                perturb(&mut weights, round, 1.0);
                let snap = publish_reused(&weights, prev.as_deref());
                sync.publish(round as u64 + 1, round as u64, Arc::clone(&snap))?;
                prev = Some(snap);
                // the pool fetches ONCE; replicas apply concurrently
                let update = sync.fetch_if_newer(round as u64).unwrap().unwrap();
                let mut promises = vec![];
                for mut r in fleet.drain(..) {
                    let u = update.clone();
                    promises.push(pool.submit(move || {
                        if shared {
                            r.apply_shared(&u.snapshot, u.version);
                        } else {
                            r.apply_cloned(&u.snapshot, u.version);
                        }
                        r
                    }));
                }
                fleet = promises.into_iter().map(|p| p.wait().unwrap()).collect();
            }
            let wall_s = t0.elapsed().as_secs_f64() / rounds as f64;
            let mb =
                fleet.iter().map(|r| r.copied_bytes).sum::<u64>() as f64 / (1024.0 * 1024.0);
            let mode = if shared { "shared-arc" } else { "clone-per-consumer" };
            table.row(vec![
                replicas.to_string(),
                mode.to_string(),
                format!("{:.2}ms", wall_s * 1e3),
                format!("{mb:.1}"),
            ]);
            rows_json.push(Value::obj(vec![
                ("bench", Value::str("publish_latency")),
                ("replicas", Value::num(replicas as f64)),
                ("mode", Value::str(mode)),
                ("wall_s", Value::num(wall_s)),
                ("mb_copied", Value::num(mb)),
            ]));
        }
    }
    table.print();

    // -- 2. delta apply vs dirty-leaf fraction ------------------------
    let replicas = 4usize;
    let mut table = Table::new(
        "delta apply vs dirty-leaf fraction (4 replicas, shared snapshots)",
        &["dirty", "wall/round", "MB copied", "rebuilt/replica/round"],
    );
    for &frac in &[0.0f64, 0.25, 0.5, 1.0] {
        let pool = ThreadPool::new("bench-sync", replicas);
        let sync = MemorySync::new();
        let mut weights: Vec<Vec<f32>> = vec![vec![0.2; elems]; LEAVES];
        let mut fleet: Vec<SimReplica> = (0..replicas).map(|_| SimReplica::new(elems)).collect();
        // prime: first apply is all-dirty for everyone; excluded from
        // the timed window and the copy meter
        let prime = publish_reused(&weights, None);
        sync.publish(1, 0, Arc::clone(&prime))?;
        let update = sync.fetch_if_newer(0)?.unwrap();
        for r in &mut fleet {
            r.apply_shared(&update.snapshot, update.version);
        }
        let primed_bytes: u64 = fleet.iter().map(|r| r.copied_bytes).sum();
        let mut prev = Some(prime);
        let mut rebuilt_total = 0usize;
        let t0 = Instant::now();
        for round in 0..rounds {
            perturb(&mut weights, round + 1, frac);
            let snap = publish_reused(&weights, prev.as_deref());
            sync.publish(round as u64 + 2, round as u64 + 1, Arc::clone(&snap))?;
            prev = Some(snap);
            let update = sync.fetch_if_newer(round as u64 + 1)?.unwrap();
            let mut promises = vec![];
            for mut r in fleet.drain(..) {
                let u = update.clone();
                promises.push(pool.submit(move || {
                    let rebuilt = r.apply_shared(&u.snapshot, u.version);
                    (r, rebuilt)
                }));
            }
            fleet = promises
                .into_iter()
                .map(|p| {
                    let (r, rebuilt) = p.wait().unwrap();
                    rebuilt_total += rebuilt;
                    r
                })
                .collect();
        }
        let wall_s = t0.elapsed().as_secs_f64() / rounds as f64;
        let mb = (fleet.iter().map(|r| r.copied_bytes).sum::<u64>() - primed_bytes) as f64
            / (1024.0 * 1024.0);
        let rebuilt_per = rebuilt_total as f64 / (replicas * rounds) as f64;
        table.row(vec![
            format!("{:.0}%", frac * 100.0),
            format!("{:.2}ms", wall_s * 1e3),
            format!("{mb:.1}"),
            format!("{rebuilt_per:.1}/{LEAVES}"),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("dirty_apply")),
            ("dirty_frac", Value::num(frac)),
            ("wall_s", Value::num(wall_s)),
            ("mb_copied", Value::num(mb)),
            ("rebuilt", Value::num(rebuilt_per)),
        ]));
    }
    table.print();

    write_json("micro_sync", &Value::arr(rows_json));
    println!(
        "\nexpectations: shared-arc beats clone-per-consumer at every\n\
         replica count (it copies half the bytes and skips the private\n\
         fetch clone), with the gap widening as replicas grow; the\n\
         dirty-fraction sweep scales MB-copied linearly with the\n\
         fraction, and an identical republish (0%) copies ~nothing."
    );
    Ok(())
}
