//! Table 2: ALFWorld profiling — multi-turn episodes with long-tailed
//! rollout latencies, across modes and batch sizes.
//!
//! The paper's observation: with small batches, one-step off-policy gains
//! nothing (a single straggling episode dominates the window), while large
//! sync_interval and fully-async absorb the long tail.  Batch sizes 1/4
//! stand in for the paper's 4/32.

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::util::benchkit::{env_usize, scaled, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::timeseries::{fmt_mean_std, summarize};

struct ModeSpec {
    label: &'static str,
    mode: &'static str,
    interval: u64,
    offset: u64,
}

const MODES: &[ModeSpec] = &[
    ModeSpec { label: "Sync (interval=1)", mode: "both", interval: 1, offset: 0 },
    ModeSpec { label: "Sync (interval=2)", mode: "both", interval: 2, offset: 0 },
    ModeSpec { label: "Sync (interval=5)", mode: "both", interval: 5, offset: 0 },
    ModeSpec { label: "One-step off-policy", mode: "both", interval: 1, offset: 1 },
    ModeSpec { label: "Fully async.", mode: "async", interval: 5, offset: 0 },
];

fn run_once(spec: &ModeSpec, batch_tasks: usize, steps: u64, seed: u64) -> anyhow::Result<(f64, f64)> {
    let mut cfg = RftConfig::default();
    cfg.mode = spec.mode.into();
    cfg.workflow = "alfworld".into();
    cfg.sync_interval = spec.interval;
    cfg.sync_offset = spec.offset;
    cfg.total_steps = steps;
    cfg.dummy_learning = true;
    cfg.batch_tasks = batch_tasks;
    // one episode per task slot; tiny train bucket is 4 experiences
    cfg.repeat_times = 4 / batch_tasks.min(4).max(1);
    cfg.max_new_tokens = 5;
    cfg.explorer_threads = 2;
    cfg.seed = seed;
    let mut session = RftSession::build(cfg, None, None)?;
    let report = session.run()?;
    Ok((report.wall_s, report.explorer_util))
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(5) as u64;
    let trials = env_usize("TRINITY_BENCH_TRIALS", 2);
    println!("Table 2 reproduction: {steps} multi-turn dummy steps x {trials} trials");

    let mut all = Vec::new();
    for batch_tasks in [1usize, 4] {
        let mut table = Table::new(
            &format!("Table 2 — ALFWorld profiling (batch_tasks = {batch_tasks})"),
            &["Mode", "Speedup", "Time (s)", "Util (%)"],
        );
        let mut baseline = None;
        for spec in MODES {
            let mut times = vec![];
            let mut utils = vec![];
            for trial in 0..trials {
                let (t, u) = run_once(spec, batch_tasks, steps, 7 + trial as u64)?;
                times.push(t);
                utils.push(u);
            }
            let t = summarize(&times);
            if baseline.is_none() {
                baseline = Some(t.mean);
            }
            table.row(vec![
                spec.label.to_string(),
                format!("{:.2}x", baseline.unwrap() / t.mean),
                fmt_mean_std(&t),
                fmt_mean_std(&summarize(&utils)),
            ]);
        }
        table.print();
        all.push(table.to_json());
    }
    write_json("table2_alfworld_modes", &Value::arr(all));
    println!(
        "\npaper shape check: large sync_interval and async dominate; one-step\n\
         off-policy shows little or no gain at the small batch size (Table 2)."
    );
    Ok(())
}
