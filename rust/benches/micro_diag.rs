//! Micro-bench: the diagnostics plane in isolation (no PJRT) —
//! DESIGN.md §12's cost model measured directly:
//!
//! 1. flight dump: wall cost of assembling + writing one self-contained
//!    anomaly bundle (span tail, gauge history, sections),
//! 2. attribution: episodes/s through the critical-path sweep over a
//!    synthetic multi-turn span population,
//! 3. SLO assess: per-call cost of the rolling per-class burn diff.
//!
//! Also writes `bench_out/trace.json` from the synthetic episode
//! population so CI can smoke-run `trinity doctor --file` against it.

use std::sync::Arc;
use std::time::{Duration, Instant};

use trinity_rft::obs::{
    attribute, write_trace, Anomaly, FlightConfig, FlightRecorder, Gauges, Histogram, SloConfig,
    SloEngine, Span, SpanKind, SpanRecorder, TelemetryHub,
};
use trinity_rft::qos::CLASS_COUNT;
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

fn span(trace: u64, kind: SpanKind, start_us: u64, dur_us: u64, detail: u64) -> Span {
    Span { trace, kind, replica: 0, start_us, dur_us, detail }
}

/// A synthetic two-turn episode population: queue -> cold prefill inside
/// decode, a think gap, queue -> cache resume inside decode.
fn episode_population(episodes: u64, rng: &mut Rng) -> Vec<Span> {
    let mut spans = Vec::with_capacity(episodes as usize * 6);
    for t in 1..=episodes {
        let t0 = t * 5_000;
        let q1 = 50 + rng.below(200);
        let p = 200 + rng.below(400);
        let d1 = p + 100 + rng.below(300);
        spans.push(span(t, SpanKind::QueueWait, t0, q1, 1));
        spans.push(span(t, SpanKind::Prefill, t0 + q1, p, 64));
        spans.push(span(t, SpanKind::Decode, t0 + q1, d1, 8));
        let gap = t0 + q1 + d1 + 100 + rng.below(200);
        let q2 = 30 + rng.below(100);
        let r = 20 + rng.below(60);
        let d2 = r + 80 + rng.below(200);
        spans.push(span(t, SpanKind::QueueWait, gap, q2, 1));
        spans.push(span(t, SpanKind::Resume, gap + q2, r, 48));
        spans.push(span(t, SpanKind::Decode, gap + q2, d2, 8));
    }
    spans
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let mut rows_json = vec![];

    // -- 1. flight-dump cost ------------------------------------------
    let dir = std::env::temp_dir().join(format!("trft_diag_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dumps = scaled(16).max(4) as u64;
    let tail = episode_population(64, &mut rng);
    let recorder = Arc::new(SpanRecorder::new(1 << 12));
    let hub = Arc::new(TelemetryHub::with_history(Duration::from_millis(1), 256));
    for i in 0..128 {
        hub.publish(Gauges { queued: i as f64, occupancy: 0.5, ..Default::default() });
    }
    let flight = FlightRecorder::new(FlightConfig {
        dir: Some(dir.clone()),
        max_dumps: dumps,
        min_interval: Duration::ZERO,
        ..Default::default()
    });
    flight.connect_spans(Arc::clone(&recorder));
    flight.connect_hub(Arc::clone(&hub));
    flight.set_config_digest("bench");
    // drain is a non-destructive copy, so recording the tail once gives
    // every dump the same 384-span window
    for s in &tail {
        recorder.record(*s);
    }
    let start = Instant::now();
    for i in 0..dumps {
        flight
            .trigger(Anomaly::BreakerOpen, &format!("bench trigger {i}"))
            .expect("dump must be written");
    }
    let dump_wall = start.elapsed().as_secs_f64();
    let dump_ms = 1e3 * dump_wall / dumps as f64;
    let dump_bytes = std::fs::metadata(dir.join("flight-0.json"))?.len();
    let _ = std::fs::remove_dir_all(&dir);
    let mut table = Table::new(
        "flight dump (384-span tail, 128-sample gauge history)",
        &["dumps", "ms/dump", "bundle (KiB)"],
    );
    table.row(vec![
        dumps.to_string(),
        format!("{dump_ms:.2}"),
        format!("{:.1}", dump_bytes as f64 / 1024.0),
    ]);
    table.print();
    rows_json.push(Value::obj(vec![
        ("bench", Value::str("flight_dump")),
        ("dumps", Value::num(dumps as f64)),
        ("ms_per_dump", Value::num(dump_ms)),
        ("bundle_kib", Value::num(dump_bytes as f64 / 1024.0)),
    ]));

    // -- 2. critical-path attribution throughput ----------------------
    let episodes = scaled(2_000).max(200) as u64;
    let spans = episode_population(episodes, &mut rng);
    let start = Instant::now();
    let breakdowns = attribute(&spans);
    let attr_wall = start.elapsed().as_secs_f64();
    assert_eq!(breakdowns.len(), episodes as usize);
    let eps_per_s = episodes as f64 / attr_wall;
    let mut table = Table::new(
        "critical-path attribution (2-turn episodes, 6 spans each)",
        &["episodes", "wall (ms)", "episodes/s"],
    );
    table.row(vec![
        episodes.to_string(),
        format!("{:.1}", attr_wall * 1e3),
        format!("{eps_per_s:.0}"),
    ]);
    table.print();
    rows_json.push(Value::obj(vec![
        ("bench", Value::str("attribution")),
        ("episodes", Value::num(episodes as f64)),
        ("wall_ms", Value::num(attr_wall * 1e3)),
        ("episodes_per_s", Value::num(eps_per_s)),
    ]));

    // -- 3. SLO assess cost -------------------------------------------
    let iters = scaled(20_000).max(1_000);
    let engine = SloEngine::new(SloConfig {
        targets: [
            Duration::from_secs(5),
            Duration::from_millis(500),
            Duration::from_millis(10),
        ],
        objective: 0.99,
    });
    let hists: [Histogram; CLASS_COUNT] = Default::default();
    let start = Instant::now();
    for i in 0..iters {
        hists[i % CLASS_COUNT].observe(1e-4 * (1 + rng.below(100)) as f64);
        let snaps = std::array::from_fn(|c| hists[c].snapshot());
        let burn = engine.assess(&snaps);
        assert!(burn.iter().all(|b| b.is_finite()));
    }
    let slo_wall = start.elapsed().as_secs_f64();
    let ns_per_assess = 1e9 * slo_wall / iters as f64;
    let mut table = Table::new(
        "SLO burn assessment (3 classes, snapshot + rolling diff)",
        &["assessments", "ns/assess"],
    );
    table.row(vec![iters.to_string(), format!("{ns_per_assess:.0}")]);
    table.print();
    rows_json.push(Value::obj(vec![
        ("bench", Value::str("slo_assess")),
        ("iters", Value::num(iters as f64)),
        ("ns_per_assess", Value::num(ns_per_assess)),
    ]));

    // the synthetic population doubles as the doctor smoke-test input
    let trace_path = std::path::Path::new("bench_out").join("trace.json");
    write_trace(&trace_path, &spans)?;
    println!("\nwrote {} ({} episodes) for `trinity doctor --file`", trace_path.display(), episodes);

    write_json("micro_diag", &Value::arr(rows_json));
    println!(
        "\nexpectations: a flight dump costs low single-digit milliseconds\n\
         and fires only on anomalies, so the steady-state overhead is zero;\n\
         attribution sweeps tens of thousands of episodes per second (it\n\
         runs once, at drain); SLO assessment is sub-microsecond and rides\n\
         the existing gauge cadence (DESIGN.md §12)."
    );
    Ok(())
}
