//! Appendix A: the three OPMD variants in the bandit setting, plus the
//! paper's punchline identity — the "embarrassingly simple" variant's
//! gradient equals the group-baseline policy gradient scaled by 1/(1+tau)
//! even off-policy.

use trinity_rft::envs::bandit::{
    run_learning, sample_group, surrogate_grad, Bandit, OpmdVariant, SoftmaxPolicy,
};
use trinity_rft::util::benchkit::{scaled, sparkline, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps = scaled(400);
    let group = 8;
    let tau = 1.0;
    let bandit = Bandit::new(vec![0.1, 0.3, 0.9, 0.2, 0.5], 0.1);
    println!("Appendix A reproduction: bandit arms {:?}, {steps} steps", bandit.means);

    // 1. gradient identity check (exact, Appendix A.3)
    let policy = SoftmaxPolicy { logits: vec![0.2, -0.1, 0.4, 0.0, -0.3] };
    let mut rng = Rng::new(7);
    let g = sample_group(&bandit, &policy, group, &mut rng);
    let g_simple = surrogate_grad(OpmdVariant::Simple, &policy, &g, tau);
    let g_pg = surrogate_grad(OpmdVariant::VanillaPg, &policy, &g, tau);
    let max_err = g_simple
        .iter()
        .zip(&g_pg)
        .map(|(a, b)| (a * (1.0 + tau) - b).abs())
        .fold(0.0f64, f64::max);
    println!("identity check: max |(1+tau)*grad_simple - grad_pg| = {max_err:.2e}");
    assert!(max_err < 1e-10);

    // 2. learning curves per variant x staleness
    let mut table = Table::new(
        "Appendix A — OPMD variants (expected reward, final 5%)",
        &["Variant", "on-policy", "staleness=5", "staleness=20"],
    );
    let mut curves_out = Vec::new();
    for (name, v) in [
        ("OPMD (Kimi)", OpmdVariant::Kimi),
        ("OPMD (pairwise)", OpmdVariant::Pairwise),
        ("OPMD (simple)", OpmdVariant::Simple),
        ("vanilla PG", OpmdVariant::VanillaPg),
    ] {
        let mut cells = vec![name.to_string()];
        for staleness in [0usize, 5, 20] {
            let curve = run_learning(v, &bandit, steps, group, 0.3, tau, staleness, 21);
            let tail = &curve[steps - steps / 20..];
            let final_r = tail.iter().sum::<f64>() / tail.len() as f64;
            cells.push(format!("{final_r:.3}"));
            if staleness == 0 {
                println!("{name:<16} {}", sparkline(&curve));
            }
            curves_out.push(Value::obj(vec![
                ("variant", Value::str(name)),
                ("staleness", Value::num(staleness as f64)),
                ("final_reward", Value::num(final_r)),
            ]));
        }
        table.row(cells);
    }
    table.print();
    write_json("appendixA_opmd_bandit", &Value::arr(curves_out));
    println!(
        "\npaper shape check: all variants approach the best arm (0.9) on-policy;\n\
         the simple variant (== scaled PG) remains a feasible ascent direction\n\
         under stale rollouts (Appendix A's surprising conclusion)."
    );
    Ok(())
}
