//! Micro-bench: workflow-runner fault tolerance under long-tailed
//! latencies and injected failures — the §2.2 machinery in isolation
//! (MockModel; no PJRT).

use std::sync::Arc;
use std::time::{Duration, Instant};

use trinity_rft::exec::ThreadPool;
use trinity_rft::explorer::{
    MockModel, RunnerConfig, SamplingArgs, Task, WorkflowRegistry, WorkflowRunner,
};
use trinity_rft::tokenizer::Tokenizer;
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;

fn math_tasks(n: usize) -> Vec<Task> {
    (0..n)
        .map(|i| {
            let mut t = Task::new(
                &format!("t{i}"),
                "math",
                Value::obj(vec![
                    ("question", Value::str("what is 3 + 4 ?")),
                    ("answer", Value::str("7")),
                ]),
            );
            t.repeat_times = 4;
            t
        })
        .collect()
}

/// MockModel with Pareto (long-tail) latency.
fn longtail_model(seed: u64, scale_ms: f64, fail_rate: f64) -> MockModel {
    let lat_rng = std::sync::Mutex::new(Rng::new(seed ^ 0xfeed));
    let model = MockModel::new(seed, Duration::ZERO, fail_rate);
    model.with_response(move |_, rng| {
        let ms = lat_rng.lock().unwrap().pareto(scale_ms, 1.5).min(scale_ms * 50.0);
        std::thread::sleep(Duration::from_micros((ms * 1000.0) as u64));
        let mut out: Vec<i32> = (0..3).map(|_| 100 + rng.below(20) as i32).collect();
        out.push(trinity_rft::tokenizer::EOS);
        out
    })
}

fn main() -> anyhow::Result<()> {
    let n = scaled(48);
    let mut table = Table::new(
        "runner fault tolerance (MockModel, long-tail latencies)",
        &["scenario", "tasks", "completed", "skipped", "wall (s)", "tasks/s"],
    );
    let mut rows_json = vec![];

    let scenarios: Vec<(&str, f64, f64, Duration)> = vec![
        ("healthy", 2.0, 0.0, Duration::from_secs(30)),
        ("long-tail 10x", 8.0, 0.0, Duration::from_secs(30)),
        ("10% transient failures", 2.0, 0.1, Duration::from_secs(30)),
        ("50% transient failures", 2.0, 0.5, Duration::from_secs(30)),
        ("tight timeout", 8.0, 0.0, Duration::from_millis(200)),
    ];
    for (name, lat_ms, fail, timeout) in scenarios {
        let pool = Arc::new(ThreadPool::new("bench", 8));
        let runner = WorkflowRunner::new(
            pool,
            RunnerConfig {
                timeout,
                max_attempts: 3,
                retry_delay: Duration::from_millis(1),
                seed: 3,
            },
        );
        let model = Arc::new(longtail_model(5, lat_ms, fail));
        let start = Instant::now();
        let (_, stats) = runner.run_collect(
            math_tasks(n),
            Arc::new(WorkflowRegistry::with_builtins()),
            model,
            Arc::new(Tokenizer::new()),
            SamplingArgs::default(),
        );
        let wall = start.elapsed().as_secs_f64();
        table.row(vec![
            name.into(),
            n.to_string(),
            stats.completed.to_string(),
            stats.skipped.to_string(),
            format!("{wall:.2}"),
            format!("{:.1}", stats.completed as f64 / wall),
        ]);
        rows_json.push(Value::obj(vec![
            ("scenario", Value::str(name)),
            ("completed", Value::num(stats.completed as f64)),
            ("skipped", Value::num(stats.skipped as f64)),
            ("wall_s", Value::num(wall)),
        ]));
    }
    table.print();
    write_json("micro_runner", &Value::arr(rows_json));
    println!(
        "\nexpectations: failures are absorbed by retries (completed stays high\n\
         until fail-rate is extreme); tight timeouts skip stragglers instead of\n\
         blocking the batch (paper §2.2)."
    );
    Ok(())
}
