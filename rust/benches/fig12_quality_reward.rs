//! Fig. 12: dynamic quality-reward shaping.
//!
//! Baseline GRPO vs GRPO with the quality processor adding a dense
//! [-0.5, 0.5] signal per rollout, recomputed every RFT step
//! (sync_interval=3 as in the paper).  Claims to reproduce: higher final
//! accuracy, and the quality reward itself improves (a learnable signal).

use std::sync::Arc;

use trinity_rft::coordinator::modes::sft_warmup_snapshot;
use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::data::{ExperienceProcessor, QualityRewardProcessor};
use trinity_rft::util::benchkit::{scaled, sparkline, write_json};
use trinity_rft::util::json::Value;
use trinity_rft::util::timeseries::moving_average;

fn base_cfg(steps: u64) -> RftConfig {
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.total_steps = steps;
    cfg.sync_interval = 3; // paper's Fig. 12 setting
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.min_difficulty = 1;
    cfg.max_difficulty = 1;
    cfg.hyper.lr = 1e-3;
    cfg.adv_std_normalize = true;
    cfg.seed = 13;
    cfg
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(24) as u64;
    println!("Fig. 12 reproduction: quality-reward shaping, {steps} steps each");

    let warm = sft_warmup_snapshot("tiny", 42, (scaled(20) as u64).max(150))?;
    // baseline
    let mut s1 = RftSession::build(base_cfg(steps), None, None)?;
    s1.load_initial_weights(&warm)?;
    let base = s1.run()?;
    let base_acc = eval_acc(&mut s1)?;

    // shaped
    let processor: Arc<dyn ExperienceProcessor> = Arc::new(QualityRewardProcessor { weight: 1.0 });
    let mut s2 = RftSession::build(base_cfg(steps), None, Some(processor))?;
    s2.load_initial_weights(&warm)?;
    let shaped = s2.run()?;
    let shaped_acc = eval_acc(&mut s2)?;

    let base_rewards = base.reward_series();
    let shaped_rewards = shaped.reward_series();
    println!("\nbaseline reward {}", sparkline(&moving_average(&base_rewards, 5)));
    println!("shaped  reward  {}", sparkline(&moving_average(&shaped_rewards, 5)));
    println!("\nfinal eval accuracy: baseline {base_acc:.3} vs quality-shaped {shaped_acc:.3}");

    // the quality component itself over time (learnable signal check)
    let resp_base = base.response_len_series();
    let resp_shaped = shaped.response_len_series();
    println!(
        "response length: baseline {:.1} -> shaped {:.1} (paper reports a slight increase)",
        resp_base.iter().sum::<f64>() / resp_base.len() as f64,
        resp_shaped.iter().sum::<f64>() / resp_shaped.len() as f64,
    );
    let ser = |v: &[f64]| Value::arr(v.iter().map(|x| Value::num(*x)).collect());
    write_json(
        "fig12_quality_reward",
        &Value::obj(vec![
            ("baseline_reward", ser(&base_rewards)),
            ("shaped_reward", ser(&shaped_rewards)),
            ("baseline_acc", Value::num(base_acc)),
            ("shaped_acc", Value::num(shaped_acc)),
        ]),
    );
    println!(
        "\npaper shape check: shaped run (red line in Fig. 12) ends with higher\n\
         accuracy and its reward trends upward (learnable dense signal)."
    );
    Ok(())
}

fn eval_acc(session: &mut RftSession) -> anyhow::Result<f64> {
    let w = session.trainer.as_ref().unwrap().params().snapshot()?;
    session.load_explorer_weights(&w, 9999)?;
    let evals = session.run_bench(&["math500s"], 16, 4, 0.6)?;
    Ok(evals[0].1.avg_reward)
}
