//! Micro-bench: the QoS serving plane in isolation (MockModel replicas;
//! no PJRT) — DESIGN.md §11's properties measured directly:
//!
//! 1. fairness: interactive queue waits under a 10:1 train:interactive
//!    backlog, FIFO vs weighted deficit-round-robin,
//! 2. overhead: single-class throughput with the QoS plane off vs on
//!    (the DRR dequeue must be free when traffic is uniform),
//! 3. migration (artifact-gated): prefill tokens saved by moving a
//!    parked KV session off a quarantined holder vs a cold re-prefill.

use std::sync::Arc;
use std::time::{Duration, Instant};

use trinity_rft::explorer::{MockModel, RolloutEndpoint, RolloutModel, SamplingArgs};
use trinity_rft::model::ParamStore;
use trinity_rft::qos::RequestClass;
use trinity_rft::runtime::{Manifest, ModelEngine, RuntimeClient};
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::Tokenizer;
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;

fn service(models: Vec<Arc<MockModel>>, cfg: ServiceConfig) -> Arc<RolloutService> {
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        models.into_iter().map(|m| m as Arc<dyn RolloutEndpoint>).collect();
    Arc::new(RolloutService::over_models(endpoints, cfg).unwrap())
}

fn spawn_chats(
    svc: &Arc<RolloutService>,
    n: usize,
    class: RequestClass,
) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|i| {
            let svc = Arc::clone(svc);
            std::thread::spawn(move || {
                let args = SamplingArgs {
                    max_new_tokens: 2,
                    seed: i as u64,
                    class,
                    ..Default::default()
                };
                svc.chat(&[1, 40 + (i % 50) as i32], 1, &args).unwrap();
            })
        })
        .collect()
}

/// 10:1 backlog on a serial replica; returns (train mean wait,
/// interactive mean wait, interactive p95 wait) in seconds.
fn skewed_load(qos_enabled: bool, train_n: usize) -> (f64, f64, f64) {
    let mut cfg = ServiceConfig::default();
    cfg.max_batch = 1;
    cfg.qos.enabled = qos_enabled;
    let svc = service(vec![Arc::new(MockModel::new(7, Duration::from_millis(2), 0.0))], cfg);
    let train = spawn_chats(&svc, train_n, RequestClass::TrainRollout);
    std::thread::sleep(Duration::from_millis(8));
    let interactive = spawn_chats(&svc, train_n / 10, RequestClass::Interactive);
    for h in train.into_iter().chain(interactive) {
        h.join().unwrap();
    }
    let s = svc.snapshot();
    let i = RequestClass::Interactive.index();
    (
        s.class_queue_wait[RequestClass::TrainRollout.index()].mean(),
        s.class_queue_wait[i].mean(),
        s.class_queue_wait[i].percentile(0.95),
    )
}

fn main() -> anyhow::Result<()> {
    let n = scaled(60).max(20);
    let mut rows_json = vec![];

    // -- 1. fairness under skewed load --------------------------------
    let mut table = Table::new(
        "fairness (1 serial replica, 2ms latency, 10:1 train:interactive)",
        &["scheduler", "train mean (ms)", "interactive mean (ms)", "interactive p95 (ms)"],
    );
    for (label, qos_on) in [("fifo", false), ("drr", true)] {
        let (train, inter, inter_p95) = skewed_load(qos_on, n);
        table.row(vec![
            label.to_string(),
            format!("{:.1}", train * 1e3),
            format!("{:.1}", inter * 1e3),
            format!("{:.1}", inter_p95 * 1e3),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("fairness")),
            ("scheduler", Value::str(label)),
            ("train_wait_ms", Value::num(train * 1e3)),
            ("interactive_wait_ms", Value::num(inter * 1e3)),
            ("interactive_wait_p95_ms", Value::num(inter_p95 * 1e3)),
        ]));
    }
    table.print();

    // -- 2. uniform-traffic overhead ----------------------------------
    let mut table = Table::new(
        "scheduler overhead (uniform train traffic, 8 concurrent rows)",
        &["scheduler", "rows", "wall (s)", "rows/s"],
    );
    for (label, qos_on) in [("fifo", false), ("drr", true)] {
        let mut cfg = ServiceConfig::default();
        cfg.max_batch = 8;
        cfg.qos.enabled = qos_on;
        let svc = service(vec![Arc::new(MockModel::new(9, Duration::from_millis(1), 0.0))], cfg);
        let start = Instant::now();
        for batch in 0..(n / 8).max(1) {
            let _ = batch;
            for h in spawn_chats(&svc, 8, RequestClass::TrainRollout) {
                h.join().unwrap();
            }
        }
        let wall = start.elapsed().as_secs_f64();
        let rows = svc.snapshot().completed;
        table.row(vec![
            label.to_string(),
            rows.to_string(),
            format!("{wall:.2}"),
            format!("{:.1}", rows as f64 / wall),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("overhead")),
            ("scheduler", Value::str(label)),
            ("wall_s", Value::num(wall)),
            ("rows_per_s", Value::num(rows as f64 / wall)),
        ]));
    }
    table.print();

    // -- 3. migration vs cold serve (artifact-gated) ------------------
    if Manifest::load_default().is_some() {
        let manifest = Manifest::load_default().unwrap();
        let client = RuntimeClient::global();
        let engine = Arc::new(ModelEngine::new(client, &manifest, "tiny")?);
        engine.warmup()?;
        let mut engines = Vec::new();
        for _ in 0..2 {
            let params = ParamStore::init(&engine.model, 23)?;
            engines.push(Arc::new(trinity_rft::explorer::GenerationEngine::new(
                Arc::clone(&engine),
                params,
            )));
        }
        let mut cfg = ServiceConfig::default();
        cfg.cache.enabled = true;
        cfg.cache.min_prefix = 2;
        cfg.qos.enabled = true;
        cfg.qos.migrate_min_tokens = 4;
        let svc = Arc::new(RolloutService::over_engines(engines, cfg)?);

        let tok = Tokenizer::new();
        let args = SamplingArgs {
            max_new_tokens: 4,
            seed: 99,
            session: Some(888),
            ..Default::default()
        };
        let turn1 = svc.chat(&tok.encode_prompt("open the red chest"), 1, &args)?.remove(0);
        svc.quarantine_replica(0, Duration::from_secs(60));
        let mut prompt2 = turn1.tokens.clone();
        prompt2.extend(tok.encode("north"));
        let start = Instant::now();
        svc.chat(&prompt2, 1, &args)?;
        let migrated_s = start.elapsed().as_secs_f64();
        let cache = svc.snapshot().cache.unwrap();

        let mut table = Table::new(
            "live migration (quarantined holder -> healthy peer)",
            &["turn-2 prompt", "prefill saved", "migrations", "turn-2 wall (ms)"],
        );
        table.row(vec![
            prompt2.len().to_string(),
            cache.migration_saved_tokens.to_string(),
            cache.migrations.to_string(),
            format!("{:.1}", migrated_s * 1e3),
        ]);
        table.print();
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("migration")),
            ("prompt_tokens", Value::num(prompt2.len() as f64)),
            ("saved_prefill_tokens", Value::num(cache.migration_saved_tokens as f64)),
            ("migrations", Value::num(cache.migrations as f64)),
            ("turn2_wall_ms", Value::num(migrated_s * 1e3)),
        ]));
    } else {
        println!("\nmigration bench skipped: no runtime artifacts in this environment");
    }

    write_json("micro_qos", &Value::arr(rows_json));
    println!(
        "\nexpectations: DRR cuts interactive waits by an order of magnitude\n\
         under a train backlog while FIFO makes them wait out the queue;\n\
         uniform traffic pays no measurable dequeue overhead; migration\n\
         resumes a parked session on the peer, saving most of the turn's\n\
         prefill tokens (DESIGN.md §11)."
    );
    Ok(())
}
