//! Fig. 4: visualization of the RFT modes as explorer/trainer timelines.
//!
//! Runs each mode briefly and renders the recorded TimelineEvents as an
//! ASCII Gantt chart — rollout batches, train steps, and weight syncs —
//! reproducing the structure of Fig. 4 (a)-(d).

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::util::benchkit::{scaled, write_json};
use trinity_rft::util::json::Value;

fn render(title: &str, report: &trinity_rft::coordinator::ModeReport) {
    println!("\n--- {title} ---");
    let end = report.timeline.iter().map(|e| e.end_s).fold(0.0, f64::max).max(1e-6);
    let width = 72.0;
    let mut roles: Vec<String> = report.timeline.iter().map(|e| e.role.clone()).collect();
    roles.sort();
    roles.dedup();
    for role in roles {
        let mut line = vec![' '; width as usize + 1];
        for ev in report.timeline.iter().filter(|e| e.role == role) {
            let a = (ev.start_s / end * width) as usize;
            let b = ((ev.end_s / end * width) as usize).max(a);
            let ch = match ev.kind.as_str() {
                "rollout" => 'R',
                "train" => 'T',
                "weight_sync" => '|',
                _ => '?',
            };
            for c in line.iter_mut().take(b.min(width as usize) + 1).skip(a) {
                *c = ch;
            }
        }
        println!("{:<12} {}", role, line.iter().collect::<String>());
    }
    println!("{:<12} 0s {:>66.2}s", "", end);
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(6) as u64;
    let mut results = Vec::new();

    let variants: Vec<(&str, &str, u64, u64, usize)> = vec![
        ("(a) synchronous, sync_interval=2", "both", 2, 0, 1),
        ("(b) one-step off-policy", "both", 1, 1, 1),
        ("(c) fully asynchronous", "async", 2, 0, 1),
        ("(d) multi-explorer async (x2)", "async", 2, 0, 2),
    ];
    for (title, mode, interval, offset, explorers) in variants {
        let mut cfg = RftConfig::default();
        cfg.mode = mode.into();
        cfg.sync_interval = interval;
        cfg.sync_offset = offset;
        cfg.explorer_count = explorers;
        cfg.total_steps = steps;
        cfg.dummy_learning = true;
        cfg.batch_tasks = 1;
        cfg.repeat_times = 4;
        cfg.max_new_tokens = 6;
        let mut session = RftSession::build(cfg, None, None)?;
        let report = session.run()?;
        render(title, &report);
        let events = report
            .timeline
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("role", Value::str(e.role.clone())),
                    ("kind", Value::str(e.kind.clone())),
                    ("start_s", Value::num(e.start_s)),
                    ("end_s", Value::num(e.end_s)),
                ])
            })
            .collect();
        results.push(Value::obj(vec![("mode", Value::str(title)), ("events", Value::arr(events))]));
    }
    write_json("fig4_mode_timelines", &Value::arr(results));
    println!(
        "\npaper shape check: (a) shows alternating R/T with sync bars; (b)\n\
         overlaps R and T; (c)/(d) show free-running explorers (Fig. 4)."
    );
    Ok(())
}
