//! Fig. 10: static task prioritization for curriculum learning.
//!
//! Two runs under identical budgets: default (shuffled difficulties) vs
//! easy->hard prioritization from the task pipeline.  The paper's claim:
//! the curriculum run converges faster and more stably.

use std::sync::Arc;

use trinity_rft::coordinator::modes::sft_warmup_snapshot;
use trinity_rft::coordinator::{PrioritizedTaskSource, RftConfig, RftSession, TaskSource};
use trinity_rft::data::TaskPipeline;
use trinity_rft::envs::math::MathTaskGen;
use trinity_rft::explorer::Task;
use trinity_rft::util::benchkit::{scaled, sparkline, write_json};
use trinity_rft::util::json::Value;
use trinity_rft::util::rng::Rng;
use trinity_rft::util::timeseries::moving_average;

fn task_pool(n: usize, repeat: usize) -> Vec<Task> {
    let mut gen = MathTaskGen::new(77, "fig10");
    gen.gen_batch(n, 1, 6)
        .into_iter()
        .map(|mt| {
            let mut t = Task::new(&mt.id, "math", mt.to_payload());
            t.difficulty = mt.difficulty as f64;
            t.repeat_times = repeat;
            t
        })
        .collect()
}

fn run(tasks: Vec<Task>, steps: u64, label: &str, warm: &[Vec<f32>]) -> anyhow::Result<Vec<f64>> {
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.total_steps = steps;
    cfg.sync_interval = 1;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.hyper.lr = 1e-3;
    cfg.adv_std_normalize = true;
    let eval = tasks[..8.min(tasks.len())].to_vec();
    let source: Arc<dyn TaskSource> = Arc::new(PrioritizedTaskSource::new(tasks, eval));
    let mut session = RftSession::build(cfg, Some(source), None)?;
    session.load_initial_weights(warm)?;
    let report = session.run()?;
    let rewards = report.reward_series();
    println!("{label:<14} reward {}", sparkline(&moving_average(&rewards, 5)));
    Ok(rewards)
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(20) as u64;
    println!("Fig. 10 reproduction: curriculum vs default, {steps} steps each");

    let warm = sft_warmup_snapshot("tiny", 42, (scaled(20) as u64).max(150))?;
    let pool = task_pool(steps as usize, 4);

    // default: shuffled difficulty order
    let mut shuffled = pool.clone();
    Rng::new(3).shuffle(&mut shuffled);
    let default_rewards = run(shuffled, steps, "default", &warm)?;

    // curriculum: difficulty ascending (priority_weights difficulty: -1.0)
    let curated = TaskPipeline::easy_to_hard().run(pool)?;
    let curriculum_rewards = run(curated, steps, "easy-to-hard", &warm)?;

    let early = |v: &[f64]| v[..v.len() / 2].iter().sum::<f64>() / (v.len() / 2).max(1) as f64;
    println!(
        "\nfirst-half mean reward: default {:.3} vs curriculum {:.3}",
        early(&default_rewards),
        early(&curriculum_rewards)
    );
    println!(
        "paper shape check: the curriculum (red line in Fig. 10) should sit\n\
         above the default early in training — easy tasks give signal first."
    );
    let ser = |v: &[f64]| Value::arr(v.iter().map(|x| Value::num(*x)).collect());
    write_json(
        "fig10_curriculum",
        &Value::obj(vec![
            ("default", ser(&default_rewards)),
            ("curriculum", ser(&curriculum_rewards)),
        ]),
    );
    Ok(())
}
