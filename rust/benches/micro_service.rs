//! Micro-bench: the rollout service in isolation (MockModel replicas; no
//! PJRT) — paper §2.2's "model service" properties measured directly:
//!
//! 1. microbatch coalescing: throughput and mean batch occupancy as the
//!    number of concurrent workflow runners grows,
//! 2. replica scaling: least-loaded routing over 1/2/4 replicas,
//! 3. quarantine drain: a replica that goes dark mid-run drains its
//!    traffic to healthy peers without failing tasks.

use std::sync::Arc;
use std::time::{Duration, Instant};

use trinity_rft::exec::ThreadPool;
use trinity_rft::explorer::{
    MockModel, RolloutEndpoint, RolloutModel, RunnerConfig, SamplingArgs, Task, WorkflowRegistry,
    WorkflowRunner,
};
use trinity_rft::service::{RolloutService, ServiceConfig};
use trinity_rft::tokenizer::Tokenizer;
use trinity_rft::util::benchkit::{scaled, write_json, Table};
use trinity_rft::util::json::Value;

fn math_tasks(n: usize) -> Vec<Task> {
    (0..n)
        .map(|i| {
            let mut t = Task::new(
                &format!("t{i}"),
                "math",
                Value::obj(vec![
                    ("question", Value::str(format!("what is {} + 4 ?", i % 9))),
                    ("answer", Value::str(((i % 9) + 4).to_string())),
                ]),
            );
            t.repeat_times = 4;
            t
        })
        .collect()
}

fn mock(seed: u64, latency: Duration, fail_rate: f64) -> Arc<MockModel> {
    Arc::new(MockModel::new(seed, latency, fail_rate))
}

fn service(models: Vec<Arc<MockModel>>, cfg: ServiceConfig) -> Arc<RolloutService> {
    let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
        models.into_iter().map(|m| m as Arc<dyn RolloutEndpoint>).collect();
    Arc::new(RolloutService::over_models(endpoints, cfg).unwrap())
}

fn run_tasks(model: Arc<dyn RolloutModel>, runners: usize, n: usize) -> (f64, usize) {
    let pool = Arc::new(ThreadPool::new("bench-svc", runners));
    let runner = WorkflowRunner::new(
        pool,
        RunnerConfig {
            timeout: Duration::from_secs(60),
            max_attempts: 3,
            retry_delay: Duration::from_millis(1),
            seed: 11,
        },
    );
    let start = Instant::now();
    let (_, stats) = runner.run_collect(
        math_tasks(n),
        Arc::new(WorkflowRegistry::with_builtins()),
        model,
        Arc::new(Tokenizer::new()),
        SamplingArgs::default(),
    );
    (start.elapsed().as_secs_f64(), stats.completed)
}

fn main() -> anyhow::Result<()> {
    let n = scaled(64);
    let latency = Duration::from_millis(2);
    let mut rows_json = vec![];

    // -- 1. coalescing vs concurrency --------------------------------
    let mut table = Table::new(
        "microbatch coalescing (1 replica, 2ms engine latency)",
        &["runners", "tasks", "rows", "sessions", "occupancy", "wall (s)", "tasks/s"],
    );
    for runners in [1usize, 4, 8, 16] {
        let mut cfg = ServiceConfig::default();
        cfg.max_batch = 16;
        cfg.admission_window = Duration::from_millis(3);
        let svc = service(vec![mock(1, latency, 0.0)], cfg);
        let (wall, completed) = run_tasks(Arc::clone(&svc) as Arc<dyn RolloutModel>, runners, n);
        let snap = svc.snapshot();
        table.row(vec![
            runners.to_string(),
            completed.to_string(),
            snap.rows.to_string(),
            snap.sessions.to_string(),
            format!("{:.2}", snap.occupancy()),
            format!("{wall:.2}"),
            format!("{:.1}", completed as f64 / wall),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("coalescing")),
            ("runners", Value::num(runners as f64)),
            ("sessions", Value::num(snap.sessions as f64)),
            ("occupancy", Value::num(snap.occupancy())),
            ("wall_s", Value::num(wall)),
        ]));
    }
    table.print();

    // -- 2. replica scaling -------------------------------------------
    let mut table = Table::new(
        "replica scaling (8 runners, least-loaded routing)",
        &["replicas", "tasks", "wall (s)", "tasks/s", "rows/replica"],
    );
    for replicas in [1usize, 2, 4] {
        let mut cfg = ServiceConfig::default();
        cfg.max_batch = 8;
        cfg.admission_window = Duration::from_millis(3);
        let models: Vec<Arc<MockModel>> =
            (0..replicas).map(|r| mock(20 + r as u64, latency, 0.0)).collect();
        let svc = service(models, cfg);
        let (wall, completed) = run_tasks(Arc::clone(&svc) as Arc<dyn RolloutModel>, 8, n);
        let snap = svc.snapshot();
        let per: Vec<String> = snap.replicas.iter().map(|r| r.rows.to_string()).collect();
        table.row(vec![
            replicas.to_string(),
            completed.to_string(),
            format!("{wall:.2}"),
            format!("{:.1}", completed as f64 / wall),
            per.join("/"),
        ]);
        rows_json.push(Value::obj(vec![
            ("bench", Value::str("replicas")),
            ("replicas", Value::num(replicas as f64)),
            ("wall_s", Value::num(wall)),
            ("tasks_per_s", Value::num(completed as f64 / wall)),
        ]));
    }
    table.print();

    // -- 3. quarantine drain ------------------------------------------
    let mut table = Table::new(
        "circuit breaker (replica 0 dark, K=2, traffic drains to peer)",
        &["tasks", "completed", "quarantines", "rerouted", "retried", "r0/r1 rows"],
    );
    let broken = mock(30, Duration::ZERO, 1.0);
    let healthy = mock(31, latency, 0.0);
    let mut cfg = ServiceConfig::default();
    cfg.breaker_failures = 2;
    cfg.quarantine = Duration::from_secs(30); // stays dark for the run
    cfg.max_attempts = 6;
    cfg.retry_backoff = Duration::from_millis(1);
    let svc = service(vec![broken, healthy], cfg);
    let (_, completed) = run_tasks(Arc::clone(&svc) as Arc<dyn RolloutModel>, 8, n);
    let snap = svc.snapshot();
    table.row(vec![
        n.to_string(),
        completed.to_string(),
        snap.replicas[0].quarantines.to_string(),
        snap.rerouted.to_string(),
        snap.retried.to_string(),
        format!("{}/{}", snap.replicas[0].rows, snap.replicas[1].rows),
    ]);
    table.print();
    rows_json.push(Value::obj(vec![
        ("bench", Value::str("quarantine")),
        ("completed", Value::num(completed as f64)),
        ("quarantines", Value::num(snap.replicas[0].quarantines as f64)),
        ("rerouted", Value::num(snap.rerouted as f64)),
    ]));

    write_json("micro_service", &Value::arr(rows_json));
    println!(
        "\nexpectations: occupancy grows with runner concurrency (shared\n\
         sessions, fewer engine calls than rows); replica scaling cuts wall\n\
         time; a dark replica quarantines after K failures and its traffic\n\
         drains to the healthy peer with zero failed tasks (paper §2.2)."
    );
    Ok(())
}
