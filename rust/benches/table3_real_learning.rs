//! Table 3 + Fig. 9: REAL learning with vanilla GRPO across RFT modes.
//!
//! Each mode trains the same initial model on the same task stream; we
//! report final benchmark accuracy per tier (Avg@K), total runtime, and
//! emit the Fig. 9 training curves (reward, response length, grad norm,
//! KL) to bench_out/fig9_curves.json.

use trinity_rft::coordinator::modes::sft_warmup_snapshot;
use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::util::benchkit::{scaled, sparkline, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::timeseries::moving_average;

struct ModeSpec {
    label: &'static str,
    mode: &'static str,
    interval: u64,
    offset: u64,
}

const MODES: &[ModeSpec] = &[
    ModeSpec { label: "Sync (interval=1)", mode: "both", interval: 1, offset: 0 },
    ModeSpec { label: "Sync (interval=2)", mode: "both", interval: 2, offset: 0 },
    ModeSpec { label: "Sync (interval=10)", mode: "both", interval: 10, offset: 0 },
    ModeSpec { label: "One-step off-policy", mode: "both", interval: 1, offset: 1 },
];

const TIERS: &[&str] = &["math500s", "amcs", "aime24s", "aime25s"];

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(40) as u64;
    println!("Table 3 / Fig. 9 reproduction: real GRPO learning, {steps} steps per mode");
    // SFT warm start: GRPO from a random init has all-zero group rewards
    let warm = sft_warmup_snapshot("tiny", 42, (scaled(30) as u64).max(150))?;

    let mut table = Table::new(
        "Table 3 — real GRPO learning across modes",
        &["Mode", "math500s", "amcs", "aime24s", "aime25s", "Average", "Runtime (s)"],
    );
    let mut curves = Vec::new();

    // baseline: untrained model
    {
        let mut cfg = base_cfg(steps);
        cfg.mode = "both".into();
        let session = RftSession::build(cfg, None, None)?;
        session.load_explorer_weights(&warm, 1)?;
        let evals = session.run_bench(TIERS, 12, 4, 0.6)?;
        let accs: Vec<f64> = evals.iter().map(|(_, r)| r.avg_reward).collect();
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec!["initial model".to_string()];
        row.extend(accs.iter().map(|a| format!("{a:.3}")));
        row.push(format!("{avg:.3}"));
        row.push("N/A".into());
        table.row(row);
    }

    for spec in MODES {
        let mut cfg = base_cfg(steps);
        cfg.mode = spec.mode.into();
        cfg.sync_interval = spec.interval;
        cfg.sync_offset = spec.offset;
        let mut session = RftSession::build(cfg, None, None)?;
        session.load_initial_weights(&warm)?;
        let report = session.run()?;

        // bench-mode eval of the FINAL weights (explorer pulls last publish;
        // force it to the trainer's final state)
        let final_weights = session.trainer.as_ref().unwrap().params().snapshot()?;
        session.load_explorer_weights(&final_weights, 9999)?;
        let evals = session.run_bench(TIERS, 12, 4, 0.6)?;
        let accs: Vec<f64> = evals.iter().map(|(_, r)| r.avg_reward).collect();
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![spec.label.to_string()];
        row.extend(accs.iter().map(|a| format!("{a:.3}")));
        row.push(format!("{avg:.3}"));
        row.push(format!("{:.1}", report.wall_s));
        table.row(row);

        // Fig. 9 series (40-step moving average in the paper; scaled here)
        let win = (steps as usize / 5).max(2);
        let reward = moving_average(&report.reward_series(), win);
        let resp = moving_average(&report.response_len_series(), win);
        let gnorm = moving_average(&report.series("grad_norm"), win);
        let kl = moving_average(&report.series("kl"), win);
        println!("\n[{}] fig9 curves:", spec.label);
        println!("  reward    {}", sparkline(&reward));
        println!("  resp_len  {}", sparkline(&resp));
        println!("  grad_norm {}", sparkline(&gnorm));
        println!("  kl        {}", sparkline(&kl));
        let ser = |v: &[f64]| Value::arr(v.iter().map(|x| Value::num(*x)).collect());
        curves.push(Value::obj(vec![
            ("mode", Value::str(spec.label)),
            ("reward", ser(&reward)),
            ("response_len", ser(&resp)),
            ("grad_norm", ser(&gnorm)),
            ("kl", ser(&kl)),
            ("wall_s", Value::num(report.wall_s)),
        ]));
    }

    table.print();
    write_json("table3_real_learning", &table.to_json());
    write_json("fig9_curves", &Value::arr(curves));
    println!(
        "\npaper shape check: all modes improve over the initial model; larger\n\
         sync_interval cuts runtime at slight quality cost; one-step off-policy\n\
         is near sync-1 quality at much lower runtime (Table 3)."
    );
    Ok(())
}

fn base_cfg(steps: u64) -> RftConfig {
    let mut cfg = RftConfig::default();
    cfg.total_steps = steps;
    cfg.algorithm = "grpo".into();
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.min_difficulty = 1;
    cfg.max_difficulty = 1;
    cfg.hyper.lr = 1e-3;
    cfg.adv_std_normalize = true;
    cfg.seed = 5;
    cfg
}
