//! Table 1: performance profiling for GSM8k across RFT modes.
//!
//! Dummy learning (lr=0) exactly as in the paper, so rollout distribution
//! is identical across modes; we report speedup vs the strictly-on-policy
//! synchronous mode, wall time, explorer utilization (the GPU-util analog)
//! and PJRT busy fraction (the GPU-power analog).
//!
//! Scale: `TRINITY_BENCH_SCALE` multiplies the 10-step default;
//! `TRINITY_BENCH_PRESETS=tiny,small` selects model sizes (the paper's
//! 1.5B vs 7B sweep).

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::util::benchkit::{env_usize, scaled, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::timeseries::{fmt_mean_std, summarize};

struct ModeSpec {
    label: &'static str,
    mode: &'static str,
    interval: u64,
    offset: u64,
}

const MODES: &[ModeSpec] = &[
    ModeSpec { label: "Sync (interval=1)", mode: "both", interval: 1, offset: 0 },
    ModeSpec { label: "Sync (interval=2)", mode: "both", interval: 2, offset: 0 },
    ModeSpec { label: "Sync (interval=10)", mode: "both", interval: 10, offset: 0 },
    ModeSpec { label: "One-step off-policy", mode: "both", interval: 1, offset: 1 },
    ModeSpec { label: "Fully async.", mode: "async", interval: 10, offset: 0 },
];

fn run_once(preset: &str, spec: &ModeSpec, steps: u64, seed: u64) -> anyhow::Result<(f64, f64, f64)> {
    let mut cfg = RftConfig::default();
    cfg.mode = spec.mode.into();
    cfg.model_preset = preset.into();
    cfg.sync_interval = spec.interval;
    cfg.sync_offset = spec.offset;
    cfg.total_steps = steps;
    cfg.dummy_learning = true; // paper's profiling methodology
    cfg.batch_tasks = 1;
    cfg.repeat_times = if preset == "small" { 8 } else { 4 };
    cfg.max_new_tokens = 6;
    cfg.seed = seed;
    let mut session = RftSession::build(cfg, None, None)?;
    let report = session.run()?;
    Ok((report.wall_s, report.explorer_util, report.device_busy))
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps = scaled(10) as u64;
    let trials = env_usize("TRINITY_BENCH_TRIALS", 2);
    let presets_env =
        std::env::var("TRINITY_BENCH_PRESETS").unwrap_or_else(|_| "tiny".to_string());
    let presets: Vec<&str> = presets_env.split(',').collect();
    println!("Table 1 reproduction: {steps} dummy-learning steps x {trials} trials");

    let mut all = Vec::new();
    for preset in &presets {
        let mut table = Table::new(
            &format!("Table 1 — GSM8k profiling ({preset} preset)"),
            &["Mode", "Speedup", "Time (s)", "Util (%)", "Busy (%)"],
        );
        let mut baseline_time = None;
        for spec in MODES {
            let mut times = vec![];
            let mut utils = vec![];
            let mut busys = vec![];
            for trial in 0..trials {
                let (t, u, b) = run_once(preset, spec, steps, 100 + trial as u64)?;
                times.push(t);
                utils.push(u);
                busys.push(b);
            }
            let t = summarize(&times);
            if baseline_time.is_none() {
                baseline_time = Some(t.mean);
            }
            let speedup = baseline_time.unwrap() / t.mean;
            table.row(vec![
                spec.label.to_string(),
                format!("{speedup:.2}x"),
                fmt_mean_std(&t),
                fmt_mean_std(&summarize(&utils)),
                fmt_mean_std(&summarize(&busys)),
            ]);
        }
        table.print();
        all.push(table.to_json());
    }
    write_json("table1_gsm8k_modes", &Value::arr(all));
    println!(
        "\npaper shape check: speedup should grow with sync_interval; one-step\n\
         off-policy and fully-async should beat strict on-policy (Table 1)."
    );
    Ok(())
}
