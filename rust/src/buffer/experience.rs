//! Experience schema (the paper's ExperienceModel) and its JSON codec for
//! the persistent store.
//!
//! One experience = one packed token sequence: prompt + response(s), with
//! per-token rollout log-probs, a loss mask (1 where the token belongs to
//! the training objective — multi-turn workflows mask out observation
//! tokens), a possibly-delayed reward, and lineage/provenance metadata.
//! DPO preference pairs reuse the schema: two experiences sharing a
//! `pair_id`, roles "chosen"/"rejected" (the DPODataModel analog).

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    Explorer,
    Expert,
    Human,
    Synthetic,
}

impl Source {
    pub fn as_str(&self) -> &'static str {
        match self {
            Source::Explorer => "explorer",
            Source::Expert => "expert",
            Source::Human => "human",
            Source::Synthetic => "synthetic",
        }
    }
    pub fn parse(s: &str) -> Result<Source> {
        Ok(match s {
            "explorer" => Source::Explorer,
            "expert" => Source::Expert,
            "human" => Source::Human,
            "synthetic" => Source::Synthetic,
            other => bail!("unknown source '{other}'"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Experience {
    /// Unique id (assigned by the buffer on write if 0).
    pub id: u64,
    /// Task that produced this rollout.
    pub task_id: String,
    /// Group id: rollouts of the same task share it (GRPO advantages).
    pub group: u64,
    /// Packed token sequence (prompt + response, multi-turn compacted).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Per-token rollout log-probs aligned with `tokens` (0 outside mask).
    pub logprobs: Vec<f32>,
    /// 1.0 where the token enters the RL objective.
    pub loss_mask: Vec<f32>,
    /// Reward; meaningful once `ready`.
    pub reward: f32,
    /// Delayed-reward support: not-ready experiences are invisible to
    /// readers until the environment's signal arrives.
    pub ready: bool,
    pub source: Source,
    /// Rollout model weight version (staleness tracking).
    pub model_version: u64,
    /// Lineage: id of the experience this one was derived from, if any.
    pub parent_id: Option<u64>,
    /// Priority score for utility-based sampling.
    pub utility: f64,
    /// Times this experience has been sampled for training.
    pub reuse_count: u32,
    /// Free-form metadata (env rounds, quality scores, annotator ids, ...).
    pub metadata: Value,
}

impl Experience {
    pub fn new(task_id: &str, tokens: Vec<i32>, prompt_len: usize, reward: f32) -> Experience {
        let n = tokens.len();
        let mut loss_mask = vec![0.0; n];
        for m in loss_mask.iter_mut().skip(prompt_len) {
            *m = 1.0;
        }
        Experience {
            id: 0,
            task_id: task_id.to_string(),
            group: 0,
            tokens,
            prompt_len,
            logprobs: vec![0.0; n],
            loss_mask,
            reward,
            ready: true,
            source: Source::Explorer,
            model_version: 0,
            parent_id: None,
            utility: 0.0,
            reuse_count: 0,
            metadata: Value::Object(vec![]),
        }
    }

    pub fn response_len(&self) -> usize {
        self.loss_mask.iter().filter(|&&m| m > 0.0).count()
    }

    pub fn seq_len(&self) -> usize {
        self.tokens.len()
    }

    /// Sum of masked rollout log-probs (sequence log-prob under the
    /// rollout policy).
    pub fn rollout_seq_logprob(&self) -> f32 {
        self.logprobs.iter().zip(&self.loss_mask).map(|(l, m)| l * m).sum()
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.metadata.get(key).and_then(Value::as_f64)
    }

    pub fn set_meta(&mut self, key: &str, v: Value) {
        if !matches!(self.metadata, Value::Object(_)) {
            self.metadata = Value::Object(vec![]);
        }
        self.metadata.set(key, v);
    }

    // -- JSON codec ----------------------------------------------------------

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("id", Value::num(self.id as f64)),
            ("task_id", Value::str(self.task_id.clone())),
            ("group", Value::num(self.group as f64)),
            ("tokens", Value::arr(self.tokens.iter().map(|&t| Value::int(t as i64)).collect())),
            ("prompt_len", Value::int(self.prompt_len as i64)),
            ("logprobs", Value::arr(self.logprobs.iter().map(|&l| Value::num(l as f64)).collect())),
            (
                "loss_mask",
                Value::arr(self.loss_mask.iter().map(|&m| Value::num(m as f64)).collect()),
            ),
            ("reward", Value::num(self.reward as f64)),
            ("ready", Value::Bool(self.ready)),
            ("source", Value::str(self.source.as_str())),
            ("model_version", Value::num(self.model_version as f64)),
            (
                "parent_id",
                self.parent_id.map(|p| Value::num(p as f64)).unwrap_or(Value::Null),
            ),
            ("utility", Value::num(self.utility)),
            ("reuse_count", Value::int(self.reuse_count as i64)),
            ("metadata", self.metadata.clone()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Experience> {
        let f32s = |key: &str| -> Result<Vec<f32>> {
            Ok(v.get(key)
                .and_then(Value::as_array)
                .with_context(|| format!("experience field {key}"))?
                .iter()
                .filter_map(Value::as_f64)
                .map(|x| x as f32)
                .collect())
        };
        let tokens: Vec<i32> = v
            .get("tokens")
            .and_then(Value::as_array)
            .context("tokens")?
            .iter()
            .filter_map(Value::as_i64)
            .map(|t| t as i32)
            .collect();
        Ok(Experience {
            id: v.get("id").and_then(Value::as_f64).context("id")? as u64,
            task_id: v.get("task_id").and_then(Value::as_str).context("task_id")?.to_string(),
            group: v.get("group").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            prompt_len: v.get("prompt_len").and_then(Value::as_usize).context("prompt_len")?,
            logprobs: f32s("logprobs")?,
            loss_mask: f32s("loss_mask")?,
            reward: v.get("reward").and_then(Value::as_f64).context("reward")? as f32,
            ready: v.get("ready").and_then(Value::as_bool).unwrap_or(true),
            source: Source::parse(v.get("source").and_then(Value::as_str).unwrap_or("explorer"))?,
            model_version: v.get("model_version").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            parent_id: v.get("parent_id").and_then(Value::as_f64).map(|p| p as u64),
            utility: v.get("utility").and_then(Value::as_f64).unwrap_or(0.0),
            reuse_count: v.get("reuse_count").and_then(Value::as_i64).unwrap_or(0) as u32,
            metadata: v.get("metadata").cloned().unwrap_or(Value::Object(vec![])),
            tokens,
        })
    }
}

/// Group-mean-baseline advantages over a borrowed slice (the hot-path
/// form [`ExperienceBatch::group_advantages`] delegates to — advantage
/// fns call this every train step without cloning the batch).
pub fn group_advantages(exps: &[Experience], normalize_std: bool) -> Vec<f32> {
    use std::collections::HashMap;
    let mut sums: HashMap<u64, (f32, f32, u32)> = HashMap::new();
    for e in exps {
        let s = sums.entry(e.group).or_default();
        s.0 += e.reward;
        s.1 += e.reward * e.reward;
        s.2 += 1;
    }
    exps.iter()
        .map(|e| {
            let (sum, sq, n) = sums[&e.group];
            let n = n as f32;
            let mean = sum / n;
            let mut adv = e.reward - mean;
            if normalize_std && n > 1.0 {
                let var = (sq / n - mean * mean).max(0.0);
                adv /= var.sqrt() + 1e-4;
            }
            adv
        })
        .collect()
}

/// A batch grouped for training (helper used by sample strategies).
#[derive(Debug, Default)]
pub struct ExperienceBatch {
    pub experiences: Vec<Experience>,
}

impl ExperienceBatch {
    /// Group-mean-baseline advantages (GRPO): experiences sharing a group
    /// id get `r - mean(group rewards)`, optionally std-normalized.
    pub fn group_advantages(&self, normalize_std: bool) -> Vec<f32> {
        group_advantages(&self.experiences, normalize_std)
    }

    pub fn mean_reward(&self) -> f64 {
        if self.experiences.is_empty() {
            return 0.0;
        }
        self.experiences.iter().map(|e| e.reward as f64).sum::<f64>() / self.experiences.len() as f64
    }

    pub fn mean_response_len(&self) -> f64 {
        if self.experiences.is_empty() {
            return 0.0;
        }
        self.experiences.iter().map(|e| e.response_len() as f64).sum::<f64>()
            / self.experiences.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Experience {
        let mut e = Experience::new("t1", vec![1, 5, 6, 7, 2], 2, 0.5);
        e.id = 42;
        e.group = 3;
        e.logprobs = vec![0.0, 0.0, -1.5, -0.5, -0.1];
        e.model_version = 7;
        e.parent_id = Some(41);
        e.set_meta("quality", Value::num(0.8));
        e
    }

    #[test]
    fn default_mask_covers_response() {
        let e = Experience::new("t", vec![1, 2, 3, 4, 5], 2, 0.0);
        assert_eq!(e.loss_mask, vec![0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(e.response_len(), 3);
    }

    #[test]
    fn json_roundtrip() {
        let e = sample();
        let v = e.to_json();
        let text = v.to_string_compact();
        let parsed = Value::parse(&text).unwrap();
        let back = Experience::from_json(&parsed).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn rollout_seq_logprob_masks() {
        let e = sample();
        let expected: f32 = -1.5 - 0.5 - 0.1;
        assert!((e.rollout_seq_logprob() - expected).abs() < 1e-6);
    }

    #[test]
    fn group_advantages_zero_mean_per_group() {
        let mut batch = ExperienceBatch::default();
        for (g, r) in [(1u64, 1.0f32), (1, 0.0), (2, 0.5), (2, 0.7)] {
            let mut e = Experience::new("t", vec![1, 2], 1, r);
            e.group = g;
            batch.experiences.push(e);
        }
        let adv = batch.group_advantages(false);
        assert!((adv[0] + adv[1]).abs() < 1e-6);
        assert!((adv[2] + adv[3]).abs() < 1e-6);
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
    }

    #[test]
    fn group_advantages_std_normalized_are_bounded() {
        let mut batch = ExperienceBatch::default();
        for r in [10.0f32, -10.0, 10.0, -10.0] {
            let mut e = Experience::new("t", vec![1], 0, r);
            e.group = 1;
            batch.experiences.push(e);
        }
        let adv = batch.group_advantages(true);
        for a in adv {
            assert!(a.abs() < 1.1);
        }
    }

    #[test]
    fn metadata_accessors() {
        let mut e = sample();
        assert_eq!(e.meta_f64("quality"), Some(0.8));
        e.set_meta("quality", Value::num(0.9));
        assert_eq!(e.meta_f64("quality"), Some(0.9));
        assert_eq!(e.meta_f64("missing"), None);
    }
}
