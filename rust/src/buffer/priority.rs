//! Prioritized experience replay (the DataActiveIterator analog):
//! multi-dimensional utility scoring, version-controlled reuse limits,
//! and asynchronous utility updates as delayed feedback arrives.

use std::sync::Mutex;

use anyhow::Result;

use super::Experience;

/// Weights over the utility features; the paper's "flexible,
/// multi-dimensional utility scoring".
#[derive(Debug, Clone)]
pub struct UtilityWeights {
    /// Weight on raw reward (amplify successes).
    pub reward: f64,
    /// Weight on recency (newer model versions score higher).
    pub recency: f64,
    /// Penalty per previous reuse (decay already-trained-on samples).
    pub reuse_penalty: f64,
    /// Weight on the explicit per-experience utility field (set by data
    /// pipelines, human feedback, etc.).
    pub explicit: f64,
}

impl Default for UtilityWeights {
    fn default() -> Self {
        UtilityWeights { reward: 1.0, recency: 0.1, reuse_penalty: 0.5, explicit: 1.0 }
    }
}

impl UtilityWeights {
    pub fn score(&self, e: &Experience, latest_version: u64) -> f64 {
        let staleness = latest_version.saturating_sub(e.model_version) as f64;
        self.reward * e.reward as f64 - self.recency * staleness
            - self.reuse_penalty * e.reuse_count as f64
            + self.explicit * e.utility
    }
}

/// In-memory priority view over a set of experiences.
pub struct PriorityBuffer {
    inner: Mutex<Vec<Experience>>,
    pub weights: UtilityWeights,
    /// Experiences sampled more than this many times are retired.
    pub max_reuse: u32,
}

impl PriorityBuffer {
    pub fn new(weights: UtilityWeights, max_reuse: u32) -> PriorityBuffer {
        PriorityBuffer { inner: Mutex::new(Vec::new()), weights, max_reuse }
    }

    pub fn insert(&self, exps: Vec<Experience>) {
        self.inner.lock().unwrap().extend(exps);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Update the explicit utility of an experience (delayed feedback).
    pub fn update_utility(&self, id: u64, utility: f64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        for e in inner.iter_mut() {
            if e.id == id {
                e.utility = utility;
                return true;
            }
        }
        false
    }

    /// Take the top-`n` by utility; bumps reuse counts and retires
    /// experiences past `max_reuse`.
    pub fn sample_top(&self, n: usize, latest_version: u64) -> Result<Vec<Experience>> {
        let mut inner = self.inner.lock().unwrap();
        // retire over-reused samples
        inner.retain(|e| e.reuse_count < self.max_reuse);
        let mut order: Vec<usize> = (0..inner.len()).collect();
        order.sort_by(|&a, &b| {
            let sa = self.weights.score(&inner[a], latest_version);
            let sb = self.weights.score(&inner[b], latest_version);
            sb.partial_cmp(&sa).unwrap()
        });
        let mut out = Vec::with_capacity(n.min(order.len()));
        for &i in order.iter().take(n) {
            inner[i].reuse_count += 1;
            out.push(inner[i].clone());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(id: u64, reward: f32, version: u64) -> Experience {
        let mut e = Experience::new("t", vec![1, 2], 1, reward);
        e.id = id;
        e.model_version = version;
        e
    }

    #[test]
    fn higher_reward_sampled_first() {
        let buf = PriorityBuffer::new(UtilityWeights::default(), 10);
        buf.insert(vec![exp(1, 0.1, 0), exp(2, 0.9, 0), exp(3, 0.5, 0)]);
        let got = buf.sample_top(2, 0).unwrap();
        assert_eq!(got[0].id, 2);
        assert_eq!(got[1].id, 3);
    }

    #[test]
    fn staleness_penalized() {
        let w = UtilityWeights { reward: 0.0, recency: 1.0, reuse_penalty: 0.0, explicit: 0.0 };
        let buf = PriorityBuffer::new(w, 10);
        buf.insert(vec![exp(1, 0.0, 1), exp(2, 0.0, 9)]);
        let got = buf.sample_top(1, 10).unwrap();
        assert_eq!(got[0].id, 2, "fresher experience wins");
    }

    #[test]
    fn reuse_penalty_rotates_samples() {
        let buf = PriorityBuffer::new(UtilityWeights::default(), 10);
        buf.insert(vec![exp(1, 0.6, 0), exp(2, 0.5, 0)]);
        let first = buf.sample_top(1, 0).unwrap();
        assert_eq!(first[0].id, 1);
        // id 1 now has reuse_count 1 -> penalized below id 2
        let second = buf.sample_top(1, 0).unwrap();
        assert_eq!(second[0].id, 2);
    }

    #[test]
    fn max_reuse_retires() {
        let buf = PriorityBuffer::new(UtilityWeights::default(), 2);
        buf.insert(vec![exp(1, 1.0, 0)]);
        assert_eq!(buf.sample_top(1, 0).unwrap().len(), 1);
        assert_eq!(buf.sample_top(1, 0).unwrap().len(), 1);
        // reuse_count == 2 == max -> retired
        assert!(buf.sample_top(1, 0).unwrap().is_empty());
        assert_eq!(buf.len(), 0);
    }

    #[test]
    fn explicit_utility_update() {
        let buf = PriorityBuffer::new(UtilityWeights::default(), 10);
        buf.insert(vec![exp(1, 0.5, 0), exp(2, 0.5, 0)]);
        assert!(buf.update_utility(2, 5.0));
        assert!(!buf.update_utility(99, 1.0));
        let got = buf.sample_top(1, 0).unwrap();
        assert_eq!(got[0].id, 2);
    }
}
