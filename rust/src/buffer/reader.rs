//! Sampling strategies — how the trainer pulls batches from buffers.
//!
//! `MixSampleStrategy` is the paper's §3.2 example verbatim: a batch
//! composed of usual rollout experiences plus expert trajectories from a
//! second buffer, to be consumed by the MIX loss.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::util::rng::Rng;

use super::{Experience, ExperienceBuffer, FileStore, Source};

pub trait SampleStrategy: Send + Sync {
    /// Sample a training batch for `step`.  Blocks (bounded by the
    /// strategy's timeout) until enough ready experiences exist.
    fn sample(&self, step: u64, batch: usize) -> Result<Vec<Experience>>;
    fn name(&self) -> &'static str;
}

/// Runtime resources available when a strategy is instantiated for a
/// session (see [`SampleStrategyFactory`]).
pub struct StrategyCtx {
    /// The session's main rollout buffer.
    pub buffer: Arc<dyn ExperienceBuffer>,
    /// A second buffer of expert trajectories, when the session provides
    /// one (`BuildOpts::expert_buffer`).
    pub expert_buffer: Option<Arc<dyn ExperienceBuffer>>,
    /// Expert share of each batch (`algorithm.mix.expert_fraction`).
    pub expert_fraction: f64,
    pub timeout: Duration,
}

/// How an algorithm spec links to its sample strategy: the spec declares
/// a factory, the coordinator supplies the [`StrategyCtx`] at session
/// build time.  This moves strategy selection out of ad-hoc call sites
/// and into the algorithm definition (paper §3.2's linked
/// SampleStrategy).
pub trait SampleStrategyFactory: Send + Sync {
    fn name(&self) -> &'static str;
    fn build(&self, ctx: &StrategyCtx) -> Result<Box<dyn SampleStrategy>>;
}

/// Plain FIFO consumption from the session buffer (the default).
pub struct FifoFactory;

impl SampleStrategyFactory for FifoFactory {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn build(&self, ctx: &StrategyCtx) -> Result<Box<dyn SampleStrategy>> {
        Ok(Box::new(FifoStrategy { buffer: Arc::clone(&ctx.buffer), timeout: ctx.timeout }))
    }
}

/// Expert-mixing strategy for MIX-style algorithms: composes the usual
/// buffer with the context's expert buffer.  Sessions without an expert
/// buffer fall back to plain FIFO (every row then counts as a rollout,
/// matching the seed behavior of running `mix` on one buffer).
pub struct MixFactory;

impl SampleStrategyFactory for MixFactory {
    fn name(&self) -> &'static str {
        "mix"
    }
    fn build(&self, ctx: &StrategyCtx) -> Result<Box<dyn SampleStrategy>> {
        match &ctx.expert_buffer {
            Some(expert) => Ok(Box::new(MixSampleStrategy {
                usual: Arc::clone(&ctx.buffer),
                expert: Arc::clone(expert),
                expert_fraction: ctx.expert_fraction,
                timeout: ctx.timeout,
            })),
            None => FifoFactory.build(ctx),
        }
    }
}

/// Plain FIFO consumption from one buffer (the default strategy).
pub struct FifoStrategy {
    pub buffer: Arc<dyn ExperienceBuffer>,
    pub timeout: Duration,
}

impl SampleStrategy for FifoStrategy {
    fn sample(&self, _step: u64, batch: usize) -> Result<Vec<Experience>> {
        let got = self.buffer.read(batch, self.timeout)?;
        ensure!(!got.is_empty(), "buffer drained or timed out before any experience");
        Ok(got)
    }
    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Uniform random sampling from a persistent store (off-policy replay).
pub struct RandomStrategy {
    pub store: Arc<FileStore>,
    pub seed: u64,
}

impl SampleStrategy for RandomStrategy {
    fn sample(&self, step: u64, batch: usize) -> Result<Vec<Experience>> {
        let n_ready = self.store.ready_count();
        ensure!(n_ready > 0, "no ready experiences in store");
        let mut rng = Rng::new(self.seed ^ step.wrapping_mul(0x9e3779b97f4a7c15));
        let indices: Vec<usize> =
            (0..batch).map(|_| rng.below(n_ready as u64) as usize).collect();
        Ok(self.store.sample_ready(&indices))
    }
    fn name(&self) -> &'static str {
        "random"
    }
}

/// Paper §3.2: usual rollout experiences + expert trajectories, with the
/// expert fraction of each batch configurable.  Expert samples get their
/// source stamped so the MIX batch builder can produce `is_expert`.
pub struct MixSampleStrategy {
    pub usual: Arc<dyn ExperienceBuffer>,
    pub expert: Arc<dyn ExperienceBuffer>,
    pub expert_fraction: f64,
    pub timeout: Duration,
}

impl SampleStrategy for MixSampleStrategy {
    fn sample(&self, _step: u64, batch: usize) -> Result<Vec<Experience>> {
        let n_expert = ((batch as f64) * self.expert_fraction).round() as usize;
        let n_expert = n_expert.min(batch);
        let n_usual = batch - n_expert;
        let mut out = self.usual.read(n_usual, self.timeout)?;
        let mut experts = self.expert.read(n_expert, self.timeout)?;
        for e in &mut experts {
            e.source = Source::Expert;
        }
        out.extend(experts);
        ensure!(!out.is_empty(), "both buffers empty");
        Ok(out)
    }
    fn name(&self) -> &'static str {
        "mix"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::QueueBuffer;

    fn filled_queue(n: usize, tag: &str) -> Arc<QueueBuffer> {
        let q = Arc::new(QueueBuffer::new(1024));
        let exps: Vec<Experience> = (0..n)
            .map(|i| Experience::new(&format!("{tag}{i}"), vec![1, 2, 3], 1, i as f32))
            .collect();
        q.write(exps).unwrap();
        q
    }

    #[test]
    fn fifo_strategy_reads_in_order() {
        let q = filled_queue(8, "t");
        let s = FifoStrategy { buffer: q, timeout: Duration::from_millis(20) };
        let b = s.sample(0, 4).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].task_id, "t0");
        assert_eq!(b[3].task_id, "t3");
    }

    #[test]
    fn mix_strategy_composition() {
        let usual = filled_queue(8, "u");
        let expert = filled_queue(8, "e");
        let s = MixSampleStrategy {
            usual,
            expert,
            expert_fraction: 0.25,
            timeout: Duration::from_millis(20),
        };
        let b = s.sample(0, 8).unwrap();
        assert_eq!(b.len(), 8);
        let experts = b.iter().filter(|e| e.source == Source::Expert).count();
        assert_eq!(experts, 2);
        // experts come from the expert buffer
        assert!(b.iter().filter(|e| e.source == Source::Expert).all(|e| e.task_id.starts_with('e')));
    }

    #[test]
    fn factories_build_from_context() {
        let ctx = StrategyCtx {
            buffer: filled_queue(4, "u"),
            expert_buffer: None,
            expert_fraction: 0.25,
            timeout: Duration::from_millis(20),
        };
        // mix without an expert buffer falls back to fifo
        assert_eq!(MixFactory.build(&ctx).unwrap().name(), "fifo");
        assert_eq!(FifoFactory.build(&ctx).unwrap().name(), "fifo");
        let ctx = StrategyCtx { expert_buffer: Some(filled_queue(4, "e")), ..ctx };
        let s = MixFactory.build(&ctx).unwrap();
        assert_eq!(s.name(), "mix");
        let b = s.sample(0, 4).unwrap();
        assert_eq!(b.iter().filter(|e| e.source == Source::Expert).count(), 1);
    }

    #[test]
    fn random_strategy_replays_same_store() {
        let p = std::env::temp_dir().join(format!("trft_rand_{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        let store = Arc::new(FileStore::open(&p).unwrap());
        store
            .write((0..10).map(|i| Experience::new(&format!("r{i}"), vec![1], 0, i as f32)).collect())
            .unwrap();
        let s = RandomStrategy { store: Arc::clone(&store), seed: 1 };
        let b1 = s.sample(1, 6).unwrap();
        let b2 = s.sample(2, 6).unwrap();
        assert_eq!(b1.len(), 6);
        // replay: same experiences can appear in multiple batches
        let total_reads: u32 = store.snapshot_ready().iter().map(|e| e.reuse_count).sum();
        assert_eq!(total_reads as usize, b1.len() + b2.len());
        std::fs::remove_file(&p).unwrap();
    }
}
