//! Persistent experience store (the SQLite analog): an append-only record
//! log with CRC-guarded frames, in-memory index, crash recovery, and
//! in-place (logical) updates for delayed rewards.
//!
//! Frame format: `[u32 len][payload bytes][u32 crc32(payload)]`.
//! Payload is a JSON object: either a full experience
//! (`{"t":"exp", ...experience}`) or an update
//! (`{"t":"upd", "id":..., "reward":..., "ready":...}`).
//! Recovery replays the log, applying updates over experiences; a torn
//! final frame (crash mid-write) is truncated away.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::model::checkpoint::crc32;
use crate::util::json::Value;

use super::{Experience, ExperienceBuffer};

struct State {
    /// All experiences, insertion order.
    all: Vec<Experience>,
    /// id -> index in `all`.
    index: HashMap<u64, usize>,
    /// read cursor into `all` (fifo consumption; skips non-ready).
    cursor: usize,
    file: std::fs::File,
    closed: bool,
}

pub struct FileStore {
    path: PathBuf,
    state: Mutex<State>,
    not_empty: Condvar,
    next_id: AtomicU64,
    written: AtomicU64,
}

fn write_frame(file: &mut std::fs::File, payload: &[u8]) -> Result<()> {
    file.write_all(&(payload.len() as u32).to_le_bytes())?;
    file.write_all(payload)?;
    file.write_all(&crc32(payload).to_le_bytes())?;
    Ok(())
}

impl FileStore {
    /// Open (or create) a store; replays the log on open.
    pub fn open(path: impl AsRef<Path>) -> Result<FileStore> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening store {path:?}"))?;

        // -- recovery replay --
        let mut raw = Vec::new();
        file.seek(SeekFrom::Start(0))?;
        file.read_to_end(&mut raw)?;
        let mut all: Vec<Experience> = Vec::new();
        let mut index: HashMap<u64, usize> = HashMap::new();
        let mut pos = 0usize;
        let mut valid_end = 0usize;
        let mut max_id = 0u64;
        while pos + 8 <= raw.len() {
            let len = u32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap()) as usize;
            if pos + 4 + len + 4 > raw.len() {
                break; // torn final frame
            }
            let payload = &raw[pos + 4..pos + 4 + len];
            let stored = u32::from_le_bytes(raw[pos + 4 + len..pos + 8 + len].try_into().unwrap());
            if crc32(payload) != stored {
                break; // corruption: stop replay here
            }
            let text = std::str::from_utf8(payload).context("store frame utf8")?;
            let v = Value::parse(text).context("store frame json")?;
            match v.get("t").and_then(Value::as_str) {
                Some("exp") => {
                    let e = Experience::from_json(&v)?;
                    max_id = max_id.max(e.id);
                    index.insert(e.id, all.len());
                    all.push(e);
                }
                Some("upd") => {
                    let id = v.get("id").and_then(Value::as_f64).context("upd id")? as u64;
                    if let Some(&i) = index.get(&id) {
                        if let Some(r) = v.get("reward").and_then(Value::as_f64) {
                            all[i].reward = r as f32;
                        }
                        if let Some(rd) = v.get("ready").and_then(Value::as_bool) {
                            all[i].ready = rd;
                        }
                        if let Some(u) = v.get("utility").and_then(Value::as_f64) {
                            all[i].utility = u;
                        }
                    }
                }
                _ => bail!("unknown frame type in store"),
            }
            pos += 8 + len;
            valid_end = pos;
        }
        if valid_end < raw.len() {
            // truncate torn tail so future appends are clean
            file.set_len(valid_end as u64)?;
        }
        file.seek(SeekFrom::End(0))?;

        Ok(FileStore {
            path,
            state: Mutex::new(State { all, index, cursor: 0, file, closed: false }),
            not_empty: Condvar::new(),
            next_id: AtomicU64::new(max_id + 1),
            written: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total records (ready or not) currently stored.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().all.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Update reward/ready/utility of an existing experience (logged).
    pub fn update(
        &self,
        id: u64,
        reward: Option<f32>,
        ready: Option<bool>,
        utility: Option<f64>,
    ) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let Some(&i) = st.index.get(&id) else { bail!("no experience {id}") };
        let mut pairs = vec![("t", Value::str("upd")), ("id", Value::num(id as f64))];
        if let Some(r) = reward {
            st.all[i].reward = r;
            pairs.push(("reward", Value::num(r as f64)));
        }
        if let Some(rd) = ready {
            st.all[i].ready = rd;
            pairs.push(("ready", Value::Bool(rd)));
        }
        if let Some(u) = utility {
            st.all[i].utility = u;
            pairs.push(("utility", Value::num(u)));
        }
        let payload = Value::obj(pairs).to_string_compact();
        write_frame(&mut st.file, payload.as_bytes())?;
        drop(st);
        self.not_empty.notify_all();
        Ok(())
    }

    /// Mark a delayed experience ready with its final reward.
    pub fn complete(&self, id: u64, reward: f32) -> Result<()> {
        self.update(id, Some(reward), Some(true), None)
    }

    /// Snapshot of all ready experiences (for priority views / pipelines).
    pub fn snapshot_ready(&self) -> Vec<Experience> {
        self.state.lock().unwrap().all.iter().filter(|e| e.ready).cloned().collect()
    }

    /// Get by id.
    pub fn get(&self, id: u64) -> Option<Experience> {
        let st = self.state.lock().unwrap();
        st.index.get(&id).map(|&i| st.all[i].clone())
    }

    /// Random-access read of `n` ready experiences without consuming the
    /// FIFO cursor (used by random/priority strategies); bumps reuse counts.
    pub fn sample_ready(&self, indices: &[usize]) -> Vec<Experience> {
        let mut st = self.state.lock().unwrap();
        let ready_idx: Vec<usize> =
            (0..st.all.len()).filter(|&i| st.all[i].ready).collect();
        indices
            .iter()
            .filter_map(|&i| ready_idx.get(i).copied())
            .map(|i| {
                st.all[i].reuse_count += 1;
                st.all[i].clone()
            })
            .collect()
    }

    pub fn ready_count(&self) -> usize {
        self.state.lock().unwrap().all.iter().filter(|e| e.ready).count()
    }

    /// Flush to disk (appends are buffered by the OS; tests use this).
    pub fn sync(&self) -> Result<()> {
        self.state.lock().unwrap().file.sync_all()?;
        Ok(())
    }
}

impl ExperienceBuffer for FileStore {
    fn write(&self, exps: Vec<Experience>) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            bail!("store closed");
        }
        for mut e in exps {
            if e.id == 0 {
                e.id = self.next_id.fetch_add(1, Ordering::SeqCst);
            }
            let mut v = e.to_json();
            v.set("t", Value::str("exp"));
            let payload = v.to_string_compact();
            write_frame(&mut st.file, payload.as_bytes())?;
            let idx = st.all.len();
            st.index.insert(e.id, idx);
            st.all.push(e);
            self.written.fetch_add(1, Ordering::SeqCst);
        }
        drop(st);
        self.not_empty.notify_all();
        Ok(())
    }

    fn read(&self, n: usize, timeout: Duration) -> Result<Vec<Experience>> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        let mut st = self.state.lock().unwrap();
        loop {
            // advance cursor over ready records
            while out.len() < n && st.cursor < st.all.len() {
                let i = st.cursor;
                if st.all[i].ready {
                    st.all[i].reuse_count += 1;
                    out.push(st.all[i].clone());
                    st.cursor += 1;
                } else {
                    // delayed record at the head: skip it for now but do not
                    // consume it — move it behind the cursor conceptually by
                    // swapping is complex; instead scan ahead.
                    let mut j = i + 1;
                    while j < st.all.len() && !st.all[j].ready {
                        j += 1;
                    }
                    if j < st.all.len() {
                        st.all.swap(i, j);
                        let (a, b) = (st.all[i].id, st.all[j].id);
                        st.index.insert(a, i);
                        st.index.insert(b, j);
                    } else {
                        break;
                    }
                }
            }
            if out.len() >= n || st.closed {
                return Ok(out);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(out);
            }
            let (g, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    fn ready_len(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.all[st.cursor.min(st.all.len())..].iter().filter(|e| e.ready).count()
    }

    fn total_written(&self) -> u64 {
        self.written.load(Ordering::SeqCst)
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("trft_store_{}_{}", std::process::id(), name))
    }

    fn exp(task: &str, reward: f32) -> Experience {
        Experience::new(task, vec![1, 7, 8, 2], 1, reward)
    }

    #[test]
    fn write_read_fifo() {
        let p = tmp("fifo");
        let _ = std::fs::remove_file(&p);
        let s = FileStore::open(&p).unwrap();
        s.write(vec![exp("a", 1.0), exp("b", 2.0), exp("c", 3.0)]).unwrap();
        let got = s.read(2, Duration::from_millis(5)).unwrap();
        assert_eq!(got.iter().map(|e| e.task_id.as_str()).collect::<Vec<_>>(), vec!["a", "b"]);
        let got2 = s.read(2, Duration::from_millis(5)).unwrap();
        assert_eq!(got2.len(), 1);
        assert_eq!(got2[0].task_id, "c");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn persistence_across_reopen() {
        let p = tmp("reopen");
        let _ = std::fs::remove_file(&p);
        {
            let s = FileStore::open(&p).unwrap();
            s.write(vec![exp("x", 0.5), exp("y", 0.7)]).unwrap();
            s.update(1, Some(0.9), None, Some(2.5)).unwrap();
            s.sync().unwrap();
        }
        let s = FileStore::open(&p).unwrap();
        assert_eq!(s.len(), 2);
        let x = s.get(1).unwrap();
        assert_eq!(x.reward, 0.9);
        assert_eq!(x.utility, 2.5);
        // ids continue from the recovered max
        s.write(vec![exp("z", 0.0)]).unwrap();
        assert_eq!(s.get(3).unwrap().task_id, "z");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_is_recovered() {
        let p = tmp("torn");
        let _ = std::fs::remove_file(&p);
        {
            let s = FileStore::open(&p).unwrap();
            s.write(vec![exp("good", 1.0)]).unwrap();
            s.sync().unwrap();
        }
        // simulate a crash mid-append
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[200, 0, 0, 0, b'{', b'"']).unwrap(); // len=200 but 2 bytes
        }
        let s = FileStore::open(&p).unwrap();
        assert_eq!(s.len(), 1);
        // store is usable after truncation
        s.write(vec![exp("after", 2.0)]).unwrap();
        s.sync().unwrap();
        drop(s);
        let s2 = FileStore::open(&p).unwrap();
        assert_eq!(s2.len(), 2);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn delayed_rewards_invisible_until_complete() {
        let p = tmp("delayed");
        let _ = std::fs::remove_file(&p);
        let s = FileStore::open(&p).unwrap();
        let mut e = exp("slow", 0.0);
        e.ready = false;
        s.write(vec![e, exp("fast", 1.0)]).unwrap();
        // reader should get only the ready one (delayed is skipped, not consumed)
        let got = s.read(2, Duration::from_millis(10)).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].task_id, "fast");
        s.complete(1, 0.42).unwrap();
        let got2 = s.read(1, Duration::from_millis(10)).unwrap();
        assert_eq!(got2[0].task_id, "slow");
        assert_eq!(got2[0].reward, 0.42);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn delayed_reward_survives_reopen() {
        let p = tmp("delayed_reopen");
        let _ = std::fs::remove_file(&p);
        {
            let s = FileStore::open(&p).unwrap();
            let mut e = exp("slow", 0.0);
            e.ready = false;
            s.write(vec![e]).unwrap();
            s.complete(1, 0.8).unwrap();
            s.sync().unwrap();
        }
        let s = FileStore::open(&p).unwrap();
        let e = s.get(1).unwrap();
        assert!(e.ready);
        assert_eq!(e.reward, 0.8);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn concurrent_writers_and_reader() {
        let p = tmp("mpmc");
        let _ = std::fs::remove_file(&p);
        let s = std::sync::Arc::new(FileStore::open(&p).unwrap());
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let s = std::sync::Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..25 {
                        s.write(vec![exp(&format!("w{w}-{i}"), 0.0)]).unwrap();
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        let got = s.read(100, Duration::from_millis(50)).unwrap();
        assert_eq!(got.len(), 100);
        let mut ids: Vec<u64> = got.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100, "ids must be unique");
        std::fs::remove_file(&p).unwrap();
    }
}
