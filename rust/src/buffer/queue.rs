//! Non-persistent FIFO buffer (the ray.Queue analog) with blocking reads
//! and backpressure, plus a holding pen for delayed-reward experiences.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{Experience, ExperienceBuffer};

struct State {
    ready: VecDeque<Experience>,
    /// Experiences written with `ready=false`, waiting for their reward.
    pending: Vec<Experience>,
    closed: bool,
}

pub struct QueueBuffer {
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    next_id: AtomicU64,
    written: AtomicU64,
}

impl QueueBuffer {
    pub fn new(capacity: usize) -> QueueBuffer {
        QueueBuffer {
            state: Mutex::new(State { ready: VecDeque::new(), pending: Vec::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
            next_id: AtomicU64::new(1),
            written: AtomicU64::new(0),
        }
    }

    /// Complete a delayed-reward experience: set its reward and move it to
    /// the readable queue (the paper's "marked ready for training").
    pub fn complete(&self, id: u64, reward: f32) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let Some(idx) = st.pending.iter().position(|e| e.id == id) else {
            bail!("no pending experience with id {id}");
        };
        let mut e = st.pending.remove(idx);
        e.reward = reward;
        e.ready = true;
        st.ready.push_back(e);
        drop(st);
        self.not_empty.notify_all();
        Ok(())
    }

    pub fn pending_len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }
}

impl ExperienceBuffer for QueueBuffer {
    fn write(&self, exps: Vec<Experience>) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        for mut e in exps {
            if e.id == 0 {
                e.id = self.next_id.fetch_add(1, Ordering::SeqCst);
            }
            // backpressure on the ready queue
            while st.ready.len() >= self.capacity && !st.closed {
                st = self.not_full.wait(st).unwrap();
            }
            if st.closed {
                bail!("buffer closed");
            }
            self.written.fetch_add(1, Ordering::SeqCst);
            if e.ready {
                st.ready.push_back(e);
                self.not_empty.notify_one();
            } else {
                st.pending.push(e);
            }
        }
        Ok(())
    }

    fn read(&self, n: usize, timeout: Duration) -> Result<Vec<Experience>> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        let mut st = self.state.lock().unwrap();
        while out.len() < n {
            if let Some(mut e) = st.ready.pop_front() {
                e.reuse_count += 1;
                out.push(e);
                self.not_full.notify_one();
                continue;
            }
            if st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
        Ok(out)
    }

    fn ready_len(&self) -> usize {
        self.state.lock().unwrap().ready.len()
    }

    fn total_written(&self) -> u64 {
        self.written.load(Ordering::SeqCst)
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn exp(task: &str, reward: f32) -> Experience {
        Experience::new(task, vec![1, 2, 3], 1, reward)
    }

    #[test]
    fn fifo_read_write() {
        let q = QueueBuffer::new(16);
        q.write(vec![exp("a", 0.1), exp("b", 0.2)]).unwrap();
        let got = q.read(2, Duration::from_millis(10)).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].task_id, "a");
        assert_eq!(got[1].task_id, "b");
        assert!(got.iter().all(|e| e.id > 0));
    }

    #[test]
    fn read_times_out_when_short() {
        let q = QueueBuffer::new(16);
        q.write(vec![exp("a", 0.0)]).unwrap();
        let start = Instant::now();
        let got = q.read(3, Duration::from_millis(40)).unwrap();
        assert_eq!(got.len(), 1);
        assert!(start.elapsed() >= Duration::from_millis(35));
    }

    #[test]
    fn delayed_reward_flow() {
        let q = QueueBuffer::new(16);
        let mut e = exp("slow", 0.0);
        e.ready = false;
        e.id = 99;
        q.write(vec![e]).unwrap();
        assert_eq!(q.ready_len(), 0);
        assert_eq!(q.pending_len(), 1);
        // reader sees nothing yet
        assert!(q.read(1, Duration::from_millis(10)).unwrap().is_empty());
        // reward arrives
        q.complete(99, 0.75).unwrap();
        let got = q.read(1, Duration::from_millis(10)).unwrap();
        assert_eq!(got[0].reward, 0.75);
        assert!(got[0].ready);
        assert!(q.complete(99, 1.0).is_err()); // already completed
    }

    #[test]
    fn blocking_reader_wakes_on_write() {
        let q = Arc::new(QueueBuffer::new(16));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.read(1, Duration::from_secs(5)).unwrap());
        std::thread::sleep(Duration::from_millis(30));
        q.write(vec![exp("late", 1.0)]).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
    }

    #[test]
    fn close_unblocks_and_rejects() {
        let q = Arc::new(QueueBuffer::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.read(1, Duration::from_secs(5)).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_empty());
        assert!(q.write(vec![exp("x", 0.0)]).is_err());
    }

    #[test]
    fn capacity_backpressure() {
        let q = Arc::new(QueueBuffer::new(2));
        q.write(vec![exp("a", 0.0), exp("b", 0.0)]).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let t0 = Instant::now();
            q2.write(vec![exp("c", 0.0)]).unwrap();
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(40));
        let _ = q.read(1, Duration::from_millis(10)).unwrap();
        assert!(h.join().unwrap() >= Duration::from_millis(30));
    }

    #[test]
    fn reuse_count_increments_on_read() {
        let q = QueueBuffer::new(4);
        q.write(vec![exp("a", 0.0)]).unwrap();
        let got = q.read(1, Duration::from_millis(5)).unwrap();
        assert_eq!(got[0].reuse_count, 1);
    }
}
