//! The experience buffer — the standalone centerpiece of the paper's
//! trinity (Fig. 3): explorer(s) write, trainer reads, with data
//! persistence, delayed-reward completion, priority views and pluggable
//! sampling strategies.

pub mod experience;
pub mod priority;
pub mod queue;
pub mod reader;
pub mod store;

pub use experience::{group_advantages, Experience, ExperienceBatch, Source};
pub use priority::{PriorityBuffer, UtilityWeights};
pub use queue::QueueBuffer;
pub use reader::{
    FifoFactory, FifoStrategy, MixFactory, MixSampleStrategy, RandomStrategy, SampleStrategy,
    SampleStrategyFactory, StrategyCtx,
};
pub use store::FileStore;

use std::time::Duration;

use anyhow::Result;

/// The buffer interface both the non-persistent queue (ray.Queue analog)
/// and the persistent store (SQLite analog) implement.
pub trait ExperienceBuffer: Send + Sync {
    /// Append experiences (they become readable once `ready`).
    fn write(&self, exps: Vec<Experience>) -> Result<()>;
    /// Read up to `n` ready experiences, blocking up to `timeout` for the
    /// first one.  Returns fewer than `n` only on timeout/closure.
    fn read(&self, n: usize, timeout: Duration) -> Result<Vec<Experience>>;
    /// Ready experiences currently readable.
    fn ready_len(&self) -> usize;
    /// Total experiences ever written.
    fn total_written(&self) -> u64;
    /// Close the buffer: readers drain what's left, writers fail.
    fn close(&self);
}
