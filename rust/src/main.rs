//! `trinity` — the leader binary: run RFT from a YAML config, bench
//! checkpoints, evaluate OPMD variants, or inspect artifacts.
//!
//! ```text
//! trinity run        --config configs/gsm8k_grpo.yaml
//! trinity bench      --preset tiny --tiers math500s,amcs --tasks 16 --k 4
//! trinity opmd       --steps 400 --group 8
//! trinity trace      --file runs/demo/trace.json
//! trinity doctor     --file runs/demo/trace.json
//! trinity algorithms list
//! trinity info
//! ```


use anyhow::Result;

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::envs::bandit::{run_learning, Bandit, OpmdVariant};
use trinity_rft::runtime::Manifest;
use trinity_rft::trainer::AlgorithmRegistry;
use trinity_rft::util::cli::{arg, arg_default, flag, Cli, CliError};
use trinity_rft::util::timeseries;

fn cli() -> Cli {
    Cli::new("trinity", "Trinity-RFT reproduction — unified RFT over Rust + JAX + Pallas")
        .command(
            "run",
            "run an RFT process from a YAML config and print the run report \
             ([control] runs append a `control` summary line: decision count, \
             admission gate + pressure, live batch tasks, staleness lag, and \
             the last three controller decisions; [qos] runs split the service \
             line per request class: train/eval/interactive submitted, \
             completed, expired, and queue-wait p95)",
            vec![
                arg("config", "path to YAML config"),
                arg("mode", "override mode (both|async|train|bench)"),
                arg("steps", "override total train steps"),
                flag("dummy", "dummy learning (lr = 0, profiling)"),
            ],
        )
        .command(
            "bench",
            "evaluate current weights on benchmark tiers",
            vec![
                arg_default("preset", "model preset", "tiny"),
                arg_default("tiers", "comma-separated tiers", "math500s,amcs,aime24s,aime25s"),
                arg_default("tasks", "tasks per tier", "16"),
                arg_default("k", "rollouts per task (Avg@K)", "4"),
                arg("checkpoint", "load a .ckpt before evaluating"),
            ],
        )
        .command(
            "opmd",
            "Appendix-A OPMD bandit comparison",
            vec![
                arg_default("steps", "learning steps", "400"),
                arg_default("group", "group size K", "8"),
                arg_default("tau", "KL temperature", "1.0"),
                arg_default("staleness", "rollout staleness (0 = on-policy)", "0"),
            ],
        )
        .command(
            "perf",
            "profile the hot paths (per-artifact PJRT timings)",
            vec![
                arg_default("preset", "model preset", "tiny"),
                arg_default("iters", "iterations per artifact", "30"),
            ],
        )
        .command(
            "trace",
            "summarize a trace.json written by a run with [observability] enabled \
             (open the same file in chrome://tracing or Perfetto for the visual timeline)",
            vec![arg("file", "path to the trace.json to summarize")],
        )
        .command(
            "doctor",
            "diagnose where episode wall time went: load a trace.json (or a \
             flight-<n>.json anomaly dump) and attribute every episode's wall \
             clock into queue/prefill/resume/decode/sync/retry/migrate \
             segments; prints the dominant bottleneck per request class and \
             the slowest episodes in detail",
            vec![
                arg("file", "path to a trace.json or flight dump to analyze"),
                arg_default("top", "how many slowest episodes to detail", "5"),
            ],
        )
        .command(
            "algorithms",
            "list the algorithm registry (`trinity algorithms list`)",
            vec![],
        )
        .command("info", "show artifact manifest summary", vec![])
}

fn cmd_algorithms() -> Result<()> {
    let registry = AlgorithmRegistry::global();
    let specs = registry.specs();
    println!("{} registered algorithms:\n", specs.len());
    println!(
        "{:<16} {:<14} {:<16} {:<16} {:<17} {:<16} {:<8} {}",
        "name", "artifact", "advantage", "grouping", "pairing", "loss", "sampler", "tau slot"
    );
    for s in &specs {
        println!(
            "{:<16} {:<14} {:<16} {:<16} {:<17} {:<16} {:<8} {}",
            s.name,
            s.artifact,
            s.advantage.name(),
            s.grouping.as_str(),
            s.pairing.as_str(),
            s.loss.policy.as_str(),
            s.sample.name(),
            s.loss.tau_slot.as_str()
        );
        if !s.about.is_empty() {
            println!("{:<16}   {}", "", s.about);
        }
    }
    println!(
        "\ncustom algorithms: AlgorithmRegistry::global().register(AlgorithmSpec::new(..)) — \
         see examples/mix_algorithm.rs and DESIGN.md §4"
    );
    Ok(())
}

fn cmd_run(m: &trinity_rft::util::cli::Matches) -> Result<()> {
    let mut cfg = match m.get("config") {
        Some(path) => RftConfig::from_file(path)?,
        None => RftConfig::default(),
    };
    if let Some(mode) = m.get("mode") {
        cfg.mode = mode.to_string();
    }
    if let Some(steps) = m.get("steps") {
        cfg.total_steps = steps.parse()?;
    }
    if m.has_flag("dummy") {
        cfg.dummy_learning = true;
    }
    cfg.validate()?;
    println!(
        "mode={} preset={} alg={} steps={} sync_interval={} sync_offset={} explorers={}",
        cfg.mode,
        cfg.model_preset,
        cfg.algorithm,
        cfg.total_steps,
        cfg.sync_interval,
        cfg.sync_offset,
        cfg.explorer_count
    );
    let mut session = RftSession::build(cfg, None, None)?;
    let report = session.run()?;
    println!("\n== run report ==");
    println!("mode            {}", report.mode);
    println!("wall time       {:.2}s", report.wall_s);
    println!("train steps     {}", report.train_steps);
    println!("explore batches {}", report.explore_batches);
    println!("weight syncs    {}", report.sync_count);
    println!("explorer util   {:.1}%", report.explorer_util);
    println!("trainer util    {:.1}%", report.trainer_util);
    println!("device busy     {:.1}%", report.device_busy);
    if let Some(svc) = &report.service {
        println!(
            "service         {} replicas, occupancy {:.2}, queue wait {:.1}ms, \
             {} completed / {} retried / {} expired / {} failed, {} quarantined",
            svc.replicas.len(),
            svc.occupancy(),
            svc.mean_queue_wait_s * 1e3,
            svc.completed,
            svc.retried,
            svc.expired,
            svc.failed,
            svc.quarantined()
        );
        if svc.queue_wait.count > 0 {
            let (p50, p95, p99) = svc.queue_wait.p50_p95_p99();
            println!(
                "queue wait      p50 {:.1}ms / p95 {:.1}ms / p99 {:.1}ms",
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            );
        }
        if svc.rollout.count > 0 {
            let (p50, p95, p99) = svc.rollout.p50_p95_p99();
            println!(
                "rollout latency p50 {:.1}ms / p95 {:.1}ms / p99 {:.1}ms",
                p50 * 1e3,
                p95 * 1e3,
                p99 * 1e3
            );
        }
        // per-class QoS split: only classes that saw traffic, and only
        // when more than one class did (all-train runs keep the old shape)
        let active: Vec<_> = trinity_rft::qos::RequestClass::ALL
            .iter()
            .filter(|c| svc.class_submitted[c.index()] > 0)
            .collect();
        if active.len() > 1 {
            for c in active {
                let i = c.index();
                println!(
                    "class {:<11} {} submitted, {} completed, {} expired, \
                     queue wait p95 {:.1}ms",
                    c.as_str(),
                    svc.class_submitted[i],
                    svc.class_completed[i],
                    svc.class_expired[i],
                    svc.class_queue_wait[i].percentile(0.95) * 1e3
                );
            }
        }
        if let Some(cache) = &svc.cache {
            println!(
                "cache           hit rate {:.0}%, {} prefix tokens reused, \
                 {} prefill tokens saved, {} parked / {} resumed, {} evictions",
                100.0 * cache.hit_rate(),
                cache.reused_tokens,
                cache.saved_prefill_tokens,
                cache.parked,
                cache.resumed,
                cache.trie_evictions + cache.park_evicted
            );
        }
    }
    if report.sample_wait.count > 0 {
        let (p50, p95, p99) = report.sample_wait.p50_p95_p99();
        println!(
            "sample wait     p50 {:.1}ms / p95 {:.1}ms / p99 {:.1}ms",
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3
        );
    }
    if let Some(ctl) = &report.control {
        let lag = match ctl.staleness_lag {
            Some(l) => format!(", staleness lag {l}"),
            None => String::new(),
        };
        println!(
            "control         {} decisions, admission {} (pressure {:.2}), \
             batch tasks {}{}{}",
            ctl.decisions,
            if ctl.admission_open { "open" } else { "closed" },
            ctl.pressure,
            ctl.batch_tasks,
            lag,
            if ctl.stale_holds > 0 {
                format!(", {} stale-gauge holds", ctl.stale_holds)
            } else {
                String::new()
            }
        );
        for d in ctl.recent.iter().rev().take(3).rev() {
            println!(
                "  {:>9}  {} -> {}  ({})",
                d.controller.as_str(),
                d.from,
                d.to,
                d.cause
            );
        }
    }
    if !report.critical_paths.is_empty() {
        println!("critical paths  {} slowest episodes:", report.critical_paths.len());
        for b in &report.critical_paths {
            let (dom, dom_us) = b.dominant();
            println!(
                "  trace {:<6} {:<11} {:>8.1}ms  dominant {} ({:.0}%)",
                b.trace,
                b.class.as_str(),
                b.wall_us as f64 / 1e3,
                dom,
                100.0 * dom_us as f64 / b.wall_us.max(1) as f64
            );
        }
    }
    if let Some(f) = &report.flight {
        if f.triggers > 0 {
            println!(
                "flight          {} anomaly triggers, {} dumps written, {} suppressed",
                f.triggers, f.dumps, f.suppressed
            );
        }
    }
    if let Some(path) = &report.trace_path {
        println!(
            "trace           {} (inspect with `trinity trace --file {0}` or \
             `trinity doctor --file {0}`)",
            path.display()
        );
    }
    let rewards = report.reward_series();
    if !rewards.is_empty() {
        let s = timeseries::summarize(&rewards);
        println!("reward          {}", timeseries::fmt_mean_std(&s));
    }
    session.monitor.flush_csv()?;
    Ok(())
}

fn cmd_trace(m: &trinity_rft::util::cli::Matches) -> Result<()> {
    use trinity_rft::obs::{load_trace, summarize_trace};
    let path = m
        .get("file")
        .ok_or_else(|| anyhow::anyhow!("--file <trace.json> required (see `trinity run` with [observability] enabled)"))?;
    let doc = load_trace(std::path::Path::new(&path))?;
    print!("{}", summarize_trace(&doc)?);
    Ok(())
}

fn cmd_doctor(m: &trinity_rft::util::cli::Matches) -> Result<()> {
    use trinity_rft::obs::{attribute, class_summary, load_trace, spans_from_trace, top_k};
    use trinity_rft::util::json::Value;
    let path = m.get("file").ok_or_else(|| {
        anyhow::anyhow!(
            "--file <trace.json | flight-N.json> required (runs with [observability] \
             enabled write trace.json; anomaly triggers write flight dumps next to it)"
        )
    })?;
    let k = m.get_usize("top", 5);
    let doc = load_trace(std::path::Path::new(&path))?;
    // flight dumps carry an anomaly header in front of the same
    // traceEvents shape a trace.json has
    if let Some(anomaly) = doc.get("anomaly").and_then(Value::as_str) {
        println!("flight dump     anomaly={anomaly}");
        if let Some(detail) = doc.get("detail").and_then(Value::as_str) {
            println!("detail          {detail}");
        }
        if let Some(at) = doc.get("at_s").and_then(Value::as_f64) {
            println!("captured at     {at:.3}s into the run");
        }
        if let Some(digest) = doc.get("config_digest").and_then(Value::as_str) {
            println!("config digest   {digest}");
        }
        println!();
    }
    let spans = spans_from_trace(&doc)?;
    let breakdowns = attribute(&spans);
    if breakdowns.is_empty() {
        println!(
            "no episodes in {path}: only run-plumbing spans (trace 0) or an empty span tail"
        );
        return Ok(());
    }
    let pct = |part: u64, whole: u64| 100.0 * part as f64 / whole.max(1) as f64;
    println!("{} episodes, dominant bottleneck per class:\n", breakdowns.len());
    println!("{:<12} {:>9} {:>12}  {}", "class", "episodes", "wall", "dominant segment");
    for (class, count, wall, segs) in class_summary(&breakdowns) {
        let (dom, dom_us) = segs.into_iter().max_by_key(|&(_, us)| us).unwrap_or(("other", 0));
        println!(
            "{:<12} {:>9} {:>10.1}ms  {} ({:.0}% of wall)",
            class.as_str(),
            count,
            wall as f64 / 1e3,
            dom,
            pct(dom_us, wall)
        );
    }
    let slowest = top_k(&breakdowns, k);
    println!("\n{} slowest episodes:", slowest.len());
    for b in slowest {
        let (dom, dom_us) = b.dominant();
        let mut notes = String::new();
        if b.retries > 0 {
            notes.push_str(&format!(", {} retries", b.retries));
        }
        if b.migrated {
            notes.push_str(", migrated");
        }
        println!(
            "  trace {:<6} {:<11} wall {:>8.1}ms  dominant {} ({:.0}%){}",
            b.trace,
            b.class.as_str(),
            b.wall_us as f64 / 1e3,
            dom,
            pct(dom_us, b.wall_us),
            notes
        );
        let parts: Vec<String> = b
            .segments()
            .iter()
            .filter(|&&(_, us)| us > 0)
            .map(|&(name, us)| format!("{name} {:.1}ms", us as f64 / 1e3))
            .collect();
        println!("                {}", parts.join(" / "));
    }
    Ok(())
}

fn cmd_bench(m: &trinity_rft::util::cli::Matches) -> Result<()> {
    let mut cfg = RftConfig::default();
    cfg.model_preset = m.get_or("preset", "tiny");
    cfg.mode = "bench".into();
    let session = RftSession::build(cfg, None, None)?;
    if let Some(ckpt) = m.get("checkpoint") {
        let ck = trinity_rft::model::load_checkpoint(ckpt)?;
        let (step, version) = (ck.step, ck.weight_version);
        session.load_explorer_snapshot(&ck.into_snapshot(), version)?;
        println!("loaded checkpoint step={step} version={version}");
    }
    let tiers_str = m.get_or("tiers", "math500s,amcs");
    let tiers: Vec<&str> = tiers_str.split(',').collect();
    let reports =
        session.run_bench(&tiers, m.get_usize("tasks", 16), m.get_usize("k", 4), 0.6)?;
    println!("{:<12} {:>8} {:>8} {:>10}", "tier", "Avg@K", "Pass@K", "resp_len");
    for (tier, r) in reports {
        println!(
            "{:<12} {:>8.3} {:>8.3} {:>10.1}",
            tier, r.avg_reward, r.pass_at_k, r.mean_response_len
        );
    }
    Ok(())
}

fn cmd_opmd(m: &trinity_rft::util::cli::Matches) -> Result<()> {
    let steps = m.get_usize("steps", 400);
    let group = m.get_usize("group", 8);
    let tau = m.get_f64("tau", 1.0);
    let staleness = m.get_usize("staleness", 0);
    let bandit = Bandit::new(vec![0.1, 0.3, 0.9, 0.2, 0.5], 0.1);
    println!("bandit arms = {:?}, staleness = {staleness}", bandit.means);
    println!("{:<12} {:>10} {:>10}", "variant", "start", "final");
    for (name, v) in [
        ("kimi", OpmdVariant::Kimi),
        ("pairwise", OpmdVariant::Pairwise),
        ("simple", OpmdVariant::Simple),
        ("vanilla_pg", OpmdVariant::VanillaPg),
    ] {
        let curve = run_learning(v, &bandit, steps, group, 0.3, tau, staleness, 17);
        println!("{:<12} {:>10.3} {:>10.3}", name, curve[0], curve[steps - 1]);
    }
    Ok(())
}

fn cmd_perf(m: &trinity_rft::util::cli::Matches) -> Result<()> {
    use trinity_rft::explorer::{GenerationEngine, RolloutModel, SamplingArgs};
    use trinity_rft::model::ParamStore;
    use trinity_rft::runtime::{ModelEngine, RuntimeClient, Tensor, TrainState};
    use trinity_rft::util::rng::Rng;

    let preset = m.get_or("preset", "tiny");
    let iters = m.get_usize("iters", 30);
    let manifest = Manifest::load_default()
        .ok_or_else(|| anyhow::anyhow!("artifacts not built — run `make artifacts`"))?;
    let client = RuntimeClient::global();
    let engine = std::sync::Arc::new(ModelEngine::new(client.clone(), &manifest, &preset)?);
    engine.warmup()?;
    let params = ParamStore::init(&engine.model, 1)?;
    let (b, t) = engine.seq_shape();
    let mut rng = Rng::new(2);
    let tokens = Tensor::from_i32(
        vec![b, t],
        (0..b * t).map(|_| rng.below(engine.model.vocab_size as u64) as i32).collect(),
    );
    let mask = Tensor::from_f32(vec![b, t], vec![1.0; b * t]);

    // logprobs path
    for _ in 0..iters {
        engine.token_logprobs(&params, &tokens)?;
    }
    // embed path
    for _ in 0..iters {
        engine.embed(&params, &tokens, &mask)?;
    }
    // generation path (prefill + decode loop)
    let gen = GenerationEngine::new(std::sync::Arc::clone(&engine), ParamStore::init(&engine.model, 1)?);
    let prompt: Vec<i32> = vec![1, 10, 11, 12];
    let args = SamplingArgs { max_new_tokens: 8, ..Default::default() };
    let t0 = std::time::Instant::now();
    let mut gen_tokens = 0usize;
    for i in 0..iters {
        let outs = gen.chat(&prompt, b, &SamplingArgs { seed: i as u64, ..args.clone() })?;
        gen_tokens += outs.iter().map(|o| o.tokens.len() - o.prompt_len).sum::<usize>();
    }
    let gen_wall = t0.elapsed().as_secs_f64();
    // train path
    let mut state = TrainState::new(ParamStore::init(&engine.model, 1)?)?;
    let (tb, tt, _) = engine.train_shape("grpo")?;
    let ttokens = Tensor::from_i32(
        vec![tb, tt],
        (0..tb * tt).map(|_| rng.below(engine.model.vocab_size as u64) as i32).collect(),
    );
    let tmask = Tensor::from_f32(vec![tb, tt], {
        let mut v = vec![1.0; tb * tt];
        for i in 0..tb { v[i * tt] = 0.0; }
        v
    });
    let (lp, _) = engine.token_logprobs(&state.params, &ttokens)?;
    let adv = Tensor::from_f32(vec![tb], (0..tb).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect());
    let hyper = [1e-4, 0.9, 0.999, 1e-8, 0.2, 1.0, 0.1, 0.0];
    let t1 = std::time::Instant::now();
    for _ in 0..iters {
        engine.train_step("grpo", &mut state, &hyper, &[&ttokens, &tmask, &adv, &lp])?;
    }
    let train_wall = t1.elapsed().as_secs_f64();

    println!("
== per-artifact PJRT timings ({preset}, {iters} iters) ==");
    let mut stats: Vec<_> = client.stats().into_iter().filter(|(_, s)| s.executions > 0).collect();
    stats.sort_by(|a, b| b.1.total_seconds.partial_cmp(&a.1.total_seconds).unwrap());
    println!("{:<42} {:>8} {:>12} {:>12}", "artifact", "execs", "total (s)", "ms/exec");
    for (name, s) in &stats {
        println!(
            "{:<42} {:>8} {:>12.3} {:>12.3}",
            name,
            s.executions,
            s.total_seconds,
            1000.0 * s.total_seconds / s.executions as f64
        );
    }
    println!("
generation: {:.1} tokens/s end-to-end ({} tokens in {:.2}s, batch {b})",
        gen_tokens as f64 / gen_wall, gen_tokens, gen_wall);
    println!("train: {:.2} steps/s ({} steps in {:.2}s)", iters as f64 / train_wall, iters, train_wall);
    println!("params/step round-trip: {} leaves x3 (p,m,v)", state.params.leaf_count());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let manifest = Manifest::load_default()
        .ok_or_else(|| anyhow::anyhow!("artifacts not built — run `make artifacts`"))?;
    println!("artifacts dir: {:?}", manifest.dir);
    println!("hyper slots: {:?}", manifest.hyper_slots);
    for (name, m) in &manifest.models {
        println!(
            "model {name}: vocab={} d={} layers={} heads={} params={}",
            m.vocab_size, m.d_model, m.n_layers, m.n_heads, m.param_count
        );
    }
    println!("{} artifacts:", manifest.artifacts.len());
    for (name, a) in &manifest.artifacts {
        println!(
            "  {:<40} kind={:<9} b={} t={} alg={}",
            name,
            a.kind,
            a.batch,
            a.seq,
            a.alg.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

fn main() {
    trinity_rft::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let matches = match cli.parse(&args) {
        Ok(m) => m,
        Err(CliError::NoCommand(help)) | Err(CliError::Help(help)) => {
            println!("{help}");
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match matches.command.as_str() {
        "run" => cmd_run(&matches),
        "trace" => cmd_trace(&matches),
        "doctor" => cmd_doctor(&matches),
        "bench" => cmd_bench(&matches),
        "opmd" => cmd_opmd(&matches),
        "perf" => cmd_perf(&matches),
        "algorithms" => cmd_algorithms(),
        "info" => cmd_info(),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
