//! Admission controller: a pressure-driven gate on explorer batch
//! launches.
//!
//! Pressure is the **max** of five normalized components (any one
//! saturated resource should throttle, a "utility" read of the gauges
//! rather than `Free`'s raw `buffer_depth` threshold):
//!
//! * queue-wait p95 over `wait_hi_s`,
//! * queued requests over `queue_hi` per *healthy* replica,
//! * quarantined fraction of the pool over `quarantine_hi`,
//! * buffer depth over `scheduler.max_buffer_depth` (when capped),
//! * per-class queued depth over the `[qos]` class caps (eval and
//!   interactive; bulk train traffic is throttled by the components
//!   above).  Uncapped classes contribute nothing.
//!
//! The gate closes after `hold_ticks` consecutive samples at pressure
//! ≥ 1.0 and reopens after `hold_ticks` consecutive samples at
//! ≤ `release` — asymmetric thresholds (the hysteresis band) so a
//! pressure hovering near the band cannot flap the gate every sample.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::obs::Gauges;

use super::{ControlConfig, ControlContext, Controller, ControllerId, Decision};

pub struct AdmissionController {
    wait_hi_s: f64,
    queue_hi: f64,
    quarantine_hi: f64,
    release: f64,
    hold_ticks: u64,
    replicas: f64,
    max_buffer_depth: f64,
    /// `[qos]` per-class queued-job caps (0 = uncapped), indexed by
    /// `RequestClass::index()`.
    class_caps: [f64; crate::qos::CLASS_COUNT],
    open: AtomicBool,
    streak: AtomicU64,
    /// Last computed pressure, f64 bits (for snapshots).
    pressure_bits: AtomicU64,
}

impl AdmissionController {
    pub fn new(cfg: &ControlConfig, ctx: &ControlContext) -> AdmissionController {
        AdmissionController {
            wait_hi_s: cfg.wait_hi_s,
            queue_hi: cfg.queue_hi,
            quarantine_hi: cfg.quarantine_hi,
            release: cfg.release,
            hold_ticks: cfg.hold_ticks.max(1),
            replicas: ctx.replicas.max(1) as f64,
            max_buffer_depth: ctx.max_buffer_depth as f64,
            class_caps: ctx.class_caps.map(|c| c as f64),
            open: AtomicBool::new(true),
            streak: AtomicU64::new(0),
            pressure_bits: AtomicU64::new(0),
        }
    }

    /// Normalized serving pressure for one sample (1.0 = at band).
    pub fn pressure_of(&self, g: &Gauges) -> f64 {
        let healthy = (self.replicas - g.quarantined).max(1.0);
        let wait = g.queue_wait_p95_s / self.wait_hi_s;
        let depth = g.queued / (self.queue_hi * healthy);
        let quarantine = (g.quarantined / self.replicas) / self.quarantine_hi;
        let buffer = if self.max_buffer_depth > 0.0 {
            g.buffer_depth / self.max_buffer_depth
        } else {
            0.0
        };
        let mut class = 0.0f64;
        let eval_cap = self.class_caps[crate::qos::RequestClass::Eval.index()];
        if eval_cap > 0.0 {
            class = class.max(g.eval_queued / eval_cap);
        }
        let inter_cap = self.class_caps[crate::qos::RequestClass::Interactive.index()];
        if inter_cap > 0.0 {
            class = class.max(g.interactive_queued / inter_cap);
        }
        wait.max(depth).max(quarantine).max(buffer).max(class)
    }

    /// Whether batch launches are currently admitted.
    pub fn open(&self) -> bool {
        self.open.load(Ordering::Relaxed)
    }

    /// The pressure computed on the last step.
    pub fn pressure(&self) -> f64 {
        f64::from_bits(self.pressure_bits.load(Ordering::Relaxed))
    }
}

impl Controller for AdmissionController {
    fn id(&self) -> ControllerId {
        ControllerId::Admission
    }

    fn bounds(&self) -> (f64, f64) {
        (0.0, 1.0)
    }

    fn output(&self) -> f64 {
        if self.open() {
            1.0
        } else {
            0.0
        }
    }

    fn step(&self, g: &Gauges) -> Option<Decision> {
        let pressure = self.pressure_of(g);
        self.pressure_bits.store(pressure.to_bits(), Ordering::Relaxed);
        let open = self.open();
        let out_of_band = if open { pressure >= 1.0 } else { pressure <= self.release };
        if !out_of_band {
            self.streak.store(0, Ordering::Relaxed);
            return None;
        }
        let streak = self.streak.fetch_add(1, Ordering::Relaxed) + 1;
        if streak < self.hold_ticks {
            return None;
        }
        self.streak.store(0, Ordering::Relaxed);
        self.open.store(!open, Ordering::Relaxed);
        Some(Decision {
            controller: ControllerId::Admission,
            at_s: g.at_s,
            from: if open { 1.0 } else { 0.0 },
            to: if open { 0.0 } else { 1.0 },
            cause: if open { "pressure over band" } else { "pressure released" },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(hold: u64, max_buffer: u64) -> AdmissionController {
        let cfg = ControlConfig { hold_ticks: hold, ..Default::default() };
        let ctx = ControlContext {
            replicas: 4,
            session_rows: 8,
            repeat_times: 2,
            explorer_count: 1,
            batch_tasks: 4,
            max_buffer_depth: max_buffer,
            class_caps: [0; crate::qos::CLASS_COUNT],
        };
        AdmissionController::new(&cfg, &ctx)
    }

    #[test]
    fn pressure_is_the_max_normalized_component() {
        let c = controller(1, 100);
        // defaults: wait_hi 0.25s, queue_hi 4/healthy, quarantine_hi 0.5
        let g = Gauges {
            queue_wait_p95_s: 0.125, // 0.5 of band
            queued: 8.0,             // 8 / (4*3 healthy) = 0.667
            quarantined: 1.0,        // (1/4)/0.5 = 0.5
            buffer_depth: 90.0,      // 0.9 of the cap -> the max
            ..Default::default()
        };
        let p = c.pressure_of(&g);
        assert!((p - 0.9).abs() < 1e-9, "expected buffer component to win, got {p}");
        // uncapped buffer contributes nothing
        let c2 = controller(1, 0);
        assert!(c2.pressure_of(&g) < 0.7);
    }

    #[test]
    fn class_caps_feed_pressure_only_when_set() {
        let cfg = ControlConfig { hold_ticks: 1, ..Default::default() };
        let mut ctx = ControlContext {
            replicas: 4,
            session_rows: 8,
            repeat_times: 2,
            explorer_count: 1,
            batch_tasks: 4,
            max_buffer_depth: 0,
            class_caps: [0; crate::qos::CLASS_COUNT],
        };
        let g = Gauges { eval_queued: 12.0, interactive_queued: 3.0, ..Default::default() };
        let uncapped = AdmissionController::new(&cfg, &ctx);
        assert_eq!(uncapped.pressure_of(&g), 0.0, "uncapped classes contribute nothing");

        ctx.class_caps[crate::qos::RequestClass::Eval.index()] = 8;
        ctx.class_caps[crate::qos::RequestClass::Interactive.index()] = 6;
        let capped = AdmissionController::new(&cfg, &ctx);
        // eval 12/8 = 1.5 dominates interactive 3/6 = 0.5
        assert!((capped.pressure_of(&g) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn hysteresis_requires_hold_ticks_and_release_band() {
        let c = controller(2, 0);
        let hot = Gauges { queue_wait_p95_s: 1.0, ..Default::default() }; // pressure 4.0
        let warm = Gauges { queue_wait_p95_s: 0.2, ..Default::default() }; // pressure 0.8
        let cool = Gauges { queue_wait_p95_s: 0.05, ..Default::default() }; // pressure 0.2

        assert!(c.step(&hot).is_none(), "one hot sample is not enough");
        let d = c.step(&hot).expect("second consecutive hot sample closes");
        assert_eq!((d.from, d.to), (1.0, 0.0));
        assert!(!c.open());

        // 0.8 is under the close band but above release (0.7): the gate
        // must stay closed — that is the hysteresis band
        assert!(c.step(&warm).is_none());
        assert!(c.step(&warm).is_none());
        assert!(!c.open(), "pressure inside the hysteresis band must not reopen");

        // a hot sample between cool ones resets the release streak
        assert!(c.step(&cool).is_none());
        assert!(c.step(&hot).is_none());
        assert!(c.step(&cool).is_none());
        let d = c.step(&cool).expect("two consecutive cool samples reopen");
        assert_eq!((d.from, d.to), (0.0, 1.0));
        assert!(c.open());
        assert_eq!(d.cause, "pressure released");
    }

    #[test]
    fn output_reflects_the_gate_within_bounds() {
        let c = controller(1, 0);
        assert_eq!(c.output(), 1.0);
        let (lo, hi) = c.bounds();
        assert!(lo <= c.output() && c.output() <= hi);
        c.step(&Gauges { queue_wait_p95_s: 9.0, ..Default::default() });
        assert_eq!(c.output(), 0.0);
    }
}
