//! Adaptive staleness: `BoundedStaleness` whose version-lag window is a
//! controller output instead of a hand-tuned constant.
//!
//! The signal is the trainer's starvation ratio — `sample_wait` p95
//! measured against rollout latency p95 (both published as gauges).  A
//! trainer that waits a large fraction of a rollout per step is starved
//! by the admission gate; one that never waits is paying off-policyness
//! for nothing.  The window moves AIMD-style between those bands:
//!
//! * **widen +1** (additive) after `hold_ticks` consecutive samples
//!   with `wait_p95 > staleness_hi × rollout_p95` — starvation earns
//!   staleness one window at a time;
//! * **narrow ÷2** (multiplicative) after `hold_ticks` consecutive
//!   samples with `wait_p95 < staleness_lo × rollout_p95` — comfort
//!   gives staleness back quickly, biasing the run on-policy;
//! * waits under `staleness_floor_s` never count as starvation, so
//!   µs-scale scheduling noise cannot widen the window.
//!
//! The output is clamped to `[0, max_version_lag]`: the static cap
//! becomes the *ceiling* the controller works under.  When `[control]`
//! is disabled the window pins at that ceiling and the policy is
//! byte-identical to `BoundedStaleness { max_version_lag }`; when
//! enabled it slow-starts at `min(1, max_version_lag)` and earns the
//! rest from evidence.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::config::RftConfig;
use crate::coordinator::policy::{ExplorerPlan, Progress, SyncPolicy};
use crate::obs::Gauges;

use super::{ControlConfig, ControlPlane, Controller, ControllerId, Decision};

/// The controller half of [`AdaptiveStaleness`]: owns the live lag and
/// is stepped by the [`ControlPlane`] once per fresh gauge sample.
pub struct StalenessCore {
    max_lag: u64,
    hi: f64,
    lo: f64,
    floor_s: f64,
    hold_ticks: u64,
    lag: AtomicU64,
    streak_widen: AtomicU64,
    streak_narrow: AtomicU64,
}

impl StalenessCore {
    pub fn new(max_lag: u64, ctl: &ControlConfig) -> StalenessCore {
        StalenessCore {
            max_lag,
            hi: ctl.staleness_hi,
            lo: ctl.staleness_lo,
            floor_s: ctl.staleness_floor_s,
            hold_ticks: ctl.hold_ticks.max(1),
            // uncontrolled default: pin at the ceiling (= BoundedStaleness)
            lag: AtomicU64::new(max_lag),
            streak_widen: AtomicU64::new(0),
            streak_narrow: AtomicU64::new(0),
        }
    }

    /// The live version-lag window.
    pub fn lag(&self) -> u64 {
        self.lag.load(Ordering::Relaxed)
    }

    /// Switch from the pinned ceiling to closed-loop control: slow-start
    /// at one window and earn the rest from observed starvation.
    pub fn enable(&self) {
        self.lag.store(1.min(self.max_lag), Ordering::Relaxed);
    }
}

impl Controller for StalenessCore {
    fn id(&self) -> ControllerId {
        ControllerId::Staleness
    }

    fn bounds(&self) -> (f64, f64) {
        (0.0, self.max_lag as f64)
    }

    fn output(&self) -> f64 {
        self.lag() as f64
    }

    fn step(&self, g: &Gauges) -> Option<Decision> {
        let wait = g.sample_wait_p95_s;
        // reference scale: one rollout, floored so a near-idle service
        // cannot make the bands degenerate
        let reference = g.rollout_p95_s.max(self.floor_s);
        let cur = self.lag.load(Ordering::Relaxed);
        let next = if wait > (self.hi * reference).max(self.floor_s) {
            self.streak_narrow.store(0, Ordering::Relaxed);
            if self.streak_widen.fetch_add(1, Ordering::Relaxed) + 1 < self.hold_ticks {
                return None;
            }
            cur.saturating_add(1).min(self.max_lag) // additive widen
        } else if wait < self.lo * reference {
            self.streak_widen.store(0, Ordering::Relaxed);
            if self.streak_narrow.fetch_add(1, Ordering::Relaxed) + 1 < self.hold_ticks {
                return None;
            }
            cur / 2 // multiplicative narrow
        } else {
            self.streak_widen.store(0, Ordering::Relaxed);
            self.streak_narrow.store(0, Ordering::Relaxed);
            return None;
        };
        self.streak_widen.store(0, Ordering::Relaxed);
        self.streak_narrow.store(0, Ordering::Relaxed);
        if next == cur {
            return None;
        }
        self.lag.store(next, Ordering::Relaxed);
        Some(Decision {
            controller: ControllerId::Staleness,
            at_s: g.at_s,
            from: cur as f64,
            to: next as f64,
            cause: if next > cur { "trainer starved: widen" } else { "trainer fed: narrow" },
        })
    }
}

/// The registered `SyncPolicy` (`scheduler.policy = "adaptive"`):
/// [`BoundedStaleness`](crate::coordinator::BoundedStaleness) admission
/// over the [`StalenessCore`]'s live window.
pub struct AdaptiveStaleness {
    interval: u64,
    core: Arc<StalenessCore>,
}

impl AdaptiveStaleness {
    pub fn from_cfg(cfg: &RftConfig) -> AdaptiveStaleness {
        AdaptiveStaleness {
            interval: cfg.sync_interval.max(1),
            core: Arc::new(StalenessCore::new(
                cfg.scheduler.max_version_lag,
                &cfg.control.to_control_config(),
            )),
        }
    }

    /// The controller half (tests and the plane hold it directly).
    pub fn core(&self) -> &Arc<StalenessCore> {
        &self.core
    }
}

impl SyncPolicy for AdaptiveStaleness {
    fn label(&self, explorer_count: usize) -> String {
        format!(
            "adaptive(i={},lag<={},x{explorer_count})",
            self.interval,
            self.core.max_lag
        )
    }
    fn explorer_plan(&self, _total_steps: u64) -> ExplorerPlan {
        ExplorerPlan::FreeRun
    }
    fn admit(&self, batch: u64, progress: Progress) -> bool {
        batch / self.interval <= progress.published_windows + self.core.lag()
    }
    fn publish_after(&self, steps_done: u64) -> bool {
        steps_done % self.interval == 0
    }
    fn version_lag(&self, batch: u64, weight_version: u64) -> u64 {
        (batch / self.interval).saturating_sub(weight_version)
    }
    fn connect_control(&self, plane: &Arc<ControlPlane>) {
        self.core.enable();
        plane.adopt_staleness(Arc::clone(&self.core) as Arc<dyn Controller>);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::resolve_policy;

    fn core(max_lag: u64, hold: u64) -> StalenessCore {
        let ctl = ControlConfig {
            hold_ticks: hold,
            staleness_hi: 0.5,
            staleness_lo: 0.1,
            staleness_floor_s: 0.005,
            ..Default::default()
        };
        StalenessCore::new(max_lag, &ctl)
    }

    fn sample(wait: f64, rollout: f64) -> Gauges {
        Gauges { sample_wait_p95_s: wait, rollout_p95_s: rollout, ..Default::default() }
    }

    #[test]
    fn disabled_core_pins_at_the_ceiling() {
        let c = core(3, 1);
        assert_eq!(c.lag(), 3, "uncontrolled = BoundedStaleness(max_version_lag)");
        c.enable();
        assert_eq!(c.lag(), 1, "enabled control slow-starts at one window");
        assert_eq!(core(0, 1).lag(), 0, "ceiling 0 stays 0");
    }

    #[test]
    fn widens_additively_under_starvation_and_clamps() {
        let c = core(3, 1);
        c.enable();
        let starved = sample(2.0, 1.0); // wait = 2x rollout >> hi band
        let d = c.step(&starved).expect("starvation widens");
        assert_eq!((d.from, d.to), (1.0, 2.0));
        assert_eq!(d.cause, "trainer starved: widen");
        c.step(&starved);
        assert_eq!(c.lag(), 3);
        assert!(c.step(&starved).is_none(), "clamped at max_version_lag");
        assert_eq!(c.lag(), 3);
        assert_eq!(c.bounds(), (0.0, 3.0));
    }

    #[test]
    fn narrows_multiplicatively_when_comfortable() {
        let c = core(8, 1);
        // pinned at 8; comfort: wait far under lo * rollout
        let comfy = sample(0.01, 1.0);
        let d = c.step(&comfy).expect("comfort narrows");
        assert_eq!((d.from, d.to), (8.0, 4.0), "halving, not -1");
        assert_eq!(d.cause, "trainer fed: narrow");
        c.step(&comfy);
        c.step(&comfy);
        c.step(&comfy);
        assert_eq!(c.lag(), 0, "8 -> 4 -> 2 -> 1 -> 0");
        assert!(c.step(&comfy).is_none());
    }

    #[test]
    fn in_band_and_sub_floor_waits_hold_the_window() {
        let c = core(8, 1);
        c.enable();
        // between lo and hi: hold
        assert!(c.step(&sample(0.3, 1.0)).is_none());
        assert_eq!(c.lag(), 1);
        // over hi ratio but under the absolute floor: scheduling noise,
        // must not widen
        assert!(c.step(&sample(0.004, 0.001)).is_none());
        assert_eq!(c.lag(), 1);
    }

    #[test]
    fn hold_ticks_require_consecutive_evidence() {
        let c = core(4, 2);
        c.enable();
        let starved = sample(2.0, 1.0);
        let in_band = sample(0.3, 1.0);
        assert!(c.step(&starved).is_none(), "first out-of-band sample held");
        assert!(c.step(&in_band).is_none(), "in-band resets the streak");
        assert!(c.step(&starved).is_none());
        assert!(c.step(&starved).is_some(), "second consecutive sample acts");
        assert_eq!(c.lag(), 2);
    }

    #[test]
    fn adaptive_policy_admission_tracks_the_live_window() {
        let mut cfg = RftConfig::default();
        cfg.sync_interval = 1;
        cfg.scheduler.max_version_lag = 4;
        let p = AdaptiveStaleness::from_cfg(&cfg);
        let at = |published_windows| Progress { published_windows, ..Default::default() };
        // uncontrolled: behaves as BoundedStaleness(4)
        assert!(p.admit(4, at(0)));
        assert!(!p.admit(5, at(0)));
        assert_eq!(p.version_lag(6, 2), 4);
        // enabled: slow-start at 1
        p.core().enable();
        assert!(p.admit(1, at(0)));
        assert!(!p.admit(2, at(0)), "window shrank to the slow-start lag");
        // widening reopens admission without a publish
        p.core().step(&sample(2.0, 1.0));
        p.core().step(&sample(2.0, 1.0));
        assert!(p.admit(3, at(0)));
        assert!(p.label(2).contains("adaptive(i=1,lag<=4,x2)"), "{}", p.label(2));
        assert_eq!(p.explorer_plan(9), ExplorerPlan::FreeRun);
        assert!(p.publish_after(1) && p.publish_after(2));
    }

    #[test]
    fn adaptive_registers_in_the_policy_registry() {
        let mut cfg = RftConfig::default();
        cfg.scheduler.policy = Some("Adaptive".into());
        cfg.sync_interval = 2;
        cfg.scheduler.max_version_lag = 3;
        let p = resolve_policy(&cfg).unwrap();
        assert_eq!(p.label(1), "adaptive(i=2,lag<=3,x1)");
    }
}
