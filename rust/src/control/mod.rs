//! Adaptive control plane (DESIGN.md §9): feedback controllers that
//! close the loop between the observability plane and run-time policy.
//!
//! PR 6 made serving telemetry *readable* — [`TelemetryHub`] publishes
//! live gauges on a cadence.  This module adds the controllers that act
//! on them, replacing three hand-tuned knobs with closed loops:
//!
//! * **staleness** — `AdaptiveStaleness` (a registered `SyncPolicy`,
//!   `policy = "adaptive"`) widens/narrows the effective version-lag
//!   window AIMD-style from the trainer's `sample_wait` p95 measured
//!   against rollout latency, clamped to `[0, max_version_lag]`;
//! * **admission** — throttles explorer batch launches when serving
//!   pressure (queue-wait p95, queue depth, quarantined replicas,
//!   buffer depth) crosses configured bands;
//! * **capacity** — adapts per-driver batch-task counts to live healthy
//!   replica capacity.
//!
//! All three implement the shared [`Controller`] trait: outputs are
//! **bounded** (clamped to [`Controller::bounds`]) and **hysteresis
//! damped** (a controller acts only after `hold_ticks` consecutive
//! out-of-band gauge samples, and never more than once per sample), so
//! a noisy gauge cannot make the plane thrash.  Every output change is
//! appended to the [`DecisionLog`], mirrored as a
//! `SpanKind::ControlDecision` mark when tracing is on, logged under
//! the `control` monitor role at publish boundaries, and summarized on
//! the `trinity run` report line.
//!
//! Staleness of the *signal* is handled explicitly: if the latest gauge
//! sample is older than `max_gauge_age_s`, controllers hold their last
//! output instead of acting on dead data (warn-once per stale episode;
//! see [`TelemetryHub::age_s`]).
//!
//! Everything is gated behind the `[control]` config section and off by
//! default: with it absent no [`ControlPlane`] is built and every run
//! behaves byte-identically to the uncontrolled scheduler.

pub mod admission;
pub mod capacity;
pub mod staleness;

pub use admission::AdmissionController;
pub use capacity::CapacityController;
pub use staleness::{AdaptiveStaleness, StalenessCore};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use crate::log_warn;
use crate::obs::{FlightSource, Gauges, SpanKind, SpanRecorder, TelemetryHub, NO_REPLICA};
use crate::util::json::Value;

/// Typed `[control]` knobs (`ControlSection` in the run config converts
/// into this).  Band semantics:
///
/// * staleness: widen when `sample_wait_p95 > staleness_hi * rollout_p95`,
///   narrow when it drops under `staleness_lo * rollout_p95`; waits under
///   `staleness_floor_s` never count as starvation.
/// * admission: close the gate when normalized pressure reaches 1.0,
///   reopen when it falls to `release`.
/// * capacity: steer per-driver batch tasks toward
///   `healthy_replicas * session_rows * capacity_headroom` rows.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Master switch: off = no plane, byte-identical scheduling.
    pub enabled: bool,
    /// Hold controller outputs when the latest gauge sample is older.
    pub max_gauge_age_s: f64,
    /// Decisions retained for the report (total count is unbounded).
    pub log_capacity: usize,
    /// Consecutive out-of-band samples required before any output moves.
    pub hold_ticks: u64,
    /// Starvation band: widen staleness above this fraction of rollout p95.
    pub staleness_hi: f64,
    /// Comfort band: narrow staleness below this fraction of rollout p95.
    pub staleness_lo: f64,
    /// Absolute sample-wait floor treated as noise, seconds.
    pub staleness_floor_s: f64,
    /// Queue-wait p95 mapping to pressure 1.0, seconds.
    pub wait_hi_s: f64,
    /// Queued requests per healthy replica mapping to pressure 1.0.
    pub queue_hi: f64,
    /// Quarantined fraction of the pool mapping to pressure 1.0.
    pub quarantine_hi: f64,
    /// Pressure level at which a closed admission gate reopens.
    pub release: f64,
    /// Rows of headroom (× live capacity) the capacity controller targets.
    pub capacity_headroom: f64,
    /// Lower clamp for per-driver batch tasks.
    pub min_batch_tasks: usize,
    /// Upper clamp for per-driver batch tasks (0 = the configured
    /// `batch_tasks`).
    pub max_batch_tasks: usize,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            enabled: false,
            max_gauge_age_s: 2.0,
            log_capacity: 256,
            hold_ticks: 2,
            staleness_hi: 0.5,
            staleness_lo: 0.1,
            staleness_floor_s: 0.005,
            wait_hi_s: 0.25,
            queue_hi: 4.0,
            quarantine_hi: 0.5,
            release: 0.7,
            capacity_headroom: 2.0,
            min_batch_tasks: 1,
            max_batch_tasks: 0,
        }
    }
}

impl ControlConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.max_gauge_age_s <= 0.0 {
            bail!("control.max_gauge_age_s must be > 0");
        }
        if self.log_capacity == 0 {
            bail!("control.log_capacity must be >= 1");
        }
        if self.hold_ticks == 0 {
            bail!("control.hold_ticks must be >= 1");
        }
        if self.staleness_hi <= self.staleness_lo || self.staleness_lo < 0.0 {
            bail!(
                "control.staleness bands must satisfy 0 <= lo < hi (got lo={}, hi={})",
                self.staleness_lo,
                self.staleness_hi
            );
        }
        if self.staleness_floor_s < 0.0 {
            bail!("control.staleness_floor_s must be >= 0");
        }
        if self.wait_hi_s <= 0.0 || self.queue_hi <= 0.0 {
            bail!("control.wait_hi_s and control.queue_hi must be > 0");
        }
        if self.quarantine_hi <= 0.0 || self.quarantine_hi > 1.0 {
            bail!("control.quarantine_hi must be in (0, 1]");
        }
        if self.release <= 0.0 || self.release >= 1.0 {
            bail!("control.release must be in (0, 1)");
        }
        if self.capacity_headroom <= 0.0 {
            bail!("control.capacity_headroom must be > 0");
        }
        if self.min_batch_tasks == 0 {
            bail!("control.min_batch_tasks must be >= 1");
        }
        if self.max_batch_tasks != 0 && self.max_batch_tasks < self.min_batch_tasks {
            bail!("control.max_batch_tasks must be 0 or >= control.min_batch_tasks");
        }
        Ok(())
    }
}

/// Which controller produced a [`Decision`].  Discriminants are stable:
/// they are packed into `ControlDecision` span details.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum ControllerId {
    Staleness = 1,
    Admission = 2,
    Capacity = 3,
}

impl ControllerId {
    pub fn as_str(&self) -> &'static str {
        match self {
            ControllerId::Staleness => "staleness",
            ControllerId::Admission => "admission",
            ControllerId::Capacity => "capacity",
        }
    }
}

/// One output change: which controller moved, from what to what, and why.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub controller: ControllerId,
    /// Gauge timestamp the controller acted on (hub-relative seconds).
    pub at_s: f64,
    pub from: f64,
    pub to: f64,
    pub cause: &'static str,
}

impl Decision {
    /// Span payload: controller id in the high 32 bits, the new output
    /// (rounded, clamped at 0) in the low 32.
    pub fn detail(&self) -> u64 {
        ((self.controller as u64) << 32) | (self.to.max(0.0).round() as u64 & 0xffff_ffff)
    }
}

/// A feedback controller with a bounded, hysteresis-damped output.
///
/// `step` is called by the [`ControlPlane`] at most once per fresh gauge
/// sample; implementations keep their own out-of-band streak counters
/// and return a [`Decision`] only when the output actually moved.
pub trait Controller: Send + Sync {
    fn id(&self) -> ControllerId;
    /// Inclusive `[lo, hi]` output clamp; `output` never leaves it.
    fn bounds(&self) -> (f64, f64);
    /// The current (last) output.
    fn output(&self) -> f64;
    /// One damped control step over a fresh gauge sample.
    fn step(&self, g: &Gauges) -> Option<Decision>;
}

/// Bounded ring of recent [`Decision`]s plus a lifetime count; every
/// push is mirrored as a `ControlDecision` span mark when tracing is on.
pub struct DecisionLog {
    cap: usize,
    recent: Mutex<VecDeque<Decision>>,
    total: AtomicU64,
    obs: Option<Arc<SpanRecorder>>,
}

impl DecisionLog {
    pub fn new(cap: usize, obs: Option<Arc<SpanRecorder>>) -> DecisionLog {
        DecisionLog {
            cap: cap.max(1),
            recent: Mutex::new(VecDeque::new()),
            total: AtomicU64::new(0),
            obs,
        }
    }

    pub fn push(&self, d: Decision) {
        self.total.fetch_add(1, Ordering::Relaxed);
        if let Some(obs) = &self.obs {
            obs.mark(0, SpanKind::ControlDecision, NO_REPLICA, d.detail());
        }
        let mut recent = self.recent.lock().unwrap();
        if recent.len() == self.cap {
            recent.pop_front();
        }
        recent.push_back(d);
    }

    /// Decisions pushed over the log's lifetime (including evicted ones).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// The retained tail, oldest first.
    pub fn recent(&self) -> Vec<Decision> {
        self.recent.lock().unwrap().iter().copied().collect()
    }
}

/// Static run shape the controllers steer within (replica pool size,
/// rows per engine session, configured batch/task fan-out).
#[derive(Debug, Clone, Copy)]
pub struct ControlContext {
    /// Serving replicas in the pool.
    pub replicas: usize,
    /// Rows one engine session can pack (service `max_batch`, or the
    /// engine's native generation batch when unlimited).
    pub session_rows: usize,
    /// Rollouts per task (`repeat_times`).
    pub repeat_times: usize,
    /// Concurrent explorer drivers.
    pub explorer_count: usize,
    /// Configured per-driver batch tasks (the capacity controller's
    /// starting point and default upper clamp).
    pub batch_tasks: usize,
    /// `scheduler.max_buffer_depth` (0 = uncapped); feeds admission
    /// pressure so the gate subsumes `Free`'s raw depth check.
    pub max_buffer_depth: u64,
    /// `[qos]` per-class queued-job caps (0 = uncapped), indexed by
    /// `RequestClass::index()`; feeds admission pressure.
    pub class_caps: [usize; crate::qos::CLASS_COUNT],
}

/// Everything a run's controllers share: the gauge feed, the decision
/// log, and the three controller instances.
///
/// The plane steps controllers lazily from its read paths
/// ([`ControlPlane::admit`] / [`ControlPlane::batch_tasks`]): a CAS on
/// the gauge tick guarantees each fresh sample is processed exactly
/// once no matter how many explorer drivers are polling.
pub struct ControlPlane {
    cfg: ControlConfig,
    hub: Arc<TelemetryHub>,
    log: DecisionLog,
    admission: AdmissionController,
    capacity: CapacityController,
    staleness: OnceLock<Arc<dyn Controller>>,
    last_tick: AtomicU64,
    stale_holds: AtomicU64,
    stale: AtomicBool,
}

impl ControlPlane {
    pub fn new(
        cfg: ControlConfig,
        ctx: ControlContext,
        hub: Arc<TelemetryHub>,
        obs: Option<Arc<SpanRecorder>>,
    ) -> Arc<ControlPlane> {
        Arc::new(ControlPlane {
            log: DecisionLog::new(cfg.log_capacity, obs),
            admission: AdmissionController::new(&cfg, &ctx),
            capacity: CapacityController::new(&cfg, &ctx),
            staleness: OnceLock::new(),
            last_tick: AtomicU64::new(0),
            stale_holds: AtomicU64::new(0),
            stale: AtomicBool::new(false),
            cfg,
            hub,
        })
    }

    pub fn config(&self) -> &ControlConfig {
        &self.cfg
    }

    pub fn hub(&self) -> &Arc<TelemetryHub> {
        &self.hub
    }

    pub fn decisions(&self) -> &DecisionLog {
        &self.log
    }

    /// Register the staleness controller (called by
    /// `AdaptiveStaleness::connect_control`; at most one per plane).
    pub fn adopt_staleness(&self, c: Arc<dyn Controller>) {
        let _ = self.staleness.set(c);
    }

    /// Step every controller over the latest gauge sample, at most once
    /// per publish tick.  Returns without acting when the sample is
    /// stale (holding the last outputs) or already processed.
    pub fn tick(&self) {
        let g = self.hub.gauges();
        let tick = g.tick as u64;
        if tick == 0 {
            return; // nothing published yet
        }
        let age = self.hub.age_s();
        if age > self.cfg.max_gauge_age_s {
            // hold last outputs; warn once per stale episode
            if !self.stale.swap(true, Ordering::Relaxed) {
                self.stale_holds.fetch_add(1, Ordering::Relaxed);
                log_warn!(
                    "control",
                    "gauges stale ({age:.1}s > {:.1}s): holding controller outputs",
                    self.cfg.max_gauge_age_s
                );
            }
            return;
        }
        self.stale.store(false, Ordering::Relaxed);
        let last = self.last_tick.load(Ordering::Relaxed);
        if tick <= last
            || self
                .last_tick
                .compare_exchange(last, tick, Ordering::Relaxed, Ordering::Relaxed)
                .is_err()
        {
            return; // sample already processed (or a racer won it)
        }
        if let Some(d) = self.admission.step(&g) {
            self.log.push(d);
        }
        if let Some(d) = self.capacity.step(&g) {
            self.log.push(d);
        }
        if let Some(c) = self.staleness.get() {
            if let Some(d) = c.step(&g) {
                self.log.push(d);
            }
        }
    }

    /// Admission gate for explorer drivers: `false` = serving pressure
    /// is over band, hold the next batch launch.
    pub fn admit(&self) -> bool {
        self.tick();
        self.admission.open()
    }

    /// Per-driver batch-task count steered to live replica capacity.
    pub fn batch_tasks(&self) -> usize {
        self.tick();
        self.capacity.tasks()
    }

    /// Times controllers entered a stale-gauge hold.
    pub fn stale_holds(&self) -> u64 {
        self.stale_holds.load(Ordering::Relaxed)
    }

    /// Wrap this plane as a flight-recorder evidence source: every dump
    /// then carries the retained decision ring, so a post-mortem can see
    /// what the controllers did in the window before the anomaly.
    pub fn flight_source(self: &Arc<Self>) -> Arc<DecisionSource> {
        Arc::new(DecisionSource { plane: Arc::clone(self) })
    }

    pub fn snapshot(&self) -> ControlSnapshot {
        ControlSnapshot {
            decisions: self.log.total(),
            stale_holds: self.stale_holds(),
            admission_open: self.admission.open(),
            pressure: self.admission.pressure(),
            batch_tasks: self.capacity.tasks(),
            staleness_lag: self.staleness.get().map(|c| c.output().round() as u64),
            recent: self.log.recent(),
        }
    }
}

/// Point-in-time controller state; rides in `ModeReport.control` and
/// feeds the monitor's `control/...` series.
#[derive(Debug, Clone)]
pub struct ControlSnapshot {
    /// Output changes over the run.
    pub decisions: u64,
    /// Stale-gauge hold episodes.
    pub stale_holds: u64,
    /// Whether explorer batch launches are currently admitted.
    pub admission_open: bool,
    /// Last normalized serving pressure (1.0 = at band).
    pub pressure: f64,
    /// Current per-driver batch-task output.
    pub batch_tasks: usize,
    /// Current staleness window, when an adaptive policy is registered.
    pub staleness_lag: Option<u64>,
    /// Retained decision tail, oldest first.
    pub recent: Vec<Decision>,
}

impl ControlSnapshot {
    /// Flat `(key, value)` series for the monitor's `control` role.
    pub fn monitor_fields(&self) -> Vec<(String, f64)> {
        let mut out = vec![
            ("control/decisions".to_string(), self.decisions as f64),
            ("control/admission_open".to_string(), if self.admission_open { 1.0 } else { 0.0 }),
            ("control/pressure".to_string(), self.pressure),
            ("control/batch_tasks".to_string(), self.batch_tasks as f64),
            ("control/stale_holds".to_string(), self.stale_holds as f64),
        ];
        if let Some(lag) = self.staleness_lag {
            out.push(("control/staleness_lag".to_string(), lag as f64));
        }
        out
    }
}

/// Flight-dump evidence section: the `[control]` decision ring as JSON
/// (see [`ControlPlane::flight_source`]).
pub struct DecisionSource {
    plane: Arc<ControlPlane>,
}

impl FlightSource for DecisionSource {
    fn name(&self) -> &'static str {
        "control"
    }

    fn collect(&self) -> Value {
        let log = self.plane.decisions();
        let recent = log
            .recent()
            .iter()
            .map(|d| {
                Value::obj(vec![
                    ("controller", Value::str(d.controller.as_str())),
                    ("at_s", Value::num(d.at_s)),
                    ("from", Value::num(d.from)),
                    ("to", Value::num(d.to)),
                    ("cause", Value::str(d.cause)),
                ])
            })
            .collect();
        Value::obj(vec![
            ("total", Value::int(log.total() as i64)),
            ("stale_holds", Value::int(self.plane.stale_holds() as i64)),
            ("recent", Value::arr(recent)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn ctx() -> ControlContext {
        ControlContext {
            replicas: 2,
            session_rows: 4,
            repeat_times: 2,
            explorer_count: 1,
            batch_tasks: 4,
            max_buffer_depth: 0,
            class_caps: [0; crate::qos::CLASS_COUNT],
        }
    }

    fn enabled_cfg() -> ControlConfig {
        ControlConfig { enabled: true, ..Default::default() }
    }

    #[test]
    fn config_defaults_off_and_validation_bands() {
        let d = ControlConfig::default();
        assert!(!d.enabled);
        assert!(d.validate().is_ok());
        let mut on = enabled_cfg();
        assert!(on.validate().is_ok());
        on.staleness_lo = 0.9; // lo >= hi
        assert!(on.validate().is_err());
        let mut on = enabled_cfg();
        on.release = 1.0;
        assert!(on.validate().is_err());
        let mut on = enabled_cfg();
        on.hold_ticks = 0;
        assert!(on.validate().is_err());
        let mut on = enabled_cfg();
        on.max_batch_tasks = 1;
        on.min_batch_tasks = 2;
        assert!(on.validate().is_err());
        let mut on = enabled_cfg();
        on.quarantine_hi = 1.5;
        assert!(on.validate().is_err());
    }

    #[test]
    fn decision_log_bounds_retention_and_counts_all() {
        let log = DecisionLog::new(2, None);
        for i in 0..5 {
            log.push(Decision {
                controller: ControllerId::Capacity,
                at_s: i as f64,
                from: i as f64,
                to: i as f64 + 1.0,
                cause: "test",
            });
        }
        assert_eq!(log.total(), 5);
        let recent = log.recent();
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[0].at_s, 3.0);
        assert_eq!(recent[1].at_s, 4.0);
    }

    #[test]
    fn decision_detail_packs_controller_and_value() {
        let d = Decision {
            controller: ControllerId::Staleness,
            at_s: 0.0,
            from: 1.0,
            to: 3.0,
            cause: "widen",
        };
        assert_eq!(d.detail(), (1u64 << 32) | 3);
    }

    #[test]
    fn decision_log_mirrors_to_control_spans() {
        let rec = Arc::new(SpanRecorder::new(64));
        let log = DecisionLog::new(8, Some(Arc::clone(&rec)));
        log.push(Decision {
            controller: ControllerId::Admission,
            at_s: 0.0,
            from: 1.0,
            to: 0.0,
            cause: "pressure over band",
        });
        let spans = rec.drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].kind, SpanKind::ControlDecision);
        assert_eq!(spans[0].detail >> 32, ControllerId::Admission as u64);
    }

    #[test]
    fn plane_processes_each_tick_once_and_holds_on_stale() {
        let hub = Arc::new(TelemetryHub::new(Duration::from_micros(1)));
        let mut cfg = enabled_cfg();
        cfg.hold_ticks = 1;
        cfg.max_gauge_age_s = 0.5;
        let plane = ControlPlane::new(cfg, ctx(), Arc::clone(&hub), None);

        // no publish yet: reads return defaults without stepping
        assert!(plane.admit());
        assert_eq!(plane.batch_tasks(), 4);
        assert_eq!(plane.snapshot().decisions, 0);

        // a heavily over-band sample closes admission after hold_ticks=1
        hub.publish(Gauges { queue_wait_p95_s: 10.0, ..Default::default() });
        assert!(!plane.admit(), "over-band pressure must close the gate");
        let after_close = plane.snapshot().decisions;
        // same sample again: no double-step, output held
        assert!(!plane.admit());
        assert_eq!(plane.snapshot().decisions, after_close);

        // recovery sample reopens
        hub.publish(Gauges::default());
        assert!(plane.admit(), "calm pressure must reopen the gate");
        assert!(plane.snapshot().decisions > after_close);
        assert_eq!(plane.stale_holds(), 0);

        // let a fresh over-band sample age past max_gauge_age_s: the
        // plane holds and records one stale episode no matter how often
        // it is polled
        hub.publish(Gauges { queue_wait_p95_s: 10.0, ..Default::default() });
        std::thread::sleep(Duration::from_millis(600));
        let before = plane.snapshot().decisions;
        assert!(plane.admit(), "stale over-band sample must NOT close the gate");
        assert!(plane.admit());
        assert_eq!(plane.snapshot().decisions, before, "no decisions on stale gauges");
        assert_eq!(plane.stale_holds(), 1, "warn/hold once per stale episode");
    }

    #[test]
    fn flight_source_exports_the_decision_ring() {
        let hub = Arc::new(TelemetryHub::new(Duration::from_micros(1)));
        let mut cfg = enabled_cfg();
        cfg.hold_ticks = 1;
        let plane = ControlPlane::new(cfg, ctx(), Arc::clone(&hub), None);
        hub.publish(Gauges { queue_wait_p95_s: 10.0, ..Default::default() });
        assert!(!plane.admit(), "over-band pressure closes the gate");
        let doc = plane.flight_source().collect();
        assert!(doc.get("total").and_then(Value::as_i64).unwrap() >= 1);
        let recent = doc.get("recent").and_then(Value::as_array).unwrap();
        assert!(!recent.is_empty());
        assert_eq!(
            recent[0].get("controller").and_then(Value::as_str),
            Some("admission"),
            "{recent:?}"
        );
        assert!(recent[0].get("cause").and_then(Value::as_str).is_some());
    }

    #[test]
    fn snapshot_monitor_fields_cover_every_output() {
        let hub = Arc::new(TelemetryHub::new(Duration::from_micros(1)));
        let plane = ControlPlane::new(enabled_cfg(), ctx(), hub, None);
        let fields = plane.snapshot().monitor_fields();
        let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        for k in [
            "control/decisions",
            "control/admission_open",
            "control/pressure",
            "control/batch_tasks",
            "control/stale_holds",
        ] {
            assert!(keys.contains(&k), "missing {k} in {keys:?}");
        }
        // no staleness controller adopted -> no lag series
        assert!(!keys.contains(&"control/staleness_lag"));
    }
}
