//! Capacity controller: steers per-driver batch-task counts to live
//! replica capacity.
//!
//! The target is derived from the gauges: `healthy_replicas ×
//! session_rows × capacity_headroom` rows of rollout work in flight,
//! divided by the rows one batch launch produces (`repeat_times ×
//! explorer_count` per batch task).  Movement is AIMD-shaped and
//! damped: after `hold_ticks` consecutive samples wanting the same
//! direction, the output grows by **+1** (additive probe into spare
//! capacity) or shrinks **toward the target by halving** (multiplicative
//! retreat when replicas quarantine or the pool shrinks), clamped to
//! `[min_batch_tasks, max_batch_tasks]`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::Gauges;

use super::{ControlConfig, ControlContext, Controller, ControllerId, Decision};

pub struct CapacityController {
    headroom: f64,
    hold_ticks: u64,
    replicas: f64,
    session_rows: f64,
    rows_per_task: f64,
    min: u64,
    max: u64,
    tasks: AtomicU64,
    streak_up: AtomicU64,
    streak_down: AtomicU64,
}

impl CapacityController {
    pub fn new(cfg: &ControlConfig, ctx: &ControlContext) -> CapacityController {
        let max = if cfg.max_batch_tasks == 0 { ctx.batch_tasks } else { cfg.max_batch_tasks }
            .max(1) as u64;
        let min = (cfg.min_batch_tasks as u64).clamp(1, max);
        CapacityController {
            headroom: cfg.capacity_headroom,
            hold_ticks: cfg.hold_ticks.max(1),
            replicas: ctx.replicas.max(1) as f64,
            session_rows: ctx.session_rows.max(1) as f64,
            rows_per_task: (ctx.repeat_times.max(1) * ctx.explorer_count.max(1)) as f64,
            min,
            max,
            tasks: AtomicU64::new((ctx.batch_tasks as u64).clamp(min, max)),
            streak_up: AtomicU64::new(0),
            streak_down: AtomicU64::new(0),
        }
    }

    /// The current per-driver batch-task output.
    pub fn tasks(&self) -> usize {
        self.tasks.load(Ordering::Relaxed) as usize
    }

    /// The batch-task count live capacity asks for (clamped).
    fn desired(&self, g: &Gauges) -> u64 {
        let healthy = (self.replicas - g.quarantined).max(0.0);
        let target_rows = healthy * self.session_rows * self.headroom;
        ((target_rows / self.rows_per_task).ceil() as u64).clamp(self.min, self.max)
    }
}

impl Controller for CapacityController {
    fn id(&self) -> ControllerId {
        ControllerId::Capacity
    }

    fn bounds(&self) -> (f64, f64) {
        (self.min as f64, self.max as f64)
    }

    fn output(&self) -> f64 {
        self.tasks() as f64
    }

    fn step(&self, g: &Gauges) -> Option<Decision> {
        let cur = self.tasks.load(Ordering::Relaxed);
        let desired = self.desired(g);
        let next = if desired > cur {
            self.streak_down.store(0, Ordering::Relaxed);
            if self.streak_up.fetch_add(1, Ordering::Relaxed) + 1 < self.hold_ticks {
                return None;
            }
            cur + 1 // additive probe upward
        } else if desired < cur {
            self.streak_up.store(0, Ordering::Relaxed);
            if self.streak_down.fetch_add(1, Ordering::Relaxed) + 1 < self.hold_ticks {
                return None;
            }
            (cur / 2).max(desired) // multiplicative retreat, not past target
        } else {
            self.streak_up.store(0, Ordering::Relaxed);
            self.streak_down.store(0, Ordering::Relaxed);
            return None;
        };
        self.streak_up.store(0, Ordering::Relaxed);
        self.streak_down.store(0, Ordering::Relaxed);
        let next = next.clamp(self.min, self.max);
        if next == cur {
            return None;
        }
        self.tasks.store(next, Ordering::Relaxed);
        Some(Decision {
            controller: ControllerId::Capacity,
            at_s: g.at_s,
            from: cur as f64,
            to: next as f64,
            cause: if next > cur { "replica capacity up" } else { "replica capacity down" },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(batch_tasks: usize, max_batch_tasks: usize) -> CapacityController {
        let cfg = ControlConfig {
            hold_ticks: 1,
            max_batch_tasks,
            capacity_headroom: 1.0,
            ..Default::default()
        };
        let ctx = ControlContext {
            replicas: 2,
            session_rows: 8,
            repeat_times: 4,
            explorer_count: 1,
            batch_tasks,
            max_buffer_depth: 0,
            class_caps: [0; crate::qos::CLASS_COUNT],
        };
        CapacityController::new(&cfg, &ctx)
    }

    #[test]
    fn starts_at_the_configured_count_within_bounds() {
        let c = controller(3, 0);
        assert_eq!(c.tasks(), 3);
        assert_eq!(c.bounds(), (1.0, 3.0)); // max_batch_tasks=0 -> batch_tasks cap
        let wide = controller(3, 16);
        assert_eq!(wide.bounds(), (1.0, 16.0));
    }

    #[test]
    fn probes_up_additively_toward_healthy_capacity() {
        // 2 healthy replicas * 8 rows * 1.0 headroom / 4 rows-per-task = 4
        let c = controller(1, 16);
        let g = Gauges::default();
        let d = c.step(&g).expect("under target must move up");
        assert_eq!((d.from, d.to), (1.0, 2.0));
        c.step(&g);
        c.step(&g);
        assert_eq!(c.tasks(), 4, "one step per sample, +1 each");
        assert!(c.step(&g).is_none(), "at target: no movement");
    }

    #[test]
    fn retreats_multiplicatively_on_quarantine() {
        let c = controller(8, 16);
        // both replicas quarantined -> desired clamps to min (1)
        let dead = Gauges { quarantined: 2.0, ..Default::default() };
        let d = c.step(&dead).expect("over target must retreat");
        assert_eq!((d.from, d.to), (8.0, 4.0), "halving, not -1");
        assert_eq!(d.cause, "replica capacity down");
        c.step(&dead);
        c.step(&dead);
        assert_eq!(c.tasks(), 1);
        // one replica back -> desired = 1*8/4 = 2: additive recovery
        let half = Gauges { quarantined: 1.0, ..Default::default() };
        let d = c.step(&half).expect("capacity returned");
        assert_eq!((d.from, d.to), (1.0, 2.0));
        assert_eq!(d.cause, "replica capacity up");
    }

    #[test]
    fn hold_ticks_damp_direction_changes() {
        let cfg = ControlConfig { hold_ticks: 3, max_batch_tasks: 16, ..Default::default() };
        let ctx = ControlContext {
            replicas: 2,
            session_rows: 8,
            repeat_times: 4,
            explorer_count: 1,
            batch_tasks: 1,
            max_buffer_depth: 0,
            class_caps: [0; crate::qos::CLASS_COUNT],
        };
        let c = CapacityController::new(&cfg, &ctx);
        let g = Gauges::default();
        assert!(c.step(&g).is_none());
        assert!(c.step(&g).is_none());
        assert!(c.step(&g).is_some(), "third consecutive sample moves");
        // a down-wanting sample resets the up streak
        assert!(c.step(&g).is_none());
        assert!(c.step(&Gauges { quarantined: 2.0, ..Default::default() }).is_none());
        assert!(c.step(&g).is_none(), "streak restarted after direction flip");
    }
}
