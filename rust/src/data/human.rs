//! Human-in-the-loop collaboration (paper §2.3.4, §3.5): annotation
//! batches flow to a simulated annotator pool (the Label Studio stand-in)
//! with realistic latency, inter-annotator agreement and noise; results
//! commit atomically and become DPO preference pairs.
//!
//! The asynchronous execution model is the point: annotation requests are
//! posted, the RFT loop keeps running, and completed batches are polled
//! with a timeout (`wait_for_annotations` in the paper's config).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::buffer::Experience;
use crate::envs::math::verify;
use crate::exec::ThreadPool;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// One item: two candidate responses for a prompt; annotators pick one.
#[derive(Debug, Clone)]
pub struct AnnotationItem {
    pub prompt: String,
    pub answer_a: String,
    pub answer_b: String,
    /// Ground truth for the simulated annotator's judgement.
    pub gold_answer: i64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct AnnotationResult {
    pub chosen_is_a: bool,
    /// Agreement among annotators in [0, 1].
    pub agreement: f64,
}

#[derive(Debug, Clone)]
pub struct AnnotatorConfig {
    /// Annotators per item (majority vote).
    pub annotators_per_item: usize,
    /// Probability each annotator judges correctly.
    pub accuracy: f64,
    /// Mean per-item latency (exponential).
    pub mean_latency: Duration,
    /// Items whose agreement falls below this are rejected (quality
    /// control stage).
    pub min_agreement: f64,
}

impl Default for AnnotatorConfig {
    fn default() -> Self {
        AnnotatorConfig {
            annotators_per_item: 3,
            accuracy: 0.9,
            mean_latency: Duration::from_millis(10),
            min_agreement: 0.6,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchStatus {
    Pending,
    Done,
    Failed,
}

struct BatchState {
    status: BatchStatus,
    results: Vec<Option<AnnotationResult>>,
}

/// The annotation service: post batches, poll with timeout, atomic commit
/// (a batch is visible only when every item is annotated).
pub struct AnnotationService {
    pool: Arc<ThreadPool>,
    config: AnnotatorConfig,
    batches: Arc<(Mutex<HashMap<u64, BatchState>>, Condvar)>,
    next_id: Mutex<u64>,
    seed: u64,
}

impl AnnotationService {
    pub fn new(config: AnnotatorConfig, workers: usize, seed: u64) -> AnnotationService {
        AnnotationService {
            pool: Arc::new(ThreadPool::new("annotators", workers.max(1))),
            config,
            batches: Arc::new((Mutex::new(HashMap::new()), Condvar::new())),
            next_id: Mutex::new(1),
            seed,
        }
    }

    /// Post a batch; returns its id immediately (async model).
    pub fn post_batch(&self, items: Vec<AnnotationItem>) -> u64 {
        let id = {
            let mut next = self.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        let n = items.len();
        self.batches
            .0
            .lock()
            .unwrap()
            .insert(id, BatchState { status: BatchStatus::Pending, results: vec![None; n] });

        for (idx, item) in items.into_iter().enumerate() {
            let batches = Arc::clone(&self.batches);
            let cfg = self.config.clone();
            let seed = self.seed ^ (id << 16) ^ idx as u64;
            self.pool.submit(move || {
                let mut rng = Rng::new(seed);
                if !cfg.mean_latency.is_zero() {
                    let latency = rng.exponential(1.0 / cfg.mean_latency.as_secs_f64());
                    std::thread::sleep(Duration::from_secs_f64(latency.min(2.0)));
                }
                // each simulated annotator votes; a "correct" vote picks the
                // truly better answer (verified against gold)
                let a_correct = verify(&item.answer_a, item.gold_answer) > 0.5;
                let b_correct = verify(&item.answer_b, item.gold_answer) > 0.5;
                let truth_is_a = a_correct || !b_correct;
                let mut votes_a = 0usize;
                for _ in 0..cfg.annotators_per_item {
                    let correct = rng.bool(cfg.accuracy);
                    let vote_a = if correct { truth_is_a } else { !truth_is_a };
                    if vote_a {
                        votes_a += 1;
                    }
                }
                let majority_a = votes_a * 2 >= cfg.annotators_per_item;
                let agreement = votes_a.max(cfg.annotators_per_item - votes_a) as f64
                    / cfg.annotators_per_item as f64;
                let result = AnnotationResult { chosen_is_a: majority_a, agreement };

                let (lock, cvar) = &*batches;
                let mut map = lock.lock().unwrap();
                if let Some(state) = map.get_mut(&id) {
                    state.results[idx] = Some(result);
                    if state.results.iter().all(Option::is_some) {
                        state.status = BatchStatus::Done; // atomic commit point
                        cvar.notify_all();
                    }
                }
            });
        }
        id
    }

    pub fn status(&self, batch_id: u64) -> BatchStatus {
        self.batches
            .0
            .lock()
            .unwrap()
            .get(&batch_id)
            .map(|s| s.status)
            .unwrap_or(BatchStatus::Failed)
    }

    /// Timeout-aware poll (paper: `wait_for_annotations` + `timeout`).
    /// Returns quality-controlled results (low-agreement items dropped).
    pub fn wait_for_batch(
        &self,
        batch_id: u64,
        timeout: Duration,
    ) -> Result<Vec<(usize, AnnotationResult)>> {
        let (lock, cvar) = &*self.batches;
        let deadline = std::time::Instant::now() + timeout;
        let mut map = lock.lock().unwrap();
        loop {
            match map.get(&batch_id) {
                None => bail!("unknown annotation batch {batch_id}"),
                Some(state) if state.status == BatchStatus::Done => {
                    let results = state
                        .results
                        .iter()
                        .enumerate()
                        .filter_map(|(i, r)| r.clone().map(|r| (i, r)))
                        .filter(|(_, r)| r.agreement >= self.config.min_agreement)
                        .collect();
                    return Ok(results);
                }
                Some(_) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        bail!("annotation batch {batch_id} timed out");
                    }
                    let (guard, _) = cvar.wait_timeout(map, deadline - now).unwrap();
                    map = guard;
                }
            }
        }
    }
}

/// Turn annotated preference items into DPO experience pairs.
pub fn results_to_preference_pairs(
    items: &[AnnotationItem],
    results: &[(usize, AnnotationResult)],
    formatter: &super::formatter::Formatter,
) -> Result<Vec<Experience>> {
    let mut out = Vec::with_capacity(results.len() * 2);
    for (idx, res) in results {
        let item = &items[*idx];
        let (chosen, rejected) = if res.chosen_is_a {
            (&item.answer_a, &item.answer_b)
        } else {
            (&item.answer_b, &item.answer_a)
        };
        let raw = Value::obj(vec![
            ("question", Value::str(item.prompt.clone())),
            ("chosen", Value::str(chosen.clone())),
            ("rejected", Value::str(rejected.clone())),
        ]);
        let (mut c, mut r) = formatter.to_preference_pair(*idx as u64 + 1, &raw)?;
        c.set_meta("agreement", Value::num(res.agreement));
        r.set_meta("agreement", Value::num(res.agreement));
        out.push(c);
        out.push(r);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(n: usize) -> Vec<AnnotationItem> {
        (0..n)
            .map(|i| AnnotationItem {
                prompt: format!("what is 3 + {i} ?"),
                answer_a: (3 + i as i64).to_string(), // correct
                answer_b: "99".to_string(),           // wrong
                gold_answer: 3 + i as i64,
            })
            .collect()
    }

    #[test]
    fn batch_completes_and_majority_is_correct() {
        let svc = AnnotationService::new(
            AnnotatorConfig { mean_latency: Duration::from_millis(2), ..Default::default() },
            4,
            1,
        );
        let batch = items(8);
        let id = svc.post_batch(batch);
        assert_eq!(svc.status(id), BatchStatus::Pending);
        let results = svc.wait_for_batch(id, Duration::from_secs(5)).unwrap();
        assert!(!results.is_empty());
        let correct = results.iter().filter(|(_, r)| r.chosen_is_a).count();
        assert!(correct as f64 >= results.len() as f64 * 0.7, "{correct}/{}", results.len());
        assert_eq!(svc.status(id), BatchStatus::Done);
    }

    #[test]
    fn timeout_on_slow_annotators() {
        let svc = AnnotationService::new(
            AnnotatorConfig { mean_latency: Duration::from_millis(500), ..Default::default() },
            1,
            2,
        );
        let id = svc.post_batch(items(4));
        assert!(svc.wait_for_batch(id, Duration::from_millis(30)).is_err());
    }

    #[test]
    fn low_agreement_items_dropped() {
        // accuracy 0.5 -> coin-flip annotators; with min_agreement 1.0 only
        // unanimous items survive
        let svc = AnnotationService::new(
            AnnotatorConfig {
                accuracy: 0.5,
                min_agreement: 1.0,
                mean_latency: Duration::ZERO,
                annotators_per_item: 3,
            },
            4,
            3,
        );
        let id = svc.post_batch(items(20));
        let results = svc.wait_for_batch(id, Duration::from_secs(5)).unwrap();
        assert!(results.len() < 20, "unanimity should be rare: {}", results.len());
        assert!(results.iter().all(|(_, r)| r.agreement == 1.0));
    }

    #[test]
    fn results_become_dpo_pairs() {
        let batch = items(3);
        let results: Vec<(usize, AnnotationResult)> = (0..3)
            .map(|i| (i, AnnotationResult { chosen_is_a: true, agreement: 1.0 }))
            .collect();
        let formatter = super::super::formatter::Formatter {
            spec: Default::default(),
            tokenizer: Arc::new(crate::tokenizer::Tokenizer::new()),
        };
        let pairs = results_to_preference_pairs(&batch, &results, &formatter).unwrap();
        assert_eq!(pairs.len(), 6);
        let chosen: Vec<_> = pairs
            .iter()
            .filter(|e| e.metadata.get("role").unwrap().as_str() == Some("chosen"))
            .collect();
        assert_eq!(chosen.len(), 3);
    }

    #[test]
    fn unknown_batch_errors() {
        let svc = AnnotationService::new(Default::default(), 1, 4);
        assert!(svc.wait_for_batch(999, Duration::from_millis(5)).is_err());
    }
}
