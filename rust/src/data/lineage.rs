//! LineageTracker (paper §2.3.4): parent/child links across shaping
//! operations (amplification, repair, synthesis), with ancestry queries —
//! the full-data-lineage requirement of the pgAdmin/asynchronous-training
//! story.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::buffer::Experience;

#[derive(Debug, Clone, PartialEq)]
pub struct LineageRecord {
    pub id: u64,
    pub parent: Option<u64>,
    pub op: String,
}

#[derive(Default)]
pub struct LineageTracker {
    records: Mutex<HashMap<u64, LineageRecord>>,
}

impl LineageTracker {
    pub fn new() -> LineageTracker {
        Self::default()
    }

    pub fn record(&self, id: u64, parent: Option<u64>, op: &str) {
        self.records
            .lock()
            .unwrap()
            .insert(id, LineageRecord { id, parent, op: op.to_string() });
    }

    /// Record a batch after buffer assignment of ids.
    pub fn record_batch(&self, exps: &[Experience], op: &str) {
        let mut map = self.records.lock().unwrap();
        for e in exps {
            if e.id != 0 {
                map.insert(e.id, LineageRecord { id: e.id, parent: e.parent_id, op: op.to_string() });
            }
        }
    }

    /// Walk ancestry from id to the root (inclusive, child-first).
    pub fn ancestry(&self, id: u64) -> Vec<LineageRecord> {
        let map = self.records.lock().unwrap();
        let mut out = vec![];
        let mut cur = Some(id);
        while let Some(c) = cur {
            match map.get(&c) {
                Some(rec) => {
                    out.push(rec.clone());
                    cur = rec.parent;
                }
                None => break,
            }
            if out.len() > 1000 {
                break; // cycle guard
            }
        }
        out
    }

    /// Direct children of an id.
    pub fn children(&self, id: u64) -> Vec<u64> {
        let map = self.records.lock().unwrap();
        let mut out: Vec<u64> =
            map.values().filter(|r| r.parent == Some(id)).map(|r| r.id).collect();
        out.sort_unstable();
        out
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ancestry_chain() {
        let t = LineageTracker::new();
        t.record(1, None, "rollout");
        t.record(2, Some(1), "amplify");
        t.record(3, Some(2), "repair");
        let chain = t.ancestry(3);
        assert_eq!(chain.len(), 3);
        assert_eq!(chain[0].op, "repair");
        assert_eq!(chain[2].op, "rollout");
        assert_eq!(t.children(1), vec![2]);
    }

    #[test]
    fn batch_recording_skips_unassigned() {
        let t = LineageTracker::new();
        let mut a = Experience::new("a", vec![1], 0, 0.0);
        a.id = 10;
        let b = Experience::new("b", vec![1], 0, 0.0); // id 0 -> skipped
        t.record_batch(&[a, b], "rollout");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn missing_id_gives_empty_ancestry() {
        let t = LineageTracker::new();
        assert!(t.ancestry(42).is_empty());
    }
}
