//! Task curation & prioritization (paper §2.3.2, Fig. 5 left): raw tasks
//! -> formatter -> scoring operators -> priority-ordered task set.
//! With `priority_weights: {difficulty: -1.0}` this yields the easy->hard
//! curriculum of Fig. 10.

use anyhow::Result;

use crate::explorer::Task;
use crate::util::json::Value;

use super::operators::DifficultyScorer;

/// Priority weights over task features (the paper's YAML
/// `priority_weights` block; negative difficulty = easy first).
#[derive(Debug, Clone)]
pub struct PriorityWeights {
    pub difficulty: f64,
    pub length: f64,
}

impl Default for PriorityWeights {
    fn default() -> Self {
        PriorityWeights { difficulty: 0.0, length: 0.0 }
    }
}

pub struct TaskPipeline {
    pub weights: PriorityWeights,
    /// Drop tasks above this difficulty (0 = no cap).
    pub max_difficulty: f64,
}

impl TaskPipeline {
    pub fn new(weights: PriorityWeights) -> TaskPipeline {
        TaskPipeline { weights, max_difficulty: 0.0 }
    }

    /// Curriculum preset: easy-to-hard ordering (Fig. 10's
    /// `priority_weights: difficulty: -1.0`).
    pub fn easy_to_hard() -> TaskPipeline {
        TaskPipeline::new(PriorityWeights { difficulty: -1.0, length: 0.0 })
    }

    fn score(&self, task: &Task) -> f64 {
        let difficulty = DifficultyScorer.score_task(task);
        let length = task
            .payload
            .get("question")
            .and_then(Value::as_str)
            .map(|q| q.len() as f64)
            .unwrap_or(0.0);
        self.weights.difficulty * difficulty + self.weights.length * length
    }

    /// Curate and order a raw task set: score, filter, sort by descending
    /// priority; annotates each task's metadata with its score.
    pub fn run(&self, mut tasks: Vec<Task>) -> Result<Vec<Task>> {
        if self.max_difficulty > 0.0 {
            tasks.retain(|t| DifficultyScorer.score_task(t) <= self.max_difficulty);
        }
        let mut scored: Vec<(f64, Task)> =
            tasks.into_iter().map(|t| (self.score(&t), t)).collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        Ok(scored
            .into_iter()
            .map(|(s, mut t)| {
                t.payload.set("priority", Value::num(s));
                t
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: &str, difficulty: f64) -> Task {
        let mut t = Task::new(id, "math", Value::obj(vec![("question", Value::str("q"))]));
        t.difficulty = difficulty;
        t
    }

    #[test]
    fn easy_to_hard_orders_ascending_difficulty() {
        let p = TaskPipeline::easy_to_hard();
        let out = p.run(vec![task("hard", 7.0), task("easy", 1.0), task("mid", 4.0)]).unwrap();
        let ids: Vec<&str> = out.iter().map(|t| t.id.as_str()).collect();
        assert_eq!(ids, vec!["easy", "mid", "hard"]);
        assert!(out[0].payload.get("priority").is_some());
    }

    #[test]
    fn hard_to_easy_with_positive_weight() {
        let p = TaskPipeline::new(PriorityWeights { difficulty: 1.0, length: 0.0 });
        let out = p.run(vec![task("a", 2.0), task("b", 6.0)]).unwrap();
        assert_eq!(out[0].id, "b");
    }

    #[test]
    fn difficulty_cap_filters() {
        let mut p = TaskPipeline::easy_to_hard();
        p.max_difficulty = 3.0;
        let out = p.run(vec![task("keep", 2.0), task("drop", 5.0)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, "keep");
    }
}
