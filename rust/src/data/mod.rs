//! Data pipelines (paper §2.3): tasks and experiences as *dynamic assets*.
//!
//! * [`operators`] — the Data-Juicer-analog operator pool: filters,
//!   dedup, difficulty/quality scorers, success amplification, failure
//!   repair.
//! * [`task_pipeline`] — task curation & prioritization ahead of the RFT
//!   loop (curriculum learning, Fig. 10).
//! * [`experience_pipeline`] — active experience shaping between explorer
//!   and trainer: quality (Fig. 12) and diversity (Fig. 14) reward
//!   augmentation, composed processors, the `ShapingBuffer` adapter.
//! * [`formatter`] — raw record -> task/experience conversion.
//! * [`agentic`] — NL command -> operator pipeline translation.
//! * [`human`] — human-in-the-loop simulation: annotator pool, timeout
//!   polling, atomic batch commit, preference pairs (DPO data).
//! * [`lineage`] — parent/child tracking across shaping operations.

pub mod agentic;
pub mod experience_pipeline;
pub mod formatter;
pub mod human;
pub mod lineage;
pub mod operators;
pub mod task_pipeline;

pub use experience_pipeline::{
    ChainProcessor, DiversityRewardProcessor, ExperienceProcessor, QualityRewardProcessor,
    ShapingBuffer,
};
pub use lineage::LineageTracker;
pub use operators::{Operator, OperatorPool};
pub use task_pipeline::TaskPipeline;
