//! The operator pool (Data-Juicer analog): composable building blocks for
//! experience cleaning, safety alignment, scoring and synthesis
//! (paper §2.3.2/§2.3.3).  Operators transform record lists; the pipeline
//! modules chain them.

use std::collections::HashSet;

use crate::buffer::Experience;
use crate::envs::math::format_score;
use crate::util::json::Value;

/// A record-level transform over experiences.
pub trait Operator: Send + Sync {
    fn name(&self) -> &'static str;
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience>;
}

// -- filters -----------------------------------------------------------------

/// Drop experiences whose response length is outside [min, max] tokens.
pub struct LengthFilter {
    pub min_tokens: usize,
    pub max_tokens: usize,
}

impl Operator for LengthFilter {
    fn name(&self) -> &'static str {
        "length_filter"
    }
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience> {
        exps.into_iter()
            .filter(|e| {
                let n = e.response_len();
                n >= self.min_tokens && n <= self.max_tokens
            })
            .collect()
    }
}

/// Exact + near (token-shingle) dedup over responses.
pub struct DedupFilter {
    /// Jaccard-style threshold on 3-token shingles; 1.0 = exact only.
    pub similarity_threshold: f64,
}

fn shingles(tokens: &[i32]) -> HashSet<(i32, i32, i32)> {
    tokens.windows(3).map(|w| (w[0], w[1], w[2])).collect()
}

impl Operator for DedupFilter {
    fn name(&self) -> &'static str {
        "dedup"
    }
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience> {
        let mut kept: Vec<Experience> = Vec::with_capacity(exps.len());
        let mut kept_shingles: Vec<HashSet<(i32, i32, i32)>> = vec![];
        'outer: for e in exps {
            let resp: Vec<i32> = e
                .tokens
                .iter()
                .zip(&e.loss_mask)
                .filter(|(_, &m)| m > 0.0)
                .map(|(&t, _)| t)
                .collect();
            let sh = shingles(&resp);
            for prev in &kept_shingles {
                if sh.is_empty() && prev.is_empty() {
                    continue 'outer; // both degenerate -> duplicates
                }
                let inter = sh.intersection(prev).count() as f64;
                let union = sh.union(prev).count() as f64;
                if union > 0.0 && inter / union >= self.similarity_threshold {
                    continue 'outer;
                }
            }
            kept_shingles.push(sh);
            kept.push(e);
        }
        kept
    }
}

/// Toxicity-sim filter: drops experiences whose metadata marks them
/// unsafe (the safety-alignment stand-in; a scorer upstream sets the tag).
pub struct SafetyFilter;

impl Operator for SafetyFilter {
    fn name(&self) -> &'static str {
        "safety_filter"
    }
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience> {
        exps.into_iter()
            .filter(|e| e.metadata.get("unsafe").and_then(Value::as_bool) != Some(true))
            .collect()
    }
}

// -- scorers -----------------------------------------------------------------

/// Heuristic difficulty scorer for *task-like* records (the Qwen-Max
/// stand-in): uses the task's declared difficulty when present, otherwise
/// question length as a proxy.
pub struct DifficultyScorer;

impl DifficultyScorer {
    pub fn score_task(&self, task: &crate::explorer::Task) -> f64 {
        if task.difficulty > 0.0 {
            return task.difficulty;
        }
        task.payload
            .get("question")
            .and_then(Value::as_str)
            .map(|q| (q.len() as f64 / 10.0).min(8.0))
            .unwrap_or(4.0)
    }
}

/// Quality scorer (the Qwen3-32B llm_quality_filter stand-in): verifier
/// outcome + well-formedness, normalized to [-0.5, 0.5] as in Fig. 12.
pub struct QualityScorer;

impl QualityScorer {
    pub fn score(&self, e: &Experience) -> f64 {
        let resp = e.metadata.get("response").and_then(Value::as_str).unwrap_or("");
        // format_score in [0,1] -> [-0.5, 0.5]
        (format_score(resp) as f64) - 0.5
    }
}

impl Operator for QualityScorer {
    fn name(&self) -> &'static str {
        "quality_scorer"
    }
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience> {
        exps.into_iter()
            .map(|mut e| {
                let q = self.score(&e);
                e.set_meta("quality", Value::num(q));
                e
            })
            .collect()
    }
}

// -- synthesis ---------------------------------------------------------------

/// Success amplification (paper §2.3.5): duplicate high-reward
/// experiences `factor` times with lineage links.
pub struct SuccessAmplifier {
    pub reward_threshold: f32,
    pub factor: usize,
}

impl Operator for SuccessAmplifier {
    fn name(&self) -> &'static str {
        "success_amplifier"
    }
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience> {
        let mut out = Vec::with_capacity(exps.len());
        for e in exps {
            let amplify = e.reward >= self.reward_threshold;
            let parent = e.id;
            out.push(e.clone());
            if amplify {
                for _ in 1..self.factor.max(1) {
                    let mut copy = e.clone();
                    copy.id = 0; // buffer reassigns
                    copy.parent_id = Some(parent).filter(|&p| p != 0);
                    copy.set_meta("amplified", Value::Bool(true));
                    out.push(copy);
                }
            }
        }
        out
    }
}

/// Failure repair (paper §2.3.5): failed trajectories whose metadata
/// carries a ground-truth answer are rewritten into corrected SFT-style
/// experiences (reward 1, Synthetic source).  The repair function is
/// pluggable; the default replaces the response with the gold answer.
pub struct FailureRepair {
    pub tokenizer: std::sync::Arc<crate::tokenizer::Tokenizer>,
}

impl Operator for FailureRepair {
    fn name(&self) -> &'static str {
        "failure_repair"
    }
    fn apply(&self, exps: Vec<Experience>) -> Vec<Experience> {
        let mut out = Vec::with_capacity(exps.len());
        for e in exps {
            if e.reward <= 0.0 {
                if let Some(answer) = e.metadata.get("gold_answer").and_then(Value::as_str) {
                    let mut fixed = e.clone();
                    fixed.id = 0;
                    fixed.parent_id = Some(e.id).filter(|&p| p != 0);
                    // rebuild: prompt + corrected answer
                    let mut tokens = e.tokens[..e.prompt_len].to_vec();
                    let answer_toks = self.tokenizer.encode(answer);
                    tokens.extend_from_slice(&answer_toks);
                    tokens.push(crate::tokenizer::EOS);
                    let n = tokens.len();
                    let mut mask = vec![0.0; e.prompt_len];
                    mask.extend(std::iter::repeat(1.0).take(n - e.prompt_len));
                    fixed.tokens = tokens;
                    fixed.loss_mask = mask;
                    fixed.logprobs = vec![0.0; n];
                    fixed.reward = 1.0;
                    fixed.source = crate::buffer::Source::Synthetic;
                    fixed.set_meta("repaired", Value::Bool(true));
                    out.push(fixed);
                }
            }
            out.push(e);
        }
        out
    }
}

/// A named pool of operators assembled by config or the agentic
/// translator.
#[derive(Default)]
pub struct OperatorPool {
    pub ops: Vec<Box<dyn Operator>>,
}

impl OperatorPool {
    pub fn push(&mut self, op: Box<dyn Operator>) -> &mut Self {
        self.ops.push(op);
        self
    }

    pub fn apply(&self, mut exps: Vec<Experience>) -> Vec<Experience> {
        for op in &self.ops {
            exps = op.apply(exps);
        }
        exps
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.ops.iter().map(|o| o.name()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Source;

    fn exp_with_response(tokens: Vec<i32>, plen: usize, reward: f32, resp: &str) -> Experience {
        let mut e = Experience::new("t", tokens, plen, reward);
        e.set_meta("response", Value::str(resp));
        e
    }

    #[test]
    fn length_filter_bounds() {
        let f = LengthFilter { min_tokens: 2, max_tokens: 4 };
        let exps = vec![
            Experience::new("a", vec![1, 2], 1, 0.0),          // resp 1 -> drop
            Experience::new("b", vec![1, 2, 3], 1, 0.0),       // resp 2 -> keep
            Experience::new("c", vec![1; 10], 1, 0.0),         // resp 9 -> drop
        ];
        let kept = f.apply(exps);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].task_id, "b");
    }

    #[test]
    fn dedup_drops_exact_and_near() {
        let f = DedupFilter { similarity_threshold: 0.8 };
        let mk = |resp: Vec<i32>| {
            let mut tokens = vec![1];
            tokens.extend(&resp);
            Experience::new("t", tokens, 1, 0.0)
        };
        let exps = vec![
            mk(vec![10, 11, 12, 13, 14]),
            mk(vec![10, 11, 12, 13, 14]),       // exact dup
            mk(vec![10, 11, 12, 13, 15]),       // near dup (shingles overlap 3/5)
            mk(vec![20, 21, 22, 23, 24]),       // distinct
        ];
        let kept = f.apply(exps);
        assert_eq!(kept.len(), 3); // near-dup at 0.6 jaccard survives 0.8 threshold
        let f2 = DedupFilter { similarity_threshold: 0.5 };
        let exps2 = vec![
            mk(vec![10, 11, 12, 13, 14]),
            mk(vec![10, 11, 12, 13, 15]),
            mk(vec![20, 21, 22, 23, 24]),
        ];
        assert_eq!(f2.apply(exps2).len(), 2);
    }

    #[test]
    fn quality_scorer_annotates_in_range() {
        let exps = vec![
            exp_with_response(vec![1, 2, 3], 1, 0.0, "42"),
            exp_with_response(vec![1, 2, 3], 1, 0.0, ""),
        ];
        let scored = QualityScorer.apply(exps);
        let q0 = scored[0].meta_f64("quality").unwrap();
        let q1 = scored[1].meta_f64("quality").unwrap();
        assert!(q0 > q1);
        assert!((-0.5..=0.5).contains(&q0));
        assert!((-0.5..=0.5).contains(&q1));
    }

    #[test]
    fn success_amplifier_duplicates_with_lineage() {
        let mut good = Experience::new("g", vec![1, 2, 3], 1, 1.0);
        good.id = 7;
        let bad = Experience::new("b", vec![1, 2, 3], 1, 0.0);
        let out = SuccessAmplifier { reward_threshold: 0.5, factor: 3 }.apply(vec![good, bad]);
        assert_eq!(out.len(), 4); // 1 original + 2 copies + 1 bad
        let copies: Vec<_> = out.iter().filter(|e| e.parent_id == Some(7)).collect();
        assert_eq!(copies.len(), 2);
        assert!(copies.iter().all(|c| c.id == 0));
    }

    #[test]
    fn failure_repair_synthesizes_corrected() {
        let tok = std::sync::Arc::new(crate::tokenizer::Tokenizer::new());
        let prompt = tok.encode_prompt("what is 2 + 2 ?");
        let plen = prompt.len();
        let mut tokens = prompt;
        tokens.extend(tok.encode("5"));
        let mut e = Experience::new("t", tokens, plen, 0.0);
        e.id = 3;
        e.set_meta("gold_answer", Value::str("4"));
        let out = FailureRepair { tokenizer: tok.clone() }.apply(vec![e]);
        assert_eq!(out.len(), 2);
        let repaired = &out[0];
        assert_eq!(repaired.reward, 1.0);
        assert_eq!(repaired.source, Source::Synthetic);
        assert_eq!(repaired.parent_id, Some(3));
        assert_eq!(tok.decode_response(&repaired.tokens, repaired.prompt_len), "4");
    }

    #[test]
    fn pool_chains_operators() {
        let mut pool = OperatorPool::default();
        pool.push(Box::new(QualityScorer));
        pool.push(Box::new(LengthFilter { min_tokens: 1, max_tokens: 100 }));
        let out = pool.apply(vec![exp_with_response(vec![1, 2, 3], 1, 0.0, "7")]);
        assert_eq!(out.len(), 1);
        assert!(out[0].meta_f64("quality").is_some());
        assert_eq!(pool.names(), vec!["quality_scorer", "length_filter"]);
    }
}
