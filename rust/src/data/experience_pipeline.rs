//! Active experience shaping (paper §2.3.3): processors applied between
//! explorer and trainer, at every RFT step, so the reward signal adapts to
//! the evolving policy.
//!
//! * [`QualityRewardProcessor`] — Fig. 12: add a quality score in
//!   [-0.5, 0.5] to the sparse rule reward.
//! * [`DiversityRewardProcessor`] — Fig. 14: reward distance from the
//!   group-mean embedding (policy-collapse counterweight) with a decaying
//!   weight schedule.
//! * [`ShapingBuffer`] — the adapter that interposes a processor chain on
//!   every buffer write, so any mode picks up shaping transparently.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::buffer::{Experience, ExperienceBuffer};
use crate::explorer::GenerationEngine;
use crate::runtime::Tensor;
use crate::util::json::Value;

use super::operators::QualityScorer;

/// A shaping stage: transform a batch of fresh experiences before they
/// become visible to the trainer.
pub trait ExperienceProcessor: Send + Sync {
    fn name(&self) -> &'static str;
    fn process(&self, exps: Vec<Experience>) -> Result<Vec<Experience>>;
}

/// Chain of processors applied in order.
pub struct ChainProcessor {
    pub stages: Vec<Arc<dyn ExperienceProcessor>>,
}

impl ExperienceProcessor for ChainProcessor {
    fn name(&self) -> &'static str {
        "chain"
    }
    fn process(&self, mut exps: Vec<Experience>) -> Result<Vec<Experience>> {
        for s in &self.stages {
            exps = s.process(exps)?;
        }
        Ok(exps)
    }
}

/// Fig. 12: reward += weight * quality, quality in [-0.5, 0.5].
pub struct QualityRewardProcessor {
    pub weight: f32,
}

impl ExperienceProcessor for QualityRewardProcessor {
    fn name(&self) -> &'static str {
        "quality_reward"
    }
    fn process(&self, exps: Vec<Experience>) -> Result<Vec<Experience>> {
        let scorer = QualityScorer;
        Ok(exps
            .into_iter()
            .map(|mut e| {
                let q = scorer.score(&e) as f32;
                e.set_meta("quality", Value::num(q as f64));
                e.set_meta("base_reward", Value::num(e.reward as f64));
                e.reward += self.weight * q;
                e
            })
            .collect())
    }
}

/// Fig. 14: diversity reward = 1 - cos(embedding, group mean), weighted by
/// a schedule decaying from `w_start` to `w_end` over `decay_steps` calls.
/// Embeddings come from the policy model's pooled-embedding artifact (the
/// GTE-embedder stand-in).
pub struct DiversityRewardProcessor {
    pub engine: Arc<GenerationEngine>,
    pub w_start: f32,
    pub w_end: f32,
    pub decay_steps: u64,
    calls: AtomicU64,
}

impl DiversityRewardProcessor {
    pub fn new(engine: Arc<GenerationEngine>, w_start: f32, w_end: f32, decay_steps: u64) -> Self {
        DiversityRewardProcessor { engine, w_start, w_end, decay_steps, calls: AtomicU64::new(0) }
    }

    fn current_weight(&self) -> f32 {
        let t = self.calls.fetch_add(1, Ordering::SeqCst) as f32;
        let frac = (t / self.decay_steps.max(1) as f32).min(1.0);
        self.w_start + (self.w_end - self.w_start) * frac
    }

    /// Compute embeddings for the batch through the embed artifact,
    /// bucketing to the artifact's [B, T] shape.
    fn embeddings(&self, exps: &[Experience]) -> Result<Vec<Vec<f32>>> {
        let engine = self.engine.engine();
        let (b, t) = engine.seq_shape();
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(exps.len());
        let snapshot = self.engine.snapshot_weights()?;
        let params = crate::model::ParamStore::from_snapshot(&engine.model, &snapshot)?;
        for chunk in exps.chunks(b) {
            let mut tokens = vec![0i32; b * t];
            let mut mask = vec![0f32; b * t];
            for (i, e) in chunk.iter().enumerate() {
                let n = e.tokens.len().min(t);
                tokens[i * t..i * t + n].copy_from_slice(&e.tokens[..n]);
                for j in 0..n {
                    mask[i * t + j] = 1.0;
                }
            }
            let emb = engine.embed(
                &params,
                &Tensor::from_i32(vec![b, t], tokens),
                &Tensor::from_f32(vec![b, t], mask),
            )?;
            for i in 0..chunk.len() {
                out.push(emb.row_f32(i)?.to_vec());
            }
        }
        Ok(out)
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na * nb < 1e-12 {
        0.0
    } else {
        dot / (na * nb)
    }
}

impl ExperienceProcessor for DiversityRewardProcessor {
    fn name(&self) -> &'static str {
        "diversity_reward"
    }
    fn process(&self, mut exps: Vec<Experience>) -> Result<Vec<Experience>> {
        if exps.is_empty() {
            return Ok(exps);
        }
        let weight = self.current_weight();
        let embeddings = self.embeddings(&exps)?;
        // group-mean embeddings
        let mut groups: HashMap<u64, (Vec<f32>, usize)> = HashMap::new();
        let dim = embeddings[0].len();
        for (e, emb) in exps.iter().zip(&embeddings) {
            let entry = groups.entry(e.group).or_insert_with(|| (vec![0.0; dim], 0));
            for (s, v) in entry.0.iter_mut().zip(emb) {
                *s += v;
            }
            entry.1 += 1;
        }
        for (sum, n) in groups.values_mut() {
            for s in sum.iter_mut() {
                *s /= *n as f32;
            }
        }
        for (e, emb) in exps.iter_mut().zip(&embeddings) {
            let mean = &groups[&e.group].0;
            let diversity = 1.0 - cosine(emb, mean);
            e.set_meta("diversity", Value::num(diversity as f64));
            e.set_meta("diversity_weight", Value::num(weight as f64));
            e.reward += weight * diversity;
        }
        Ok(exps)
    }
}

/// Buffer adapter: apply a processor chain on every write, then forward.
/// This is how shaping interposes between explorer and trainer in all
/// modes without either knowing (paper Fig. 5, right side).
pub struct ShapingBuffer {
    inner: Arc<dyn ExperienceBuffer>,
    processor: Arc<dyn ExperienceProcessor>,
}

impl ShapingBuffer {
    pub fn new(inner: Arc<dyn ExperienceBuffer>, processor: Arc<dyn ExperienceProcessor>) -> Self {
        ShapingBuffer { inner, processor }
    }
}

impl ExperienceBuffer for ShapingBuffer {
    fn write(&self, exps: Vec<Experience>) -> Result<()> {
        let shaped = self.processor.process(exps)?;
        if shaped.is_empty() {
            return Ok(());
        }
        self.inner.write(shaped)
    }
    fn read(&self, n: usize, timeout: Duration) -> Result<Vec<Experience>> {
        self.inner.read(n, timeout)
    }
    fn ready_len(&self) -> usize {
        self.inner.ready_len()
    }
    fn total_written(&self) -> u64 {
        self.inner.total_written()
    }
    fn close(&self) {
        self.inner.close()
    }
}

/// Operator-pool-backed processor (clean/filter/synthesize stages built
/// from `data::operators`).
pub struct OperatorProcessor {
    pub pool: super::operators::OperatorPool,
}

impl ExperienceProcessor for OperatorProcessor {
    fn name(&self) -> &'static str {
        "operators"
    }
    fn process(&self, exps: Vec<Experience>) -> Result<Vec<Experience>> {
        Ok(self.pool.apply(exps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::QueueBuffer;

    fn exp(resp: &str, reward: f32, group: u64) -> Experience {
        let mut e = Experience::new("t", vec![1, 10, 11, 2], 1, reward);
        e.group = group;
        e.set_meta("response", Value::str(resp));
        e
    }

    #[test]
    fn quality_reward_augments() {
        let p = QualityRewardProcessor { weight: 1.0 };
        let out = p.process(vec![exp("42", 1.0, 1), exp("", 0.0, 1)]).unwrap();
        // "42" gets positive quality, "" negative
        assert!(out[0].reward > 1.0);
        assert!(out[1].reward < 0.0);
        assert_eq!(out[0].meta_f64("base_reward"), Some(1.0));
    }

    #[test]
    fn shaping_buffer_applies_on_write() {
        let q = Arc::new(QueueBuffer::new(16));
        let shaped = ShapingBuffer::new(q.clone(), Arc::new(QualityRewardProcessor { weight: 1.0 }));
        shaped.write(vec![exp("42", 0.0, 1)]).unwrap();
        let got = shaped.read(1, Duration::from_millis(10)).unwrap();
        assert!(got[0].reward > 0.0);
        assert!(got[0].meta_f64("quality").is_some());
    }

    #[test]
    fn chain_runs_in_order() {
        let chain = ChainProcessor {
            stages: vec![
                Arc::new(QualityRewardProcessor { weight: 0.5 }),
                Arc::new(QualityRewardProcessor { weight: 0.5 }),
            ],
        };
        let out = chain.process(vec![exp("7", 0.0, 1)]).unwrap();
        // applied twice
        let q = out[0].meta_f64("quality").unwrap() as f32;
        assert!((out[0].reward - q).abs() < 1e-6);
    }

    #[test]
    fn cosine_helper() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
    }
}
