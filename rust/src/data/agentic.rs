//! Agent-driven data processing (paper §2.3.3): translate a high-level
//! natural-language objective ("improve response diversity and safety")
//! into an executable operator pipeline.  The translator is rule-based —
//! the framework seam is identical to the paper's (command -> pipeline),
//! with the LLM planner swapped for keyword rules per the substitution
//! policy in DESIGN.md.

use std::sync::Arc;

use crate::tokenizer::Tokenizer;

use super::experience_pipeline::{
    ChainProcessor, ExperienceProcessor, OperatorProcessor, QualityRewardProcessor,
};
use super::operators::{
    DedupFilter, FailureRepair, LengthFilter, OperatorPool, QualityScorer, SafetyFilter,
    SuccessAmplifier,
};

/// The plan produced from a command: named stages for transparency
/// (what the paper's UI shows) plus the executable processor.
pub struct AgenticPlan {
    pub stages: Vec<String>,
    pub processor: Arc<dyn ExperienceProcessor>,
}

/// Translate a natural-language processing objective into a pipeline.
pub fn translate_command(command: &str, tokenizer: Arc<Tokenizer>) -> AgenticPlan {
    let lower = command.to_lowercase();
    let mut pool = OperatorPool::default();
    let mut stages: Vec<String> = vec![];
    let mut extra: Vec<Arc<dyn ExperienceProcessor>> = vec![];

    if lower.contains("clean") || lower.contains("filter") || lower.contains("length") {
        pool.push(Box::new(LengthFilter { min_tokens: 1, max_tokens: 512 }));
        stages.push("length_filter".into());
    }
    if lower.contains("dedup") || lower.contains("duplicate") || lower.contains("diversity") {
        pool.push(Box::new(DedupFilter { similarity_threshold: 0.9 }));
        stages.push("dedup".into());
    }
    if lower.contains("safety") || lower.contains("safe") || lower.contains("toxic") {
        pool.push(Box::new(SafetyFilter));
        stages.push("safety_filter".into());
    }
    if lower.contains("quality") {
        pool.push(Box::new(QualityScorer));
        stages.push("quality_scorer".into());
        extra.push(Arc::new(QualityRewardProcessor { weight: 1.0 }));
        stages.push("quality_reward".into());
    }
    if lower.contains("amplif") || lower.contains("success") {
        pool.push(Box::new(SuccessAmplifier { reward_threshold: 0.5, factor: 2 }));
        stages.push("success_amplifier".into());
    }
    if lower.contains("repair") || lower.contains("fix") || lower.contains("failure") {
        pool.push(Box::new(FailureRepair { tokenizer: Arc::clone(&tokenizer) }));
        stages.push("failure_repair".into());
    }
    if stages.is_empty() {
        // default hygiene pipeline
        pool.push(Box::new(LengthFilter { min_tokens: 1, max_tokens: 512 }));
        pool.push(Box::new(DedupFilter { similarity_threshold: 1.0 }));
        stages = vec!["length_filter".into(), "dedup".into()];
    }

    let mut chain: Vec<Arc<dyn ExperienceProcessor>> = vec![Arc::new(OperatorProcessor { pool })];
    chain.extend(extra);
    AgenticPlan { stages, processor: Arc::new(ChainProcessor { stages: chain }) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::Experience;
    use crate::util::json::Value;

    fn tok() -> Arc<Tokenizer> {
        Arc::new(Tokenizer::new())
    }

    #[test]
    fn diversity_and_safety_command() {
        let plan =
            translate_command("improve response diversity and safety for coding scenarios", tok());
        assert!(plan.stages.contains(&"dedup".to_string()));
        assert!(plan.stages.contains(&"safety_filter".to_string()));
    }

    #[test]
    fn quality_command_builds_reward_stage() {
        let plan = translate_command("improve quality", tok());
        assert!(plan.stages.contains(&"quality_reward".to_string()));
        let mut e = Experience::new("t", vec![1, 10, 11, 2], 1, 0.0);
        e.set_meta("response", Value::str("42"));
        let out = plan.processor.process(vec![e]).unwrap();
        assert!(out[0].reward > 0.0);
    }

    #[test]
    fn empty_command_gets_default_hygiene() {
        let plan = translate_command("do something", tok());
        assert_eq!(plan.stages, vec!["length_filter", "dedup"]);
    }

    #[test]
    fn pipeline_executes_end_to_end() {
        let plan = translate_command("dedup and amplify successes", tok());
        let mut good = Experience::new("g", vec![1, 10, 11, 12, 13, 2], 1, 1.0);
        good.set_meta("response", Value::str("9"));
        let dup = good.clone();
        let out = plan.processor.process(vec![good, dup]).unwrap();
        // dedup drops the copy, amplifier duplicates the survivor
        assert_eq!(out.len(), 2);
        assert!(out[1].metadata.get("amplified").is_some());
    }
}
