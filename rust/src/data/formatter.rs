//! The Formatter module (paper §2.3.1): convert disparate raw records
//! (prompt/response pairs, QA with tagged rewards, preference pairs) into
//! the structured task / experience / DPO schemas, with field
//! normalization and metadata recording.

use anyhow::{Context, Result};

use crate::buffer::{Experience, Source};
use crate::explorer::Task;
use crate::tokenizer::Tokenizer;
use crate::util::json::Value;

/// Field mapping for raw records (the paper's `format:` config block).
#[derive(Debug, Clone)]
pub struct FormatSpec {
    pub prompt_key: String,
    pub response_key: String,
    pub reward_key: Option<String>,
}

impl Default for FormatSpec {
    fn default() -> Self {
        FormatSpec {
            prompt_key: "question".into(),
            response_key: "answer".into(),
            reward_key: None,
        }
    }
}

pub struct Formatter {
    pub spec: FormatSpec,
    pub tokenizer: std::sync::Arc<Tokenizer>,
}

impl Formatter {
    /// Raw record -> rollout Task (the task-pipeline input path).
    pub fn to_task(&self, id: &str, workflow: &str, raw: &Value) -> Result<Task> {
        let question = raw
            .get(&self.spec.prompt_key)
            .and_then(Value::as_str)
            .with_context(|| format!("raw record missing '{}'", self.spec.prompt_key))?;
        let answer = raw.get(&self.spec.response_key).and_then(Value::as_str).unwrap_or("");
        let mut payload = Value::obj(vec![
            ("question", Value::str(question)),
            ("answer", Value::str(answer)),
        ]);
        if let Some(d) = raw.get("difficulty") {
            payload.set("difficulty", d.clone());
        }
        let mut t = Task::new(id, workflow, payload);
        t.difficulty = raw.get("difficulty").and_then(Value::as_f64).unwrap_or(0.0);
        Ok(t)
    }

    /// Raw (prompt, response[, reward]) -> expert Experience (SFT/MIX
    /// warm-start data, paper §3.2).
    pub fn to_expert_experience(&self, raw: &Value) -> Result<Experience> {
        let prompt = raw
            .get(&self.spec.prompt_key)
            .and_then(Value::as_str)
            .with_context(|| format!("raw record missing '{}'", self.spec.prompt_key))?;
        let response = raw
            .get(&self.spec.response_key)
            .and_then(Value::as_str)
            .with_context(|| format!("raw record missing '{}'", self.spec.response_key))?;
        let reward = self
            .spec
            .reward_key
            .as_ref()
            .and_then(|k| raw.get(k))
            .and_then(Value::as_f64)
            .unwrap_or(1.0) as f32;
        let mut tokens = self.tokenizer.encode_prompt(prompt);
        let plen = tokens.len();
        tokens.extend(self.tokenizer.encode(response));
        tokens.push(crate::tokenizer::EOS);
        let mut e = Experience::new("expert", tokens, plen, reward);
        e.source = Source::Expert;
        e.set_meta("response", Value::str(response));
        Ok(e)
    }

    /// Raw preference record -> a chosen/rejected Experience pair sharing
    /// `pair_id` (the DPODataModel analog).
    pub fn to_preference_pair(&self, pair_id: u64, raw: &Value) -> Result<(Experience, Experience)> {
        let prompt = raw
            .get(&self.spec.prompt_key)
            .and_then(Value::as_str)
            .context("preference record missing prompt")?;
        let chosen = raw.get("chosen").and_then(Value::as_str).context("missing 'chosen'")?;
        let rejected = raw.get("rejected").and_then(Value::as_str).context("missing 'rejected'")?;
        let build = |resp: &str, role: &str, reward: f32| -> Experience {
            let mut tokens = self.tokenizer.encode_prompt(prompt);
            let plen = tokens.len();
            tokens.extend(self.tokenizer.encode(resp));
            tokens.push(crate::tokenizer::EOS);
            let mut e = Experience::new(&format!("pref-{pair_id}"), tokens, plen, reward);
            e.source = Source::Human;
            e.group = pair_id;
            e.set_meta("pair", Value::num(pair_id as f64));
            e.set_meta("role", Value::str(role));
            e.set_meta("response", Value::str(resp));
            e
        };
        Ok((build(chosen, "chosen", 1.0), build(rejected, "rejected", 0.0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn formatter() -> Formatter {
        Formatter { spec: FormatSpec::default(), tokenizer: std::sync::Arc::new(Tokenizer::new()) }
    }

    #[test]
    fn raw_to_task() {
        let f = formatter();
        let raw = Value::obj(vec![
            ("question", Value::str("what is 1 + 1")),
            ("answer", Value::str("2")),
            ("difficulty", Value::num(3.0)),
        ]);
        let t = f.to_task("t1", "math", &raw).unwrap();
        assert_eq!(t.payload_str("question").unwrap(), "what is 1 + 1");
        assert_eq!(t.difficulty, 3.0);
    }

    #[test]
    fn raw_to_expert_experience() {
        let f = formatter();
        let raw = Value::obj(vec![
            ("question", Value::str("what is 2 + 2")),
            ("answer", Value::str("4")),
        ]);
        let e = f.to_expert_experience(&raw).unwrap();
        assert_eq!(e.source, Source::Expert);
        assert_eq!(e.reward, 1.0);
        assert!(e.response_len() >= 2); // "4" + EOS
        assert_eq!(f.tokenizer.decode_response(&e.tokens, e.prompt_len), "4");
    }

    #[test]
    fn raw_to_preference_pair() {
        let f = formatter();
        let raw = Value::obj(vec![
            ("question", Value::str("pick one")),
            ("chosen", Value::str("good answer")),
            ("rejected", Value::str("bad")),
        ]);
        let (c, r) = f.to_preference_pair(9, &raw).unwrap();
        assert_eq!(c.metadata.get("role").unwrap().as_str(), Some("chosen"));
        assert_eq!(r.metadata.get("role").unwrap().as_str(), Some("rejected"));
        assert_eq!(c.meta_f64("pair"), Some(9.0));
        assert_eq!(c.group, r.group);
    }

    #[test]
    fn missing_fields_error() {
        let f = formatter();
        assert!(f.to_task("x", "math", &Value::obj(vec![("other", Value::str("y"))])).is_err());
        assert!(f.to_preference_pair(1, &Value::obj(vec![("question", Value::str("q"))])).is_err());
    }
}
