//! Deterministic hybrid word/char tokenizer.
//!
//! The whole corpus is synthetic (envs generate task text in Rust), so the
//! vocabulary is fixed at build time: special tokens, digits, a curated
//! word list covering the math / grid-world domains, then printable ASCII
//! as character fallback.  Encoding is greedy word-level with char
//! fallback; decoding is exact for single-spaced text (round-trip tested).
//!
//! Python never sees text — the model config only fixes `vocab_size`, and
//! this tokenizer guarantees every id < 256, fitting every preset.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const UNK: i32 = 4;

/// Words the synthetic envs emit; keeping them single tokens keeps
/// sequences short enough for the tiny/small shape buckets.
const WORDS: &[&str] = &[
    // math domain
    "what", "is", "compute", "calculate", "answer", "question", "equals", "sum", "of",
    "plus", "minus", "times", "divided", "by", "and", "then", "result", "the", "a",
    "has", "gets", "loses", "buys", "gives", "apples", "coins", "books", "total",
    "how", "many", "left", "now", "more", "away", "starts", "with",
    // grid-world domain
    "go", "take", "put", "open", "look", "in", "on", "room", "kitchen", "hall",
    "office", "garden", "box", "chest", "drawer", "shelf", "table", "apple", "key",
    "ball", "lamp", "book", "cup", "you", "are", "see", "closed", "empty", "holding",
    "nothing", "done", "goal", "task", "move", "to", "from", "it", "at", "there",
    // dialogue scaffolding
    "user", "assistant", "system", "turn", "ok", "yes", "no", "think", "step",
];

pub struct Tokenizer {
    vocab: Vec<String>,
    word_ids: HashMap<String, i32>,
    char_ids: HashMap<char, i32>,
    space_id: i32,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Tokenizer {
        let mut vocab: Vec<String> =
            vec!["<pad>".into(), "<bos>".into(), "<eos>".into(), "<sep>".into(), "<unk>".into()];
        let mut word_ids = HashMap::new();
        let mut char_ids = HashMap::new();

        // explicit space token
        let space_id = vocab.len() as i32;
        vocab.push(" ".into());

        for w in WORDS {
            word_ids.insert(w.to_string(), vocab.len() as i32);
            vocab.push(w.to_string());
        }
        // printable ASCII chars as fallback units (also covers digits,
        // operators, punctuation)
        for c in 33u8..127 {
            let ch = c as char;
            char_ids.insert(ch, vocab.len() as i32);
            vocab.push(ch.to_string());
        }
        assert!(vocab.len() <= 256, "tokenizer must fit every model preset");
        Tokenizer { vocab, word_ids, char_ids, space_id }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Greedy word-level encoding with char fallback; words separated by
    /// the explicit space token.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len());
        for (i, word) in text.split(' ').enumerate() {
            if i > 0 {
                out.push(self.space_id);
            }
            if word.is_empty() {
                continue;
            }
            if let Some(&id) = self.word_ids.get(word) {
                out.push(id);
            } else {
                for c in word.chars() {
                    out.push(*self.char_ids.get(&c).unwrap_or(&UNK));
                }
            }
        }
        out
    }

    /// Encode with BOS prefix and SEP suffix (the prompt convention all
    /// workflows use).
    pub fn encode_prompt(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        out.extend(self.encode(text));
        out.push(SEP);
        out
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let mut out = String::new();
        for &t in tokens {
            match t {
                PAD | BOS | EOS => {}
                SEP => out.push_str(" | "),
                UNK => out.push('\u{fffd}'),
                t if (t as usize) < self.vocab.len() => out.push_str(&self.vocab[t as usize]),
                _ => out.push('\u{fffd}'),
            }
        }
        out
    }

    /// Decode only the response part (after prompt_len), stopping at EOS.
    pub fn decode_response(&self, tokens: &[i32], prompt_len: usize) -> String {
        let resp: Vec<i32> =
            tokens[prompt_len.min(tokens.len())..].iter().copied().take_while(|&t| t != EOS).collect();
        self.decode(&resp)
    }

    pub fn eos(&self) -> i32 {
        EOS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_math_text() {
        let tok = Tokenizer::new();
        for text in [
            "what is 3 + 4 * 2 ?",
            "compute 12 - 5",
            "tom has 3 apples and buys 4 more",
            "answer: 42",
        ] {
            let ids = tok.encode(text);
            assert_eq!(tok.decode(&ids), text, "roundtrip failed for {text:?}");
        }
    }

    #[test]
    fn roundtrip_gridworld_text() {
        let tok = Tokenizer::new();
        for text in ["go kitchen", "take apple", "put apple in box", "you are in hall . see key"] {
            let ids = tok.encode(text);
            assert_eq!(tok.decode(&ids), text);
        }
    }

    #[test]
    fn known_words_are_single_tokens() {
        let tok = Tokenizer::new();
        assert_eq!(tok.encode("go").len(), 1);
        assert_eq!(tok.encode("kitchen").len(), 1);
        // unknown word falls back to chars
        assert_eq!(tok.encode("zxq").len(), 3);
    }

    #[test]
    fn digits_are_char_level() {
        let tok = Tokenizer::new();
        assert_eq!(tok.encode("42").len(), 2);
        assert_eq!(tok.encode("7").len(), 1);
    }

    #[test]
    fn prompt_framing() {
        let tok = Tokenizer::new();
        let ids = tok.encode_prompt("what is 1 + 1");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), SEP);
    }

    #[test]
    fn decode_response_stops_at_eos() {
        let tok = Tokenizer::new();
        let mut ids = tok.encode_prompt("q");
        let plen = ids.len();
        ids.extend(tok.encode("42"));
        ids.push(EOS);
        ids.extend(tok.encode("junk"));
        assert_eq!(tok.decode_response(&ids, plen), "42");
    }

    #[test]
    fn vocab_fits_smallest_preset() {
        assert!(Tokenizer::new().vocab_size() <= 256);
    }

    #[test]
    fn all_ids_in_range() {
        let tok = Tokenizer::new();
        let ids = tok.encode("the quick brown fox 123 !?");
        assert!(ids.iter().all(|&i| (0..tok.vocab_size() as i32).contains(&i)));
    }
}
