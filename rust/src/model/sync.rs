//! Model weight synchronization between trainer and explorer(s).
//!
//! Two implementations mirroring the paper (§2.1.2):
//! * [`MemorySync`] — the NCCL analog: an in-memory shared store, fast,
//!   available when explorer and trainer share a process ("same host").
//! * [`CheckpointSync`] — checkpoint save/load through a directory;
//!   slower but works across independently launched explorer/trainer
//!   processes, the mechanism the fully-async modes use.
//!
//! Both are versioned: the explorer pulls only when the trainer has
//! published something newer, and multiple explorers may pull the same
//! version at different moments (the multi-explorer mode's 24/7-service
//! property relies on this).
//!
//! Weight payloads move as [`Arc<WeightSnapshot>`]: one publish
//! materializes the host buffers once, and every consumer's
//! [`fetch_if_newer`](WeightSync::fetch_if_newer) is a refcount bump —
//! an N-replica pool pulling one version shares a single allocation
//! (see `DESIGN.md` §10).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use anyhow::{Context, Result};

use crate::util::Registry;

use super::checkpoint::{load_checkpoint, save_checkpoint};
use super::snapshot::WeightSnapshot;

/// One published weight version.  `Clone` is cheap by construction: the
/// snapshot is behind an `Arc`, so updates fan out to any number of
/// consumers without copying weight data.
#[derive(Debug, Clone)]
pub struct WeightUpdate {
    pub version: u64,
    pub step: u64,
    /// The published weights, shared across every consumer of this
    /// version (leaf buffers + per-leaf fingerprints for delta apply).
    pub snapshot: Arc<WeightSnapshot>,
}

/// The trainer→explorer weight distribution service.
///
/// Contract: `publish` makes `snapshot` the newest version visible to
/// every consumer; `fetch_if_newer` returns that version **without
/// copying weight data** (the returned [`WeightUpdate`] shares the
/// published `Arc<WeightSnapshot>`); `latest_version` is a cheap probe
/// safe to call on every admitted batch.
pub trait WeightSync: Send + Sync {
    /// Trainer-side: publish `snapshot` as `version` (monotonically
    /// increasing).  The snapshot is immutable from here on; publishers
    /// that reuse unchanged leaf buffers across versions (see
    /// `ParamStore::to_snapshot`) let consumers skip those leaves
    /// entirely on apply.
    fn publish(&self, version: u64, step: u64, snapshot: Arc<WeightSnapshot>) -> Result<()>;
    /// Explorer-side: fetch the newest published weights if newer than
    /// `current_version`.  Returns a shared handle, never a copy.
    fn fetch_if_newer(&self, current_version: u64) -> Result<Option<WeightUpdate>>;
    /// Latest published version (0 = nothing published).
    fn latest_version(&self) -> u64;
    /// Drop published versions older than the newest `keep` (the trainer
    /// driver calls this after each publish when `scheduler.keep_checkpoints`
    /// is set).  No-op for methods without durable storage.
    fn rotate(&self, keep: usize) -> Result<()> {
        let _ = keep;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// sync-method factory registry

/// Everything a sync-method factory may need at session build time.
pub struct SyncCtx {
    /// `sync.dir` from config, if any (checkpoint-style methods).
    pub dir: Option<PathBuf>,
    pub preset: String,
    /// Parameter leaf names + shapes, in pytree flattening order.
    pub leaf_names: Vec<(String, Vec<usize>)>,
}

/// Builds a [`WeightSync`] service from a [`SyncCtx`].  Implemented for
/// plain closures, so registration is one line.
pub trait WeightSyncFactory: Send + Sync {
    fn build(&self, ctx: &SyncCtx) -> Result<Arc<dyn WeightSync>>;
}

impl<F> WeightSyncFactory for F
where
    F: Fn(&SyncCtx) -> Result<Arc<dyn WeightSync>> + Send + Sync,
{
    fn build(&self, ctx: &SyncCtx) -> Result<Arc<dyn WeightSync>> {
        self(ctx)
    }
}

/// The sync-method registry (mirrors the trainer's `AlgorithmRegistry`):
/// `sync.method` names resolve here instead of through string dispatch in
/// the session builder.  Lookup is case-insensitive and unknown names
/// fail with the full method catalog.
pub struct WeightSyncRegistry {
    factories: Registry<Arc<dyn WeightSyncFactory>>,
}

impl WeightSyncRegistry {
    /// An empty registry (tests); production code uses [`global`](Self::global).
    pub fn new() -> WeightSyncRegistry {
        WeightSyncRegistry {
            factories: Registry::new(
                "sync method",
                "methods",
                "register custom methods with WeightSyncRegistry::global().register(..)",
                true,
            ),
        }
    }

    /// A registry pre-populated with the builtin methods
    /// (`memory`, `checkpoint`).
    pub fn with_builtins() -> WeightSyncRegistry {
        let r = WeightSyncRegistry::new();
        r.register("memory", |_ctx: &SyncCtx| -> Result<Arc<dyn WeightSync>> {
            Ok(Arc::new(MemorySync::new()))
        });
        r.register("checkpoint", |ctx: &SyncCtx| -> Result<Arc<dyn WeightSync>> {
            let dir =
                ctx.dir.clone().unwrap_or_else(|| std::env::temp_dir().join("trft_sync"));
            Ok(Arc::new(CheckpointSync::new(dir, &ctx.preset, ctx.leaf_names.clone())?))
        });
        r
    }

    /// The process-wide registry.  Custom sync methods register here
    /// before building a session:
    ///
    /// ```ignore
    /// WeightSyncRegistry::global().register("my_rdma", |ctx: &SyncCtx| {
    ///     Ok(Arc::new(MyRdmaSync::new(ctx)?) as Arc<dyn WeightSync>)
    /// });
    /// ```
    pub fn global() -> &'static WeightSyncRegistry {
        static GLOBAL: OnceLock<WeightSyncRegistry> = OnceLock::new();
        GLOBAL.get_or_init(WeightSyncRegistry::with_builtins)
    }

    /// Register a factory under `name` (stored lowercased; latest wins).
    pub fn register(&self, name: &str, factory: impl WeightSyncFactory + 'static) {
        self.factories.insert(name, Arc::new(factory));
    }

    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains(name)
    }

    /// Registered method names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.names()
    }

    /// Resolve `name` (case-insensitive) and build the service.
    pub fn build(&self, name: &str, ctx: &SyncCtx) -> Result<Arc<dyn WeightSync>> {
        self.factories.lookup(name)?.build(ctx)
    }
}

impl Default for WeightSyncRegistry {
    fn default() -> Self {
        WeightSyncRegistry::new()
    }
}

// ---------------------------------------------------------------------------
// in-memory (NCCL analog)

#[derive(Default)]
struct MemShared {
    state: Mutex<Option<WeightUpdate>>,
    cvar: Condvar,
    /// Mirror of the published version, updated inside the publish
    /// critical section: version probes (`latest_version`) never touch
    /// the mutex — replica pools hit them on every admitted batch.
    latest: AtomicU64,
}

#[derive(Clone, Default)]
pub struct MemorySync {
    shared: Arc<MemShared>,
}

impl MemorySync {
    pub fn new() -> MemorySync {
        Self::default()
    }

    /// Block until a version newer than `current_version` is available (or
    /// timeout); used by tests and the synchronous mode's barrier.
    pub fn wait_for_newer(
        &self,
        current_version: u64,
        timeout: std::time::Duration,
    ) -> Option<WeightUpdate> {
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = self.shared.state.lock().unwrap();
        loop {
            if let Some(u) = &*guard {
                if u.version > current_version {
                    return Some(u.clone());
                }
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self.shared.cvar.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
            if res.timed_out() {
                return guard.clone().filter(|u| u.version > current_version);
            }
        }
    }
}

impl WeightSync for MemorySync {
    fn publish(&self, version: u64, step: u64, snapshot: Arc<WeightSnapshot>) -> Result<()> {
        let mut guard = self.shared.state.lock().unwrap();
        *guard = Some(WeightUpdate { version, step, snapshot });
        // Release pairs with the Acquire in latest_version(): a probe
        // that observes the new version will find it under the mutex
        self.shared.latest.store(version, Ordering::Release);
        self.shared.cvar.notify_all();
        Ok(())
    }

    fn fetch_if_newer(&self, current_version: u64) -> Result<Option<WeightUpdate>> {
        // lock-free probe first: the common already-current case pays
        // one atomic load, no mutex
        if self.shared.latest.load(Ordering::Acquire) <= current_version {
            return Ok(None);
        }
        let guard = self.shared.state.lock().unwrap();
        // the clone is two Arc bumps (snapshot + nothing else) — weight
        // data is never copied on the fetch path
        Ok(guard.clone().filter(|u| u.version > current_version))
    }

    fn latest_version(&self) -> u64 {
        self.shared.latest.load(Ordering::Acquire)
    }
}

// ---------------------------------------------------------------------------
// checkpoint directory (flexible path)

pub struct CheckpointSync {
    dir: PathBuf,
    preset: String,
    leaf_names: Vec<(String, Vec<usize>)>,
}

impl CheckpointSync {
    pub fn new(dir: impl Into<PathBuf>, preset: &str, leaf_names: Vec<(String, Vec<usize>)>) -> Result<CheckpointSync> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating sync dir {dir:?}"))?;
        Ok(CheckpointSync { dir, preset: preset.to_string(), leaf_names })
    }

    fn latest_path(&self) -> PathBuf {
        self.dir.join("LATEST")
    }

    fn ckpt_path(&self, version: u64) -> PathBuf {
        self.dir.join(format!("weights_v{version}.ckpt"))
    }

    fn read_latest(&self) -> u64 {
        std::fs::read_to_string(self.latest_path())
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0)
    }

    /// Remove checkpoints older than the newest `keep` (rotation).
    pub fn rotate(&self, keep: usize) -> Result<()> {
        let latest = self.read_latest();
        if latest as usize <= keep {
            return Ok(());
        }
        for v in 1..=(latest - keep as u64) {
            let _ = std::fs::remove_file(self.ckpt_path(v));
        }
        Ok(())
    }
}

impl WeightSync for CheckpointSync {
    fn publish(&self, version: u64, step: u64, snapshot: Arc<WeightSnapshot>) -> Result<()> {
        // serialize straight from the shared leaf buffers — no
        // intermediate Vec<Vec<f32>> materialization
        let leaves: Vec<(String, Vec<usize>, &[f32])> = self
            .leaf_names
            .iter()
            .enumerate()
            .map(|(i, (n, s))| (n.clone(), s.clone(), snapshot.leaf(i)))
            .collect();
        save_checkpoint(self.ckpt_path(version), &self.preset, step, version, &leaves)?;
        // atomic LATEST update
        let tmp = self.latest_path().with_extension("tmp");
        std::fs::write(&tmp, format!("{version}"))?;
        std::fs::rename(&tmp, self.latest_path())?;
        Ok(())
    }

    fn fetch_if_newer(&self, current_version: u64) -> Result<Option<WeightUpdate>> {
        // LATEST-read and file-load race against keep-N rotation: a
        // version read here can be rotated away before the load.  The
        // newest checkpoint always survives rotation, so re-reading
        // LATEST and retrying converges.
        let mut last_err = None;
        for _ in 0..3 {
            let latest = self.read_latest();
            if latest <= current_version {
                return Ok(None);
            }
            match load_checkpoint(self.ckpt_path(latest)) {
                Ok(ck) => {
                    // the decoded leaf vectors move into the snapshot —
                    // the old double-copy (decode, then weights() clone)
                    // is gone
                    let (version, step) = (ck.weight_version, ck.step);
                    return Ok(Some(WeightUpdate {
                        version,
                        step,
                        snapshot: ck.into_snapshot(),
                    }));
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap().context("checkpoint vanished beneath fetch (rotation race)"))
    }

    fn latest_version(&self) -> u64 {
        self.read_latest()
    }

    fn rotate(&self, keep: usize) -> Result<()> {
        CheckpointSync::rotate(self, keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights(tag: f32) -> Arc<WeightSnapshot> {
        WeightSnapshot::of(vec![vec![tag; 4], vec![tag * 2.0; 2]])
    }

    #[test]
    fn memory_sync_versioning() {
        let s = MemorySync::new();
        assert!(s.fetch_if_newer(0).unwrap().is_none());
        s.publish(1, 10, weights(1.0)).unwrap();
        let u = s.fetch_if_newer(0).unwrap().unwrap();
        assert_eq!((u.version, u.step), (1, 10));
        assert!(s.fetch_if_newer(1).unwrap().is_none());
        s.publish(2, 20, weights(2.0)).unwrap();
        assert_eq!(s.fetch_if_newer(1).unwrap().unwrap().snapshot.leaf(0)[0], 2.0);
        assert_eq!(s.latest_version(), 2);
    }

    #[test]
    fn memory_fetch_shares_the_published_allocation() {
        let s = MemorySync::new();
        let published = weights(4.0);
        s.publish(1, 1, Arc::clone(&published)).unwrap();
        let a = s.fetch_if_newer(0).unwrap().unwrap();
        let b = s.fetch_if_newer(0).unwrap().unwrap();
        assert!(Arc::ptr_eq(&a.snapshot, &published));
        assert!(Arc::ptr_eq(&a.snapshot, &b.snapshot));
    }

    #[test]
    fn memory_sync_wait_wakes_on_publish() {
        let s = MemorySync::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.wait_for_newer(0, std::time::Duration::from_secs(5)));
        std::thread::sleep(std::time::Duration::from_millis(30));
        s.publish(1, 1, weights(3.0)).unwrap();
        let u = h.join().unwrap().unwrap();
        assert_eq!(u.version, 1);
    }

    #[test]
    fn checkpoint_sync_roundtrip_and_rotation() {
        let dir = std::env::temp_dir().join(format!("trft_sync_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let names = vec![("a".to_string(), vec![4]), ("b".to_string(), vec![2])];
        let s = CheckpointSync::new(&dir, "tiny", names).unwrap();
        assert!(s.fetch_if_newer(0).unwrap().is_none());
        for v in 1..=4 {
            s.publish(v, v * 100, weights(v as f32)).unwrap();
        }
        let u = s.fetch_if_newer(2).unwrap().unwrap();
        assert_eq!(u.version, 4);
        assert_eq!(u.step, 400);
        assert_eq!(u.snapshot.leaf(1)[0], 8.0);
        s.rotate(1).unwrap();
        assert!(!dir.join("weights_v1.ckpt").exists());
        assert!(dir.join("weights_v4.ckpt").exists());
        // fetch still works after rotation
        assert!(s.fetch_if_newer(0).unwrap().is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_roundtrip_preserves_fingerprints() {
        // fingerprints are content-derived, so a checkpoint hop must
        // reproduce them exactly (delta apply keeps working across the
        // durable path)
        let dir = std::env::temp_dir().join(format!("trft_sync_fp_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let names = vec![("a".to_string(), vec![4]), ("b".to_string(), vec![2])];
        let s = CheckpointSync::new(&dir, "tiny", names).unwrap();
        let published = weights(7.0);
        s.publish(1, 10, Arc::clone(&published)).unwrap();
        let u = s.fetch_if_newer(0).unwrap().unwrap();
        assert_eq!(u.snapshot.fingerprints(), published.fingerprints());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn registry_resolves_builtins_case_insensitively() {
        let reg = WeightSyncRegistry::global();
        assert_eq!(reg.names(), vec!["checkpoint", "memory"]);
        let ctx = SyncCtx { dir: None, preset: "tiny".into(), leaf_names: vec![] };
        for name in ["memory", "MEMORY", " Memory "] {
            let s = reg.build(name, &ctx).unwrap();
            assert_eq!(s.latest_version(), 0);
        }
    }

    #[test]
    fn registry_unknown_method_lists_catalog() {
        let ctx = SyncCtx { dir: None, preset: "tiny".into(), leaf_names: vec![] };
        let err =
            WeightSyncRegistry::global().build("warp", &ctx).unwrap_err().to_string();
        assert!(err.contains("unknown sync method 'warp'"), "{err}");
        for method in ["memory", "checkpoint"] {
            assert!(err.contains(method), "error should list '{method}': {err}");
        }
    }

    #[test]
    fn registry_accepts_custom_factories() {
        let reg = WeightSyncRegistry::new();
        reg.register("shared", |_ctx: &SyncCtx| -> Result<Arc<dyn WeightSync>> {
            Ok(Arc::new(MemorySync::new()))
        });
        let ctx = SyncCtx { dir: None, preset: "tiny".into(), leaf_names: vec![] };
        let s = reg.build("Shared", &ctx).unwrap();
        s.publish(1, 1, weights(1.0)).unwrap();
        assert_eq!(s.latest_version(), 1);
        assert!(reg.build("memory", &ctx).is_err()); // builtins not inherited
    }

    #[test]
    fn registry_checkpoint_builds_with_default_dir() {
        let ctx = SyncCtx {
            dir: Some(std::env::temp_dir().join(format!("trft_reg_{}", std::process::id()))),
            preset: "tiny".into(),
            leaf_names: vec![("a".to_string(), vec![4])],
        };
        let s = WeightSyncRegistry::global().build("Checkpoint", &ctx).unwrap();
        s.publish(1, 5, WeightSnapshot::of(vec![vec![1.0; 4]])).unwrap();
        assert_eq!(s.latest_version(), 1);
        std::fs::remove_dir_all(ctx.dir.unwrap()).unwrap();
    }

    #[test]
    fn rotate_dispatches_through_the_trait_object() {
        // memory sync: rotation is a no-op
        let mem: Arc<dyn WeightSync> = Arc::new(MemorySync::new());
        mem.publish(1, 1, weights(1.0)).unwrap();
        mem.rotate(1).unwrap();
        assert_eq!(mem.latest_version(), 1);
        // checkpoint sync: the trait call reaches the inherent rotation
        let dir = std::env::temp_dir().join(format!("trft_rot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let names = vec![("a".to_string(), vec![4]), ("b".to_string(), vec![2])];
        let ck: Arc<dyn WeightSync> = Arc::new(CheckpointSync::new(&dir, "tiny", names).unwrap());
        for v in 1..=3 {
            ck.publish(v, v, weights(v as f32)).unwrap();
        }
        ck.rotate(1).unwrap();
        assert!(!dir.join("weights_v1.ckpt").exists());
        assert!(dir.join("weights_v3.ckpt").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn multiple_consumers_can_pull_same_version() {
        let s = MemorySync::new();
        s.publish(5, 50, weights(5.0)).unwrap();
        // two explorers at different versions both get v5
        assert_eq!(s.fetch_if_newer(0).unwrap().unwrap().version, 5);
        assert_eq!(s.fetch_if_newer(3).unwrap().unwrap().version, 5);
    }
}
