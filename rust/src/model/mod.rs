//! Model-state management: the parameter store (leaf order mirrors the
//! jax pytree flattening), binary checkpoints, and the weight-sync service
//! connecting trainer to explorer(s).

pub mod checkpoint;
pub mod params;
pub mod snapshot;
pub mod sync;

pub use checkpoint::{load_checkpoint, save_checkpoint, Checkpoint};
pub use params::{ParamStore, PreparedLeaves};
pub use snapshot::{fingerprint_f32, WeightSnapshot};
pub use sync::{
    CheckpointSync, MemorySync, SyncCtx, WeightSync, WeightSyncFactory, WeightSyncRegistry,
    WeightUpdate,
};
