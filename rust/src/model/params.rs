//! The parameter store: model weights held as PJRT literals in manifest
//! leaf order (identical to jax's sorted-dict pytree flattening, which is
//! the AOT contract).
//!
//! Consumers on the weight-distribution path additionally track the
//! content fingerprint of the snapshot leaf each literal was last built
//! from (`applied`), so applying a new [`WeightSnapshot`] rebuilds only
//! the leaves whose content actually changed (dirty-leaf delta apply).
//! The rebuild itself can be split into a lock-free *prepare* phase
//! ([`ParamStore::prepare_leaves`], parallelized over large leaves) and
//! a short *commit* ([`ParamStore::commit_prepared`]) that only swaps
//! literal handles — see `GenerationEngine::apply_update`.

use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, ensure, Context, Result};

use crate::exec::ThreadPool;
use crate::runtime::artifact::ModelInfo;
use crate::util::rng::Rng;

use super::snapshot::{fingerprint_f32, WeightSnapshot};

/// Fingerprint sentinel: "host content unknown" (set after a device
/// train step replaces the literals).  Real fingerprints are never 0.
const FP_UNKNOWN: u64 = 0;

/// Leaves at or above this element count are rebuilt on the shared
/// prepare pool; smaller ones are cheaper to build inline than to ship
/// across threads.
const POOL_LEAF_THRESHOLD: usize = 1 << 15;

pub struct ParamStore {
    pub model: ModelInfo,
    literals: Vec<xla::Literal>,
    version: u64,
    /// Per-leaf content fingerprint of the snapshot leaf each literal
    /// was last built from ([`FP_UNKNOWN`] when nothing is known).
    applied: Vec<u64>,
    /// Cumulative leaves *skipped* by delta applies (fingerprint hits).
    fingerprint_hits: u64,
}

// Literals are host-memory buffers behind raw pointers; moving them across
// threads is safe (the PJRT CPU client synchronizes internally), the auto
// impls are only blocked by the raw pointers in the `xla` wrappers.
unsafe impl Send for ParamStore {}
unsafe impl Sync for ParamStore {}

/// A literal crossing from a prepare worker back to the committer; same
/// safety argument as the `ParamStore` impls above.
struct SendLit(xla::Literal);
unsafe impl Send for SendLit {}

/// Shared pool for the prepare phase of weight applies.  Small and
/// lazily built: applies are bursty (one per publish per consumer) and
/// the work is memcpy-bound, so a few threads saturate it.
fn prepare_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let size = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 4);
        ThreadPool::new("weight-apply", size)
    })
}

/// Leaf literals rebuilt outside the params lock, ready to swap in:
/// `(leaf index, literal, fingerprint it was built from)`.
pub struct PreparedLeaves {
    leaves: Vec<(usize, xla::Literal, u64)>,
}

impl PreparedLeaves {
    /// No pre-built leaves: `commit_prepared` rebuilds every dirty leaf
    /// inline (the non-parallel apply path).
    pub fn none() -> PreparedLeaves {
        PreparedLeaves { leaves: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.leaves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leaves.is_empty()
    }
}

impl ParamStore {
    /// Initialize parameters exactly as `model.init_params` does shape-wise:
    /// normal(0, std) for weight matrices, ones for norm scales.  (The RNG
    /// differs from jax's — initial weights are random either way; tests
    /// that need numeric parity load a checkpoint instead.)
    pub fn init(model: &ModelInfo, seed: u64) -> Result<ParamStore> {
        let mut rng = Rng::new(seed);
        let mut literals = Vec::with_capacity(model.params.len());
        for (i, p) in model.params.iter().enumerate() {
            let n = p.element_count();
            let mut leaf_rng = rng.fork(i as u64);
            let data: Vec<f32> = if p.init_std == 0.0 {
                vec![1.0; n]
            } else {
                (0..n).map(|_| (leaf_rng.normal() * p.init_std) as f32).collect()
            };
            literals.push(to_literal(&data, &p.shape)?);
        }
        let _ = rng.next_u64();
        let applied = vec![FP_UNKNOWN; literals.len()];
        Ok(ParamStore { model: model.clone(), literals, version: 0, applied, fingerprint_hits: 0 })
    }

    /// Build from a host snapshot (leaf order must match the manifest).
    pub fn from_snapshot(model: &ModelInfo, weights: &[Vec<f32>]) -> Result<ParamStore> {
        ensure!(weights.len() == model.params.len(), "snapshot leaf count mismatch");
        let mut literals = Vec::with_capacity(weights.len());
        for (p, w) in model.params.iter().zip(weights) {
            ensure!(w.len() == p.element_count(), "leaf '{}' size mismatch", p.name);
            literals.push(to_literal(w, &p.shape)?);
        }
        let applied = vec![FP_UNKNOWN; literals.len()];
        Ok(ParamStore { model: model.clone(), literals, version: 0, applied, fingerprint_hits: 0 })
    }

    /// Build from a shared [`WeightSnapshot`], recording its fingerprints
    /// so a later delta apply starts warm.
    pub fn from_weight_snapshot(model: &ModelInfo, snapshot: &WeightSnapshot) -> Result<ParamStore> {
        ensure!(snapshot.leaf_count() == model.params.len(), "snapshot leaf count mismatch");
        let mut literals = Vec::with_capacity(snapshot.leaf_count());
        for (i, p) in model.params.iter().enumerate() {
            ensure!(snapshot.leaf(i).len() == p.element_count(), "leaf '{}' size mismatch", p.name);
            literals.push(to_literal(snapshot.leaf(i), &p.shape)?);
        }
        let applied = snapshot.fingerprints().to_vec();
        Ok(ParamStore { model: model.clone(), literals, version: 0, applied, fingerprint_hits: 0 })
    }

    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }

    pub fn leaf_count(&self) -> usize {
        self.literals.len()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn set_version(&mut self, v: u64) {
        self.version = v;
    }

    /// Leaves skipped by delta applies so far (cumulative; tests assert
    /// a partial update rebuilds exactly the dirty leaves).
    pub fn fingerprint_hits(&self) -> u64 {
        self.fingerprint_hits
    }

    /// Replace all leaves (e.g. with a train step's outputs). Bumps version.
    pub fn replace(&mut self, literals: Vec<xla::Literal>) -> Result<()> {
        ensure!(literals.len() == self.literals.len(), "leaf count mismatch on replace");
        self.literals = literals;
        // device outputs: host content unknown until the next snapshot,
        // so a subsequent apply must treat every leaf as dirty
        self.applied.fill(FP_UNKNOWN);
        self.version += 1;
        Ok(())
    }

    /// Copy weights out to host vectors (for checkpointing / weight sync).
    pub fn snapshot(&self) -> Result<Vec<Vec<f32>>> {
        self.literals.iter().map(|l| l.to_vec::<f32>().context("literal to_vec")).collect()
    }

    /// Publish-side snapshot: copy each leaf out once, fingerprint it,
    /// and — when `prev` (the previously published snapshot) already
    /// holds a leaf with identical content — share `prev`'s buffer
    /// instead of keeping the fresh copy.  Consumers then see both the
    /// same fingerprint *and* the same allocation for unchanged leaves,
    /// so frozen embeddings / norm scales ride through publish after
    /// publish without being re-sent or re-applied.
    pub fn to_snapshot(&self, prev: Option<&WeightSnapshot>) -> Result<Arc<WeightSnapshot>> {
        let n = self.literals.len();
        let prev = prev.filter(|p| p.leaf_count() == n);
        let mut leaves = Vec::with_capacity(n);
        let mut fps = Vec::with_capacity(n);
        for (i, l) in self.literals.iter().enumerate() {
            let data = l.to_vec::<f32>().context("literal to_vec")?;
            let fp = fingerprint_f32(&data);
            match prev {
                Some(p) if p.fingerprint(i) == fp => leaves.push(Arc::clone(p.leaf_arc(i))),
                _ => leaves.push(Arc::new(data)),
            }
            fps.push(fp);
        }
        Ok(Arc::new(WeightSnapshot::from_parts(leaves, fps)))
    }

    /// Load a host snapshot in place (legacy receive path; snapshot-based
    /// consumers use [`apply_snapshot`](Self::apply_snapshot)).
    pub fn load_snapshot(&mut self, weights: &[Vec<f32>], version: u64) -> Result<()> {
        ensure!(weights.len() == self.literals.len(), "snapshot leaf count mismatch");
        for (i, (p, w)) in self.model.params.iter().zip(weights).enumerate() {
            ensure!(w.len() == p.element_count(), "leaf '{}' size mismatch", p.name);
            self.literals[i] = to_literal(w, &p.shape)?;
            self.applied[i] = FP_UNKNOWN;
        }
        self.version = version;
        Ok(())
    }

    /// Leaves that must be rebuilt to bring this store to `snapshot`
    /// (fingerprint mismatch or unknown).  Read-only: callers plan under
    /// a read lock, [`prepare`](Self::prepare_leaves) with no lock, then
    /// [`commit`](Self::commit_prepared) under a short write lock.
    pub fn plan_delta(&self, snapshot: &WeightSnapshot) -> Result<Vec<usize>> {
        ensure!(snapshot.leaf_count() == self.literals.len(), "snapshot leaf count mismatch");
        Ok((0..self.literals.len())
            .filter(|&i| self.applied[i] != snapshot.fingerprint(i))
            .collect())
    }

    /// Rebuild the literals for `dirty` leaves of `snapshot` without any
    /// store lock held.  Large leaves fan out over the shared prepare
    /// pool (each worker borrows the snapshot's `Arc` buffer — no data
    /// copy beyond the literal itself); small leaves build inline.
    pub fn prepare_leaves(
        model: &ModelInfo,
        snapshot: &WeightSnapshot,
        dirty: &[usize],
    ) -> Result<PreparedLeaves> {
        ensure!(snapshot.leaf_count() == model.params.len(), "snapshot leaf count mismatch");
        let mut out = Vec::with_capacity(dirty.len());
        let mut jobs = Vec::new();
        for &i in dirty {
            let p = &model.params[i];
            ensure!(snapshot.leaf(i).len() == p.element_count(), "leaf '{}' size mismatch", p.name);
            if p.element_count() >= POOL_LEAF_THRESHOLD {
                let data = Arc::clone(snapshot.leaf_arc(i));
                let shape = p.shape.clone();
                jobs.push((
                    i,
                    prepare_pool().submit(move || to_literal(&data, &shape).map(SendLit)),
                ));
            } else {
                out.push((i, to_literal(snapshot.leaf(i), &p.shape)?, snapshot.fingerprint(i)));
            }
        }
        for (i, promise) in jobs {
            let lit = promise.wait().map_err(|e| anyhow!("weight prepare worker: {e}"))??;
            out.push((i, lit.0, snapshot.fingerprint(i)));
        }
        Ok(PreparedLeaves { leaves: out })
    }

    /// Swap pre-built literals in and bring the store to `snapshot` at
    /// `version`.  The critical section is pointer swaps plus an inline
    /// rebuild of any leaf that became dirty *after* the plan (e.g. a
    /// train step replaced literals in between) — with an up-to-date
    /// plan this is O(leaves) handle moves, not O(parameters).  Returns
    /// the number of leaves rebuilt; unchanged leaves count as
    /// fingerprint hits.
    pub fn commit_prepared(
        &mut self,
        snapshot: &WeightSnapshot,
        prepared: PreparedLeaves,
        version: u64,
    ) -> Result<usize> {
        ensure!(snapshot.leaf_count() == self.literals.len(), "snapshot leaf count mismatch");
        let mut rebuilt = 0usize;
        for (i, lit, fp) in prepared.leaves {
            ensure!(i < self.literals.len(), "prepared leaf {i} out of range");
            self.literals[i] = lit;
            self.applied[i] = fp;
            rebuilt += 1;
        }
        for (i, p) in self.model.params.iter().enumerate() {
            let fp = snapshot.fingerprint(i);
            if self.applied[i] != fp {
                ensure!(
                    snapshot.leaf(i).len() == p.element_count(),
                    "leaf '{}' size mismatch",
                    p.name
                );
                self.literals[i] = to_literal(snapshot.leaf(i), &p.shape)?;
                self.applied[i] = fp;
                rebuilt += 1;
            }
        }
        self.fingerprint_hits += (self.literals.len() - rebuilt) as u64;
        self.version = version;
        Ok(rebuilt)
    }

    /// One-shot delta apply (plan + rebuild + commit inline, no
    /// parallelism): rebuild exactly the leaves whose fingerprints
    /// differ from `snapshot`'s, skip the rest.  Returns the number of
    /// leaves rebuilt.
    pub fn apply_snapshot(&mut self, snapshot: &WeightSnapshot, version: u64) -> Result<usize> {
        self.commit_prepared(snapshot, PreparedLeaves::none(), version)
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.model.params.iter().map(|p| p.element_count()).sum()
    }

    /// L2 distance to another store (diagnostics / tests).  Streams
    /// leaf-by-leaf — at most one leaf of each store is materialized on
    /// the host at a time, never a full snapshot of either.
    pub fn l2_distance(&self, other: &ParamStore) -> Result<f64> {
        ensure!(self.literals.len() == other.literals.len(), "leaf count mismatch");
        let mut acc = 0.0f64;
        for (a, b) in self.literals.iter().zip(&other.literals) {
            let x = a.to_vec::<f32>().context("literal to_vec")?;
            let y = b.to_vec::<f32>().context("literal to_vec")?;
            ensure!(x.len() == y.len(), "leaf size mismatch");
            for (u, v) in x.iter().zip(&y) {
                acc += ((u - v) as f64).powi(2);
            }
        }
        Ok(acc.sqrt())
    }
}

fn to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).context("reshape param literal")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{default_artifacts_dir, Manifest};

    fn tiny_model() -> Option<ModelInfo> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Manifest::load(dir).unwrap().model("tiny").unwrap().clone())
    }

    #[test]
    fn init_deterministic_and_shaped() {
        let Some(model) = tiny_model() else { return };
        let a = ParamStore::init(&model, 7).unwrap();
        let b = ParamStore::init(&model, 7).unwrap();
        let c = ParamStore::init(&model, 8).unwrap();
        assert_eq!(a.param_count(), model.param_count);
        assert!(a.l2_distance(&b).unwrap() == 0.0);
        assert!(a.l2_distance(&c).unwrap() > 0.0);
    }

    #[test]
    fn norm_leaves_are_ones() {
        let Some(model) = tiny_model() else { return };
        let store = ParamStore::init(&model, 1).unwrap();
        let snap = store.snapshot().unwrap();
        for (p, w) in model.params.iter().zip(&snap) {
            if p.init_std == 0.0 {
                assert!(w.iter().all(|&x| x == 1.0), "norm leaf '{}' not ones", p.name);
            } else {
                let std =
                    (w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / w.len() as f64).sqrt();
                assert!(
                    (std - p.init_std).abs() < p.init_std * 0.5,
                    "leaf '{}' std {std} vs {}",
                    p.name,
                    p.init_std
                );
            }
        }
    }

    #[test]
    fn snapshot_roundtrip() {
        let Some(model) = tiny_model() else { return };
        let store = ParamStore::init(&model, 3).unwrap();
        let snap = store.snapshot().unwrap();
        let rebuilt = ParamStore::from_snapshot(&model, &snap).unwrap();
        assert_eq!(store.l2_distance(&rebuilt).unwrap(), 0.0);
    }

    #[test]
    fn load_snapshot_updates_version() {
        let Some(model) = tiny_model() else { return };
        let mut store = ParamStore::init(&model, 3).unwrap();
        let other = ParamStore::init(&model, 9).unwrap();
        store.load_snapshot(&other.snapshot().unwrap(), 42).unwrap();
        assert_eq!(store.version(), 42);
        assert_eq!(store.l2_distance(&other).unwrap(), 0.0);
    }

    #[test]
    fn weight_snapshot_roundtrip_is_exact() {
        let Some(model) = tiny_model() else { return };
        let store = ParamStore::init(&model, 5).unwrap();
        let snap = store.to_snapshot(None).unwrap();
        let rebuilt = ParamStore::from_weight_snapshot(&model, &snap).unwrap();
        assert_eq!(store.l2_distance(&rebuilt).unwrap(), 0.0);
        // a warm store has nothing dirty against its own snapshot
        assert!(rebuilt.plan_delta(&snap).unwrap().is_empty());
    }

    #[test]
    fn to_snapshot_reuses_unchanged_leaf_buffers() {
        let Some(model) = tiny_model() else { return };
        let store = ParamStore::init(&model, 5).unwrap();
        let first = store.to_snapshot(None).unwrap();
        let second = store.to_snapshot(Some(&first)).unwrap();
        // nothing changed between publishes: every buffer is shared
        assert_eq!(second.shared_leaves(&first), store.leaf_count());
        let cold = store.to_snapshot(None).unwrap();
        assert_eq!(cold.shared_leaves(&first), 0);
        assert_eq!(cold.fingerprints(), first.fingerprints());
    }

    #[test]
    fn delta_apply_rebuilds_only_dirty_leaves() {
        let Some(model) = tiny_model() else { return };
        let base = ParamStore::init(&model, 5).unwrap();
        let base_snap = base.to_snapshot(None).unwrap();
        let mut store = ParamStore::from_weight_snapshot(&model, &base_snap).unwrap();
        let n = store.leaf_count();

        // perturb one leaf, republish
        let mut weights = base_snap.to_weights();
        weights[0][0] += 1.0;
        let next = WeightSnapshot::of(weights);
        let dirty = store.plan_delta(&next).unwrap();
        assert_eq!(dirty, vec![0]);
        let rebuilt = store.apply_snapshot(&next, 2).unwrap();
        assert_eq!(rebuilt, 1);
        assert_eq!(store.fingerprint_hits(), (n - 1) as u64);
        assert_eq!(store.version(), 2);
        // byte-identical to a cold full apply of the same snapshot
        let full = ParamStore::from_weight_snapshot(&model, &next).unwrap();
        assert_eq!(store.l2_distance(&full).unwrap(), 0.0);
    }

    #[test]
    fn prepare_commit_matches_inline_apply() {
        let Some(model) = tiny_model() else { return };
        let base = ParamStore::init(&model, 6).unwrap();
        let base_snap = base.to_snapshot(None).unwrap();
        let target = ParamStore::init(&model, 7).unwrap().to_snapshot(None).unwrap();

        let mut inline = ParamStore::from_weight_snapshot(&model, &base_snap).unwrap();
        inline.apply_snapshot(&target, 3).unwrap();

        let mut staged = ParamStore::from_weight_snapshot(&model, &base_snap).unwrap();
        let dirty = staged.plan_delta(&target).unwrap();
        let prepared = ParamStore::prepare_leaves(&model, &target, &dirty).unwrap();
        assert_eq!(prepared.len(), dirty.len());
        let rebuilt = staged.commit_prepared(&target, prepared, 3).unwrap();
        assert_eq!(rebuilt, dirty.len());
        assert_eq!(staged.version(), 3);
        assert_eq!(inline.l2_distance(&staged).unwrap(), 0.0);
    }
}
