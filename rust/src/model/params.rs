//! The parameter store: model weights held as PJRT literals in manifest
//! leaf order (identical to jax's sorted-dict pytree flattening, which is
//! the AOT contract).

use anyhow::{ensure, Context, Result};

use crate::runtime::artifact::ModelInfo;
use crate::util::rng::Rng;

pub struct ParamStore {
    pub model: ModelInfo,
    literals: Vec<xla::Literal>,
    version: u64,
}

// Literals are host-memory buffers behind raw pointers; moving them across
// threads is safe (the PJRT CPU client synchronizes internally), the auto
// impls are only blocked by the raw pointers in the `xla` wrappers.
unsafe impl Send for ParamStore {}
unsafe impl Sync for ParamStore {}

impl ParamStore {
    /// Initialize parameters exactly as `model.init_params` does shape-wise:
    /// normal(0, std) for weight matrices, ones for norm scales.  (The RNG
    /// differs from jax's — initial weights are random either way; tests
    /// that need numeric parity load a checkpoint instead.)
    pub fn init(model: &ModelInfo, seed: u64) -> Result<ParamStore> {
        let mut rng = Rng::new(seed);
        let mut literals = Vec::with_capacity(model.params.len());
        for (i, p) in model.params.iter().enumerate() {
            let n = p.element_count();
            let mut leaf_rng = rng.fork(i as u64);
            let data: Vec<f32> = if p.init_std == 0.0 {
                vec![1.0; n]
            } else {
                (0..n).map(|_| (leaf_rng.normal() * p.init_std) as f32).collect()
            };
            literals.push(to_literal(&data, &p.shape)?);
        }
        let _ = rng.next_u64();
        Ok(ParamStore { model: model.clone(), literals, version: 0 })
    }

    /// Build from a host snapshot (leaf order must match the manifest).
    pub fn from_snapshot(model: &ModelInfo, weights: &[Vec<f32>]) -> Result<ParamStore> {
        ensure!(weights.len() == model.params.len(), "snapshot leaf count mismatch");
        let mut literals = Vec::with_capacity(weights.len());
        for (p, w) in model.params.iter().zip(weights) {
            ensure!(w.len() == p.element_count(), "leaf '{}' size mismatch", p.name);
            literals.push(to_literal(w, &p.shape)?);
        }
        Ok(ParamStore { model: model.clone(), literals, version: 0 })
    }

    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }

    pub fn leaf_count(&self) -> usize {
        self.literals.len()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn set_version(&mut self, v: u64) {
        self.version = v;
    }

    /// Replace all leaves (e.g. with a train step's outputs). Bumps version.
    pub fn replace(&mut self, literals: Vec<xla::Literal>) -> Result<()> {
        ensure!(literals.len() == self.literals.len(), "leaf count mismatch on replace");
        self.literals = literals;
        self.version += 1;
        Ok(())
    }

    /// Copy weights out to host vectors (for checkpointing / weight sync).
    pub fn snapshot(&self) -> Result<Vec<Vec<f32>>> {
        self.literals.iter().map(|l| l.to_vec::<f32>().context("literal to_vec")).collect()
    }

    /// Load a host snapshot in place (weight sync receive path).
    pub fn load_snapshot(&mut self, weights: &[Vec<f32>], version: u64) -> Result<()> {
        ensure!(weights.len() == self.literals.len(), "snapshot leaf count mismatch");
        for (i, (p, w)) in self.model.params.iter().zip(weights).enumerate() {
            ensure!(w.len() == p.element_count(), "leaf '{}' size mismatch", p.name);
            self.literals[i] = to_literal(w, &p.shape)?;
        }
        self.version = version;
        Ok(())
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.model.params.iter().map(|p| p.element_count()).sum()
    }

    /// L2 distance to another store (diagnostics / tests).
    pub fn l2_distance(&self, other: &ParamStore) -> Result<f64> {
        let a = self.snapshot()?;
        let b = other.snapshot()?;
        let mut acc = 0.0f64;
        for (x, y) in a.iter().zip(&b) {
            for (u, v) in x.iter().zip(y) {
                acc += ((u - v) as f64).powi(2);
            }
        }
        Ok(acc.sqrt())
    }
}

fn to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).context("reshape param literal")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{default_artifacts_dir, Manifest};

    fn tiny_model() -> Option<ModelInfo> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Manifest::load(dir).unwrap().model("tiny").unwrap().clone())
    }

    #[test]
    fn init_deterministic_and_shaped() {
        let Some(model) = tiny_model() else { return };
        let a = ParamStore::init(&model, 7).unwrap();
        let b = ParamStore::init(&model, 7).unwrap();
        let c = ParamStore::init(&model, 8).unwrap();
        assert_eq!(a.param_count(), model.param_count);
        assert!(a.l2_distance(&b).unwrap() == 0.0);
        assert!(a.l2_distance(&c).unwrap() > 0.0);
    }

    #[test]
    fn norm_leaves_are_ones() {
        let Some(model) = tiny_model() else { return };
        let store = ParamStore::init(&model, 1).unwrap();
        let snap = store.snapshot().unwrap();
        for (p, w) in model.params.iter().zip(&snap) {
            if p.init_std == 0.0 {
                assert!(w.iter().all(|&x| x == 1.0), "norm leaf '{}' not ones", p.name);
            } else {
                let std =
                    (w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / w.len() as f64).sqrt();
                assert!(
                    (std - p.init_std).abs() < p.init_std * 0.5,
                    "leaf '{}' std {std} vs {}",
                    p.name,
                    p.init_std
                );
            }
        }
    }

    #[test]
    fn snapshot_roundtrip() {
        let Some(model) = tiny_model() else { return };
        let store = ParamStore::init(&model, 3).unwrap();
        let snap = store.snapshot().unwrap();
        let rebuilt = ParamStore::from_snapshot(&model, &snap).unwrap();
        assert_eq!(store.l2_distance(&rebuilt).unwrap(), 0.0);
    }

    #[test]
    fn load_snapshot_updates_version() {
        let Some(model) = tiny_model() else { return };
        let mut store = ParamStore::init(&model, 3).unwrap();
        let other = ParamStore::init(&model, 9).unwrap();
        store.load_snapshot(&other.snapshot().unwrap(), 42).unwrap();
        assert_eq!(store.version(), 42);
        assert_eq!(store.l2_distance(&other).unwrap(), 0.0);
    }
}
