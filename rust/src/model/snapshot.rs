//! Immutable, shareable weight snapshots — the unit of weight
//! distribution.
//!
//! A [`WeightSnapshot`] holds every parameter leaf as an `Arc`-shared
//! host buffer plus a content fingerprint per leaf, both computed once
//! at publish time.  Everything downstream of the trainer — the sync
//! services, the rollout service's replica pool, checkpoint load —
//! passes `Arc<WeightSnapshot>` around, so fanning one publish out to N
//! consumers costs N refcount bumps instead of N deep copies, and
//! consumers can diff fingerprints to rebuild only the leaves that
//! actually changed (see `ParamStore::plan_delta`).

use std::sync::Arc;

/// Content fingerprint of one leaf (FNV-1a over the f32 bytes).
///
/// Never returns 0: the zero value is reserved as the "unknown" sentinel
/// consumers use for leaves whose host content they can no longer vouch
/// for (e.g. after a device train step replaced the literal).
pub fn fingerprint_f32(data: &[f32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for v in data {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    // length guards against trailing-zero collisions across shapes
    h ^= data.len() as u64;
    h = h.wrapping_mul(PRIME);
    if h == 0 {
        1
    } else {
        h
    }
}

/// An immutable published weight set: `Arc`-shared leaf buffers in
/// manifest leaf order, with per-leaf content fingerprints.
#[derive(Debug, Clone)]
pub struct WeightSnapshot {
    leaves: Vec<Arc<Vec<f32>>>,
    fingerprints: Vec<u64>,
}

impl WeightSnapshot {
    /// Wrap already-shared leaf buffers, fingerprinting each once.
    pub fn from_leaves(leaves: Vec<Arc<Vec<f32>>>) -> WeightSnapshot {
        let fingerprints = leaves.iter().map(|l| fingerprint_f32(l)).collect();
        WeightSnapshot { leaves, fingerprints }
    }

    /// Wrap leaf buffers whose fingerprints the caller already knows
    /// (publish-side delta reuse).  Callers must pass fingerprints
    /// produced by [`fingerprint_f32`] over exactly these buffers.
    pub(crate) fn from_parts(leaves: Vec<Arc<Vec<f32>>>, fingerprints: Vec<u64>) -> WeightSnapshot {
        debug_assert_eq!(leaves.len(), fingerprints.len());
        WeightSnapshot { leaves, fingerprints }
    }

    /// Take ownership of plain leaf vectors (no copy) and share them.
    pub fn of(weights: Vec<Vec<f32>>) -> Arc<WeightSnapshot> {
        Arc::new(Self::from_leaves(weights.into_iter().map(Arc::new).collect()))
    }

    /// Copy borrowed leaf slices into a fresh snapshot (compat shims for
    /// `&[Vec<f32>]` call sites; the copy happens once, at the boundary).
    pub fn from_weights(weights: &[Vec<f32>]) -> Arc<WeightSnapshot> {
        Self::of(weights.to_vec())
    }

    pub fn leaf_count(&self) -> usize {
        self.leaves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leaves.is_empty()
    }

    /// Leaf `i`'s data.
    pub fn leaf(&self, i: usize) -> &[f32] {
        &self.leaves[i]
    }

    /// Leaf `i`'s shared buffer (refcount bumps only; used to carry
    /// unchanged leaves from one published snapshot into the next).
    pub fn leaf_arc(&self, i: usize) -> &Arc<Vec<f32>> {
        &self.leaves[i]
    }

    /// Leaf `i`'s content fingerprint (never 0).
    pub fn fingerprint(&self, i: usize) -> u64 {
        self.fingerprints[i]
    }

    pub fn fingerprints(&self) -> &[u64] {
        &self.fingerprints
    }

    /// Total elements across leaves.
    pub fn total_elements(&self) -> usize {
        self.leaves.iter().map(|l| l.len()).sum()
    }

    /// Leaves whose buffers are literally shared with `other`
    /// (`Arc::ptr_eq`) — publish-side reuse telemetry.
    pub fn shared_leaves(&self, other: &WeightSnapshot) -> usize {
        self.leaves
            .iter()
            .zip(&other.leaves)
            .filter(|(a, b)| Arc::ptr_eq(a, b))
            .count()
    }

    /// Copy out to plain vectors (compat with `&[Vec<f32>]` consumers).
    pub fn to_weights(&self) -> Vec<Vec<f32>> {
        self.leaves.iter().map(|l| l.as_ref().clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_content_addressed() {
        let a = fingerprint_f32(&[1.0, 2.0, 3.0]);
        let b = fingerprint_f32(&[1.0, 2.0, 3.0]);
        let c = fingerprint_f32(&[1.0, 2.0, 3.5]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, 0);
        assert_ne!(fingerprint_f32(&[]), 0);
    }

    #[test]
    fn fingerprint_distinguishes_lengths() {
        // zero-padding must not alias shorter leaves
        assert_ne!(fingerprint_f32(&[0.0; 4]), fingerprint_f32(&[0.0; 8]));
    }

    #[test]
    fn snapshot_shares_buffers_not_copies() {
        let snap = WeightSnapshot::of(vec![vec![1.0; 8], vec![2.0; 4]]);
        let other = Arc::clone(&snap);
        assert!(Arc::ptr_eq(&snap, &other));
        assert!(Arc::ptr_eq(snap.leaf_arc(0), other.leaf_arc(0)));
        assert_eq!(snap.leaf_count(), 2);
        assert_eq!(snap.total_elements(), 12);
        assert_eq!(snap.leaf(1), &[2.0; 4]);
    }

    #[test]
    fn shared_leaves_counts_pointer_reuse() {
        let a = WeightSnapshot::of(vec![vec![1.0; 4], vec![2.0; 4]]);
        let b = WeightSnapshot::from_parts(
            vec![Arc::clone(a.leaf_arc(0)), Arc::new(vec![3.0; 4])],
            vec![a.fingerprint(0), fingerprint_f32(&[3.0; 4])],
        );
        assert_eq!(b.shared_leaves(&a), 1);
        // equal content in a distinct allocation is not "shared"
        let c = WeightSnapshot::of(a.to_weights());
        assert_eq!(c.shared_leaves(&a), 0);
        assert_eq!(c.fingerprints(), a.fingerprints());
    }
}
