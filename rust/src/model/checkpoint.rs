//! Binary checkpoint format (magic / version / CRC32-guarded payload).
//!
//! Layout (little-endian):
//! ```text
//!   "TRFT"  u32 format_version  u64 step  u64 weight_version
//!   u16 preset_len  preset bytes
//!   u32 n_leaves
//!   per leaf: u16 name_len, name, u8 ndim, u32 dims[ndim], u32 n, f32 data[n]
//!   u32 crc32 (over everything after the magic)
//! ```
//! Writes go to a temp file + atomic rename so a crashed writer never
//! leaves a torn checkpoint — the async modes poll this directory.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

const MAGIC: &[u8; 4] = b"TRFT";
const FORMAT_VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub preset: String,
    pub step: u64,
    pub weight_version: u64,
    pub leaves: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn weights(&self) -> Vec<Vec<f32>> {
        self.leaves.iter().map(|(_, _, w)| w.clone()).collect()
    }

    /// Move the decoded leaf buffers into a shareable
    /// [`WeightSnapshot`](super::snapshot::WeightSnapshot) without
    /// copying them again — the load path's counterpart to
    /// `CheckpointSync::publish` writing straight from snapshot leaves.
    pub fn into_snapshot(self) -> std::sync::Arc<super::snapshot::WeightSnapshot> {
        super::snapshot::WeightSnapshot::of(
            self.leaves.into_iter().map(|(_, _, w)| w).collect(),
        )
    }
}

// -- CRC32 (IEEE 802.3) ------------------------------------------------------

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

pub fn crc32(data: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// -- encode / decode ----------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "truncated checkpoint");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

pub fn save_checkpoint(
    path: impl AsRef<Path>,
    preset: &str,
    step: u64,
    weight_version: u64,
    leaves: &[(String, Vec<usize>, &[f32])],
) -> Result<()> {
    let mut e = Enc { buf: Vec::new() };
    e.u32(FORMAT_VERSION);
    e.u64(step);
    e.u64(weight_version);
    e.u16(preset.len() as u16);
    e.bytes(preset.as_bytes());
    e.u32(leaves.len() as u32);
    for (name, shape, data) in leaves {
        e.u16(name.len() as u16);
        e.bytes(name.as_bytes());
        e.u8(shape.len() as u8);
        for &d in shape {
            e.u32(d as u32);
        }
        e.u32(data.len() as u32);
        // bulk copy of the f32 payload
        let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        e.bytes(bytes);
    }
    let crc = crc32(&e.buf);
    e.u32(crc);

    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&e.buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))?;
    Ok(())
}

pub fn load_checkpoint(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let mut raw = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?
        .read_to_end(&mut raw)?;
    ensure!(raw.len() > 8 && &raw[..4] == MAGIC, "not a TRFT checkpoint");
    let body = &raw[4..raw.len() - 4];
    let stored_crc = u32::from_le_bytes(raw[raw.len() - 4..].try_into().unwrap());
    ensure!(crc32(body) == stored_crc, "checkpoint CRC mismatch (torn write?)");

    let mut d = Dec { buf: body, pos: 0 };
    let fmt = d.u32()?;
    if fmt != FORMAT_VERSION {
        bail!("unsupported checkpoint format {fmt}");
    }
    let step = d.u64()?;
    let weight_version = d.u64()?;
    let preset_len = d.u16()? as usize;
    let preset = String::from_utf8(d.take(preset_len)?.to_vec()).context("preset utf8")?;
    let n_leaves = d.u32()? as usize;
    let mut leaves = Vec::with_capacity(n_leaves);
    for _ in 0..n_leaves {
        let name_len = d.u16()? as usize;
        let name = String::from_utf8(d.take(name_len)?.to_vec()).context("leaf name utf8")?;
        let ndim = d.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(d.u32()? as usize);
        }
        let n = d.u32()? as usize;
        ensure!(n == shape.iter().product::<usize>().max(1) || shape.is_empty(), "leaf '{name}' shape/size mismatch");
        let bytes = d.take(n * 4)?;
        let mut data = vec![0f32; n];
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), data.as_mut_ptr() as *mut u8, n * 4);
        }
        leaves.push((name, shape, data));
    }
    Ok(Checkpoint { preset, step, weight_version, leaves })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_leaves() -> Vec<(String, Vec<usize>, Vec<f32>)> {
        vec![
            ("a.w".to_string(), vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 7.25, -0.125]),
            ("b.norm".to_string(), vec![4], vec![1.0; 4]),
        ]
    }

    fn as_refs(leaves: &[(String, Vec<usize>, Vec<f32>)]) -> Vec<(String, Vec<usize>, &[f32])> {
        leaves.iter().map(|(n, s, d)| (n.clone(), s.clone(), d.as_slice())).collect()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("trft_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let leaves = sample_leaves();
        save_checkpoint(&path, "tiny", 123, 9, &as_refs(&leaves)).unwrap();
        let ck = load_checkpoint(&path).unwrap();
        assert_eq!(ck.preset, "tiny");
        assert_eq!(ck.step, 123);
        assert_eq!(ck.weight_version, 9);
        assert_eq!(ck.leaves, leaves);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn detects_corruption() {
        let dir = std::env::temp_dir().join(format!("trft_ckpt_c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let leaves = sample_leaves();
        save_checkpoint(&path, "tiny", 1, 1, &as_refs(&leaves)).unwrap();
        let mut raw = std::fs::read(&path).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir().join(format!("trft_ckpt_r_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: crc32("123456789") == 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
