//! Trinity-RFT reproduction: a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3 — the coordinator holding the paper's system
//! contribution: the explorer / buffer / trainer trinity, the unified RFT
//! modes (synchronous, one-step off-policy, fully asynchronous,
//! multi-explorer, bench, train-only), first-class agent–environment
//! interaction, and the systematic data pipelines.  Layers 1–2 (Pallas
//! kernels + JAX model) are compiled ahead-of-time to `artifacts/*.hlo.txt`
//! by `python/compile/aot.py`; Python is never on the request path.
//!
//! Module map (see DESIGN.md §3 for the full inventory):
//!
//! * [`util`], [`exec`] — substrates built from scratch for the offline
//!   environment (JSON, YAML-subset config, CLI, PRNG, thread pool,
//!   promises, channels).
//! * [`runtime`], [`model`] — PJRT artifact loading/execution, parameter
//!   store, checkpoints, weight synchronization.
//! * [`buffer`] — the experience buffer: queue, persistent store,
//!   priority views, sampling strategies, delayed rewards.
//! * [`explorer`] — workflows, workflow runners with timeout/retry/skip,
//!   and the continuous-batching generation engine.
//! * [`service`] — the rollout service tier between runners and engines:
//!   microbatching with continuous slot refill, a replica pool with
//!   least-loaded routing and rolling weight updates, deadlines, bounded
//!   retry, and circuit-breaker quarantine (DESIGN.md §6).
//! * [`cache`] — the prefix-reuse cache under the service: a radix
//!   prefix trie, parked KV sessions resumed across the turns of one
//!   workflow episode, and affinity routing to the replica holding the
//!   prefix (DESIGN.md §7).
//! * [`qos`] — the QoS serving plane over the service: request classes
//!   (train / eval / interactive) with per-class deadlines, weighted
//!   deficit-round-robin fair scheduling, and live migration of parked
//!   sessions off overloaded or quarantined replicas (DESIGN.md §11).
//! * [`obs`] — the observability plane: lock-free span recorder with
//!   per-episode trace IDs, fixed-bucket latency histograms, the
//!   readable telemetry hub, and Chrome-trace export (DESIGN.md §8).
//! * [`control`] — the adaptive control plane over those gauges:
//!   bounded, hysteresis-damped controllers for staleness (the
//!   `"adaptive"` sync policy), explorer admission, and per-driver
//!   batch capacity, with a shared decision log (DESIGN.md §9).
//! * [`trainer`] — the composable algorithm API: specs assembled from
//!   advantage fns, loss specs, grouping policies and linked sample
//!   strategies, registered in the global registry
//!   (GRPO/PPO/SFT/DPO/MIX/OPMD×3 are all registrations; see
//!   DESIGN.md §4), plus the algorithm-agnostic training loop.
//! * [`coordinator`] — the unified RFT scheduler with pluggable sync
//!   policies (windowed / free / offline / bounded-staleness), launcher,
//!   run reports, monitor, typed config.
//! * [`data`] — task curation, experience shaping, agentic pipelines,
//!   human-in-the-loop simulation, lineage.
//! * [`envs`] — synthetic verifiable-math tasks (GSM8K stand-in),
//!   multi-turn grid-world (ALFWorld stand-in), tabular bandit (Appendix A).
//! * [`tokenizer`] — the deterministic tokenizer shared by all tasks.

pub mod buffer;
pub mod cache;
pub mod control;
pub mod coordinator;
pub mod data;
pub mod envs;
pub mod exec;
pub mod explorer;
pub mod model;
pub mod obs;
pub mod qos;
pub mod runtime;
pub mod service;
pub mod tokenizer;
pub mod trainer;
pub mod util;
