//! Host tensors (f32 / i32) and conversion to/from `xla::Literal`.
//!
//! All request-path data (token batches, masks, advantages, metrics) moves
//! through these; parameters live as `Literal`s inside the `ParamStore`
//! and only materialize as `Tensor`s for checkpointing / weight sync.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" | "s32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape: shape.to_vec(), data: vec![0; n] },
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn f32_data_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Row `i` of a rank-2 f32 tensor.
    pub fn row_f32(&self, i: usize) -> Result<&[f32]> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("row_f32 on rank-{} tensor", shape.len());
        }
        let w = shape[1];
        Ok(&self.f32_data()?[i * w..(i + 1) * w])
    }

    pub fn row_i32(&self, i: usize) -> Result<&[i32]> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("row_i32 on rank-{} tensor", shape.len());
        }
        let w = shape[1];
        Ok(&self.i32_data()?[i * w..(i + 1) * w])
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        lit.reshape(&dims).context("reshape literal")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(DType::F32, &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    fn rows() {
        let t = Tensor::from_i32(vec![2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.row_i32(1).unwrap(), &[4, 5, 6]);
        assert!(t.row_f32(0).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(vec![3], vec![7, -1, 0]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_f32(3.5);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.f32_data().unwrap(), &[3.5]);
        assert!(back.shape().is_empty());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
    }
}
