//! The PJRT client wrapper: one process-wide CPU client, a compile cache
//! keyed by artifact name, and per-artifact execution statistics that feed
//! the monitor's "device" accounting.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifact::{ArtifactInfo, Manifest};

#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub executions: u64,
    pub total_seconds: f64,
    pub compile_seconds: f64,
}

pub struct RuntimeClient {
    client: xla::PjRtClient,
    executables: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

// The PJRT CPU client and its executables are internally synchronized;
// the raw pointers inside the `xla` wrappers are what block the auto
// impls.
unsafe impl Send for RuntimeClient {}
unsafe impl Sync for RuntimeClient {}

static GLOBAL: OnceLock<Arc<RuntimeClient>> = OnceLock::new();

impl RuntimeClient {
    pub fn new() -> Result<RuntimeClient> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient {
            client,
            executables: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Process-wide shared client (PJRT CPU client creation is expensive;
    /// explorer and trainer share one, each owning its own executables and
    /// parameters — the isolation the paper needs lives at the engine
    /// level, not the device level).
    pub fn global() -> Arc<RuntimeClient> {
        GLOBAL
            .get_or_init(|| Arc::new(RuntimeClient::new().expect("PJRT CPU client")))
            .clone()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by name).
    pub fn load(&self, info: &ArtifactInfo) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.lock().unwrap().get(&info.name) {
            return Ok(Arc::clone(exe));
        }
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("parsing HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp).with_context(|| format!("compiling {}", info.name))?);
        let elapsed = start.elapsed().as_secs_f64();
        crate::log_debug!("runtime", "compiled {} in {:.2}s", info.name, elapsed);
        self.stats.lock().unwrap().entry(info.name.clone()).or_default().compile_seconds = elapsed;
        self.executables.lock().unwrap().insert(info.name.clone(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Pre-compile every artifact of a manifest matching a predicate.
    pub fn warmup(&self, manifest: &Manifest, pred: impl Fn(&ArtifactInfo) -> bool) -> Result<usize> {
        let mut n = 0;
        for info in manifest.artifacts.values() {
            if pred(info) {
                self.load(info)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Execute an artifact with literal inputs; returns the decomposed
    /// output tuple (aot.py lowers with return_tuple=True).
    pub fn execute(
        &self,
        info: &ArtifactInfo,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            args.len() == info.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            info.name,
            info.inputs.len(),
            args.len()
        );
        let exe = self.load(info)?;
        let start = Instant::now();
        let result = exe.execute::<&xla::Literal>(args).with_context(|| format!("executing {}", info.name))?;
        let tuple = result[0][0].to_literal_sync().context("fetching output tuple")?;
        let outputs = tuple.to_tuple().context("decomposing output tuple")?;
        let elapsed = start.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            let entry = stats.entry(info.name.clone()).or_default();
            entry.executions += 1;
            entry.total_seconds += elapsed;
        }
        anyhow::ensure!(
            outputs.len() == info.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            info.name,
            outputs.len(),
            info.outputs.len()
        );
        Ok(outputs)
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    pub fn total_exec_seconds(&self) -> f64 {
        self.stats.lock().unwrap().values().map(|s| s.total_seconds).sum()
    }
}
