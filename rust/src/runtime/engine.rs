//! `ModelEngine`: the typed execution surface over the AOT artifacts.
//!
//! One engine per role (explorer's rollout engine / trainer's policy
//! engine), each with its own `ParamStore` — the paper's decoupling means
//! the two never share mutable weight state; they exchange weights only
//! through the sync service.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::model::params::ParamStore;
use crate::obs::{Span, SpanKind, SpanRecorder, NO_REPLICA};

use super::artifact::{ArtifactInfo, Manifest, ModelInfo, Role};
use super::client::RuntimeClient;
use super::tensor::Tensor;

pub const N_HYPER: usize = 8;

pub struct ModelEngine {
    client: Arc<RuntimeClient>,
    pub model: ModelInfo,
    logprobs: ArtifactInfo,
    prefill: ArtifactInfo,
    decode: ArtifactInfo,
    embed: ArtifactInfo,
    train: HashMap<String, ArtifactInfo>,
    /// Device-lane span recorder (set once by the scheduler when
    /// observability is on; untraced executions cost one `get()`).
    obs: OnceLock<Arc<SpanRecorder>>,
}

/// KV-cache state for one generation batch; the cache literals are fed
/// back into every decode step and never leave the runtime.
pub struct GenerationState {
    pub batch: usize,
    pub cache_len: usize,
    pub logits: Tensor,
    k_cache: xla::Literal,
    v_cache: xla::Literal,
}

unsafe impl Send for GenerationState {}

/// Trainer-side state: params + Adam moments + step counter.
pub struct TrainState {
    pub params: ParamStore,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    pub step: u64,
}

unsafe impl Send for TrainState {}

impl TrainState {
    pub fn new(params: ParamStore) -> Result<TrainState> {
        let m = Self::zero_moments(&params.model)?;
        let v = Self::zero_moments(&params.model)?;
        Ok(TrainState { params, m, v, step: 0 })
    }

    /// Param-shaped zero literals (fresh Adam moments).
    fn zero_moments(model: &ModelInfo) -> Result<Vec<xla::Literal>> {
        model
            .params
            .iter()
            .map(|p| {
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&vec![0f32; p.element_count()])
                    .reshape(&dims)
                    .context("zero literal")
            })
            .collect()
    }

    /// Reset optimizer moments (used when swapping in external weights).
    /// Builds fresh zeros directly — the params never leave the store.
    pub fn reset_optimizer(&mut self) -> Result<()> {
        self.m = Self::zero_moments(&self.params.model)?;
        self.v = Self::zero_moments(&self.params.model)?;
        self.step = 0;
        Ok(())
    }
}

impl ModelEngine {
    pub fn new(client: Arc<RuntimeClient>, manifest: &Manifest, preset: &str) -> Result<ModelEngine> {
        let model = manifest.model(preset)?.clone();
        let mut train = HashMap::new();
        for a in manifest.artifacts.values() {
            if a.model == preset && a.kind == "train" {
                train.insert(a.alg.clone().unwrap_or_default(), a.clone());
            }
        }
        Ok(ModelEngine {
            client,
            logprobs: manifest.find(preset, "logprobs", None)?.clone(),
            prefill: manifest.find(preset, "prefill", None)?.clone(),
            decode: manifest.find(preset, "decode", None)?.clone(),
            embed: manifest.find(preset, "embed", None)?.clone(),
            train,
            model,
            obs: OnceLock::new(),
        })
    }

    /// Attach the span recorder: device prefill/decode/train executions
    /// show up on the trace's device lane.  First call wins.
    pub fn set_observer(&self, spans: Arc<SpanRecorder>) {
        let _ = self.obs.set(spans);
    }

    fn device_span(&self, kind: SpanKind, started: Instant, detail: u64) {
        if let Some(o) = self.obs.get() {
            o.record(Span {
                trace: 0,
                kind,
                replica: NO_REPLICA,
                start_us: o.rel_us(started),
                dur_us: started.elapsed().as_micros() as u64,
                detail,
            });
        }
    }

    /// Compile all artifacts up front (excluded from step timings).
    pub fn warmup(&self) -> Result<()> {
        for info in [&self.logprobs, &self.prefill, &self.decode, &self.embed] {
            self.client.load(info)?;
        }
        for info in self.train.values() {
            self.client.load(info)?;
        }
        Ok(())
    }

    pub fn client(&self) -> &Arc<RuntimeClient> {
        &self.client
    }

    // -- shape buckets -------------------------------------------------------

    /// (batch, seq) of the logprobs/train bucket.
    pub fn seq_shape(&self) -> (usize, usize) {
        (self.logprobs.batch, self.logprobs.seq)
    }

    /// (batch, prompt_len, cache_len) of the generation bucket.
    pub fn gen_shape(&self) -> (usize, usize, usize) {
        (self.prefill.batch, self.prefill.seq, self.prefill.cache_len)
    }

    pub fn train_shape(&self, alg: &str) -> Result<(usize, usize, usize)> {
        let a = self.train_artifact(alg)?;
        Ok((a.batch, a.seq, a.group_size))
    }

    pub fn has_algorithm(&self, alg: &str) -> bool {
        self.train.contains_key(alg)
    }

    pub fn algorithms(&self) -> Vec<String> {
        let mut v: Vec<String> = self.train.keys().cloned().collect();
        v.sort();
        v
    }

    fn train_artifact(&self, alg: &str) -> Result<&ArtifactInfo> {
        self.train
            .get(alg)
            .with_context(|| format!("no train artifact for algorithm '{alg}' (model {})", self.model.name))
    }

    // -- execution ----------------------------------------------------------

    fn check_data(&self, info: &ArtifactInfo, data: &[&Tensor]) -> Result<()> {
        let descs = info.data_input_descs();
        ensure!(
            descs.len() == data.len(),
            "artifact {} wants {} data inputs, got {}",
            info.name,
            descs.len(),
            data.len()
        );
        for (d, t) in descs.iter().zip(data) {
            ensure!(
                d.shape == t.shape() && d.dtype == t.dtype(),
                "artifact {} input '{}' expects {:?} {:?}, got {:?} {:?}",
                info.name,
                d.name,
                d.dtype,
                d.shape,
                t.dtype(),
                t.shape()
            );
        }
        Ok(())
    }

    fn run_with_params(
        &self,
        info: &ArtifactInfo,
        params: &ParamStore,
        data: &[&Tensor],
    ) -> Result<Vec<xla::Literal>> {
        self.check_data(info, data)?;
        let data_lits: Vec<xla::Literal> = data.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(info.inputs.len());
        args.extend(params.literals().iter());
        args.extend(data_lits.iter());
        self.client.execute(info, &args)
    }

    /// Per-token log-probs + entropy for a [B, T] token batch.
    pub fn token_logprobs(&self, params: &ParamStore, tokens: &Tensor) -> Result<(Tensor, Tensor)> {
        let out = self.run_with_params(&self.logprobs, params, &[tokens])?;
        Ok((Tensor::from_literal(&out[0])?, Tensor::from_literal(&out[1])?))
    }

    /// Pooled embedding for a [B, T] token batch with a [B, T] f32 mask.
    pub fn embed(&self, params: &ParamStore, tokens: &Tensor, mask: &Tensor) -> Result<Tensor> {
        let out = self.run_with_params(&self.embed, params, &[tokens, mask])?;
        Tensor::from_literal(&out[0])
    }

    /// Prompt prefill: returns last-position logits + populated KV cache.
    pub fn prefill(&self, params: &ParamStore, tokens: &Tensor, lens: &Tensor) -> Result<GenerationState> {
        let t = Instant::now();
        let mut out = self.run_with_params(&self.prefill, params, &[tokens, lens])?;
        self.device_span(SpanKind::DevicePrefill, t, self.prefill.batch as u64);
        ensure!(out.len() == 3, "prefill returns 3 outputs");
        let v_cache = out.pop().unwrap();
        let k_cache = out.pop().unwrap();
        let logits = Tensor::from_literal(&out[0])?;
        Ok(GenerationState {
            batch: self.prefill.batch,
            cache_len: self.prefill.cache_len,
            logits,
            k_cache,
            v_cache,
        })
    }

    /// One decode step at per-sequence positions; updates the cache state
    /// in place and returns next-token logits [B, V].
    pub fn decode(
        &self,
        params: &ParamStore,
        state: &mut GenerationState,
        tokens: &Tensor,
        pos: &Tensor,
    ) -> Result<Tensor> {
        ensure!(tokens.shape() == [state.batch], "decode tokens must be [batch]");
        ensure!(pos.shape() == [state.batch], "decode pos must be [batch]");
        let tok_lit = tokens.to_literal()?;
        let pos_lit = pos.to_literal()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.decode.inputs.len());
        args.extend(params.literals().iter());
        args.push(&state.k_cache);
        args.push(&state.v_cache);
        args.push(&tok_lit);
        args.push(&pos_lit);
        let t = Instant::now();
        let mut out = self.client.execute(&self.decode, &args)?;
        self.device_span(SpanKind::DeviceDecode, t, state.batch as u64);
        ensure!(out.len() == 3, "decode returns 3 outputs");
        state.v_cache = out.pop().unwrap();
        state.k_cache = out.pop().unwrap();
        let logits = Tensor::from_literal(&out[0])?;
        state.logits = logits.clone();
        Ok(logits)
    }

    /// One fused train step (loss -> grads -> Adam).  Updates `state` in
    /// place and returns named metrics.
    pub fn train_step(
        &self,
        alg: &str,
        state: &mut TrainState,
        hyper: &[f32],
        data: &[&Tensor],
    ) -> Result<Vec<(String, f32)>> {
        ensure!(hyper.len() == N_HYPER, "hyper vector must have {N_HYPER} slots");
        let info = self.train_artifact(alg)?.clone();
        self.check_data(&info, data)?;

        state.step += 1;
        let step_lit = Tensor::scalar_f32(state.step as f32).to_literal()?;
        let hyper_lit = Tensor::from_f32(vec![N_HYPER], hyper.to_vec()).to_literal()?;
        let data_lits: Vec<xla::Literal> = data.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;

        let n = state.params.leaf_count();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(info.inputs.len());
        args.extend(state.params.literals().iter());
        args.extend(state.m.iter());
        args.extend(state.v.iter());
        args.push(&step_lit);
        args.push(&hyper_lit);
        args.extend(data_lits.iter());

        let t = Instant::now();
        let mut out = self.client.execute(&info, &args)?;
        self.device_span(SpanKind::DeviceTrain, t, state.step);
        ensure!(out.len() == 3 * n + 1, "train step output arity");
        let metrics_lit = out.pop().unwrap();
        let v: Vec<xla::Literal> = out.split_off(2 * n);
        let m: Vec<xla::Literal> = out.split_off(n);
        state.params.replace(out)?;
        state.m = m;
        state.v = v;

        let metrics = metrics_lit.to_vec::<f32>()?;
        let names = &info.metrics;
        ensure!(metrics.len() == names.len(), "metric arity mismatch");
        Ok(names.iter().cloned().zip(metrics).collect())
    }

    /// Metric names for an algorithm (manifest order).
    pub fn metric_names(&self, alg: &str) -> Result<Vec<String>> {
        Ok(self.train_artifact(alg)?.metrics.clone())
    }

    /// Group size baked into an OPMD-family train artifact.
    pub fn group_size(&self, alg: &str) -> Result<usize> {
        Ok(self.train_artifact(alg)?.group_size)
    }

    /// Which data tensors (by name, in order) an algorithm's step expects.
    pub fn data_input_names(&self, alg: &str) -> Result<Vec<String>> {
        Ok(self.train_artifact(alg)?.data_inputs.clone())
    }

    /// Validate that every artifact's param inputs match the model table —
    /// run at startup so a stale artifact set fails fast.
    pub fn validate_manifest(&self) -> Result<()> {
        for info in [&self.logprobs, &self.prefill, &self.decode, &self.embed]
            .into_iter()
            .chain(self.train.values())
        {
            let params: Vec<_> = info.inputs.iter().filter(|d| d.role == Role::Param).collect();
            ensure!(params.len() == self.model.params.len(), "{}: param arity", info.name);
            for (d, p) in params.iter().zip(&self.model.params) {
                if d.shape != p.shape {
                    bail!("{}: param '{}' shape {:?} vs model '{}' {:?}", info.name, d.name, d.shape, p.name, p.shape);
                }
            }
        }
        Ok(())
    }
}
