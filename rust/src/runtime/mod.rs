//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the L3 hot path via the `xla` crate's PJRT CPU client.
//!
//! Flow (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! The manifest (artifacts/manifest.json) is the contract with L2: it
//! names every input/output leaf, its shape/dtype, and its role.

pub mod artifact;
pub mod client;
pub mod engine;
pub mod tensor;

pub use artifact::{ArtifactInfo, IoDesc, Manifest, ModelInfo, Role};
pub use client::RuntimeClient;
pub use engine::{GenerationState, ModelEngine, TrainState};
pub use tensor::{DType, Tensor};
