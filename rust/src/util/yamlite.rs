//! YAML-subset config parser (the offline registry has no serde_yaml).
//!
//! Supports the subset our configs need — which mirrors the paper's YAML
//! configuration files (Listing 5): nested maps by 2-space indentation,
//! block lists (`- item`, including `- key: val` object items), inline
//! lists (`[1, 2]`), scalars (bool/null/int/float/string, quoted strings),
//! comments (`#`) and blank lines.  Produces `util::json::Value`.

use super::json::Value;

#[derive(Debug, thiserror::Error)]
#[error("yaml parse error at line {line}: {msg}")]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

struct Line {
    indent: usize,
    text: String,
    lineno: usize,
}

pub fn parse(text: &str) -> Result<Value, YamlError> {
    let lines: Vec<Line> = text
        .lines()
        .enumerate()
        .filter_map(|(i, raw)| {
            let without_comment = strip_comment(raw);
            let trimmed = without_comment.trim_end();
            if trimmed.trim().is_empty() {
                return None;
            }
            let indent = trimmed.len() - trimmed.trim_start().len();
            Some(Line { indent, text: trimmed.trim_start().to_string(), lineno: i + 1 })
        })
        .collect();
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, 0)?;
    if pos != lines.len() {
        return Err(YamlError { line: lines[pos].lineno, msg: "unexpected dedent/content".into() });
    }
    Ok(v)
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_single = false;
    let mut in_double = false;
    for (i, c) in line.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '#' if !in_single && !in_double => {
                // YAML requires '#' to start a comment at start or after space
                if i == 0 || line[..i].ends_with(' ') {
                    return &line[..i];
                }
            }
            _ => {}
        }
    }
    line
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    if *pos >= lines.len() {
        return Ok(Value::Object(vec![]));
    }
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_list(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let mut pairs: Vec<(String, Value)> = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError { line: line.lineno, msg: "unexpected indent".into() });
        }
        let (key, rest) = split_key(&line.text)
            .ok_or_else(|| YamlError { line: line.lineno, msg: "expected 'key: value'".into() })?;
        *pos += 1;
        let value = if rest.is_empty() {
            // nested block (or empty -> empty object)
            if *pos < lines.len() && lines[*pos].indent > indent {
                parse_block(lines, pos, lines[*pos].indent)?
            } else {
                Value::Object(vec![])
            }
        } else {
            parse_scalar(rest, line.lineno)?
        };
        pairs.push((key.to_string(), value));
    }
    Ok(Value::Object(pairs))
}

fn parse_list(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
            if line.indent >= indent && !line.text.starts_with('-') {
                break;
            }
            if line.indent < indent {
                break;
            }
            return Err(YamlError { line: line.lineno, msg: "bad list item".into() });
        }
        let rest = line.text[1..].trim_start().to_string();
        let lineno = line.lineno;
        *pos += 1;
        if rest.is_empty() {
            // nested block under a bare '-'
            if *pos < lines.len() && lines[*pos].indent > indent {
                items.push(parse_block(lines, pos, lines[*pos].indent)?);
            } else {
                items.push(Value::Null);
            }
        } else if let Some((key, val)) = split_key(&rest) {
            // '- key: value' starts an inline object item; following lines at
            // deeper indent extend it.
            let mut pairs = vec![];
            let first_val = if val.is_empty() {
                if *pos < lines.len() && lines[*pos].indent > indent + 2 {
                    parse_block(lines, pos, lines[*pos].indent)?
                } else {
                    Value::Object(vec![])
                }
            } else {
                parse_scalar(val, lineno)?
            };
            pairs.push((key.to_string(), first_val));
            // continuation keys are indented by the '- ' width (2)
            if *pos < lines.len() && lines[*pos].indent == indent + 2 && split_key(&lines[*pos].text).is_some() {
                if let Value::Object(more) = parse_map(lines, pos, indent + 2)? {
                    pairs.extend(more);
                }
            }
            items.push(Value::Object(pairs));
        } else {
            items.push(parse_scalar(&rest, lineno)?);
        }
    }
    Ok(Value::Array(items))
}

/// Split "key: rest" (colon must be followed by space or end).
fn split_key(text: &str) -> Option<(&str, &str)> {
    let mut in_single = false;
    let mut in_double = false;
    for (i, c) in text.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            ':' if !in_single && !in_double => {
                let rest = &text[i + 1..];
                if rest.is_empty() || rest.starts_with(' ') {
                    let key = text[..i].trim();
                    let key = key.trim_matches('"').trim_matches('\'');
                    return Some((key, rest.trim()));
                }
            }
            _ => {}
        }
    }
    None
}

fn parse_scalar(text: &str, lineno: usize) -> Result<Value, YamlError> {
    let t = text.trim();
    if t.starts_with('[') {
        return parse_inline_list(t, lineno);
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Ok(Value::String(t[1..t.len() - 1].to_string()));
    }
    Ok(match t {
        "null" | "~" => Value::Null,
        "true" | "True" => Value::Bool(true),
        "false" | "False" => Value::Bool(false),
        _ => {
            if let Ok(n) = t.parse::<f64>() {
                Value::Number(n)
            } else {
                Value::String(t.to_string())
            }
        }
    })
}

fn parse_inline_list(text: &str, lineno: usize) -> Result<Value, YamlError> {
    let inner = text
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| YamlError { line: lineno, msg: "unterminated inline list".into() })?;
    let mut items = Vec::new();
    if inner.trim().is_empty() {
        return Ok(Value::Array(items));
    }
    for part in split_top_level(inner, ',') {
        items.push(parse_scalar(part.trim(), lineno)?);
    }
    Ok(Value::Array(items))
}

fn split_top_level(s: &str, sep: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_q = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' | '\'' => in_q = !in_q,
            '[' if !in_q => depth += 1,
            ']' if !in_q => depth = depth.saturating_sub(1),
            c if c == sep && depth == 0 && !in_q => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_scalars() {
        let v = parse("a: 1\nb: hello\nc: true\nd: 2.5\ne: null\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hello"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d").unwrap().as_f64(), Some(2.5));
        assert!(v.get("e").unwrap().is_null());
    }

    #[test]
    fn nested_maps() {
        let src = "model:\n  name: tiny\n  sizes:\n    batch: 4\nmode: both\n";
        let v = parse(src).unwrap();
        assert_eq!(v.path("model.name").unwrap().as_str(), Some("tiny"));
        assert_eq!(v.path("model.sizes.batch").unwrap().as_i64(), Some(4));
        assert_eq!(v.get("mode").unwrap().as_str(), Some("both"));
    }

    #[test]
    fn block_lists() {
        let src = "items:\n  - 1\n  - two\n  - true\n";
        let v = parse(src).unwrap();
        let items = v.get("items").unwrap().as_array().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[1].as_str(), Some("two"));
    }

    #[test]
    fn list_of_objects_paper_style() {
        // mirrors the paper's Listing 5 input_buffers section
        let src = "input_buffers:\n  - name: raw_input\n    path: openai/gsm8k\n    raw: true\n  - name: second\n    path: other\n";
        let v = parse(src).unwrap();
        let bufs = v.get("input_buffers").unwrap().as_array().unwrap();
        assert_eq!(bufs.len(), 2);
        assert_eq!(bufs[0].get("name").unwrap().as_str(), Some("raw_input"));
        assert_eq!(bufs[0].get("raw").unwrap().as_bool(), Some(true));
        assert_eq!(bufs[1].get("path").unwrap().as_str(), Some("other"));
    }

    #[test]
    fn inline_lists_and_comments() {
        let src = "# header comment\nsync_intervals: [1, 2, 10]  # paper's sweep\nname: 'quoted: colon'\n";
        let v = parse(src).unwrap();
        let ints = v.get("sync_intervals").unwrap().as_array().unwrap();
        assert_eq!(ints.iter().map(|x| x.as_i64().unwrap()).collect::<Vec<_>>(), vec![1, 2, 10]);
        assert_eq!(v.get("name").unwrap().as_str(), Some("quoted: colon"));
    }

    #[test]
    fn priority_weights_example() {
        // the paper's Listing 5 priority_weights block
        let src = "priority_weights:\n  difficulty: -1.0\n";
        let v = parse(src).unwrap();
        assert_eq!(v.path("priority_weights.difficulty").unwrap().as_f64(), Some(-1.0));
    }

    #[test]
    fn empty_and_errors() {
        assert!(parse("").unwrap().as_object().unwrap().is_empty());
        assert!(parse("a: 1\n    b: 2\n").is_err()); // stray indent under scalar...
    }

    #[test]
    fn deep_nesting() {
        let src = "a:\n  b:\n    c:\n      d: deep\n";
        let v = parse(src).unwrap();
        assert_eq!(v.path("a.b.c.d").unwrap().as_str(), Some("deep"));
    }
}
