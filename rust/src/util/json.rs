//! Minimal but complete JSON codec (parser + writer).
//!
//! Used for the AOT manifest, metrics sinks, and the persistent buffer's
//! record payloads.  Objects preserve insertion order (`Vec<(String, Value)>`)
//! so round-trips are stable.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Value {
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- constructors ------------------------------------------------------
    pub fn str(s: impl Into<String>) -> Value {
        Value::String(s.into())
    }
    pub fn num(n: f64) -> Value {
        Value::Number(n)
    }
    pub fn int(n: i64) -> Value {
        Value::Number(n as f64)
    }
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Value>) -> Value {
        Value::Array(items)
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        match self {
            Value::Object(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Insert or replace a key in an object.
    pub fn set(&mut self, key: &str, value: Value) {
        if let Value::Object(pairs) = self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value;
            } else {
                pairs.push((key.to_string(), value));
            }
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Convenience: `v.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Value> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, Some(1), 0);
        out
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

// ---------------------------------------------------------------------------
// writer

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Value::Object(pairs) => {
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            if !pairs.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf; metrics use null
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Number).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(Value::parse("\"hi\\n\"").unwrap(), Value::str("hi\n"));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, {"b": "c"}, null], "d": {}}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[1].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"x","nums":[1,2.5,-3],"flag":false,"nested":{"k":"v"}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(Value::parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(Value::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::str("a\"b\\c\nd\te\u{1}");
        let encoded = v.to_string_compact();
        assert_eq!(Value::parse(&encoded).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse(r#""héllo 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
        let esc = Value::parse(r#""世""#).unwrap();
        assert_eq!(esc.as_str(), Some("世"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn set_and_get_mut() {
        let mut v = Value::obj(vec![("a", Value::int(1))]);
        v.set("b", Value::str("x"));
        v.set("a", Value::int(2));
        assert_eq!(v.get("a").unwrap().as_i64(), Some(2));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn nan_serializes_as_null() {
        let v = Value::Number(f64::NAN);
        assert_eq!(v.to_string_compact(), "null");
    }

    #[test]
    fn deep_path_access() {
        let v = Value::parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_i64(), Some(42));
        assert!(v.path("a.x.c").is_none());
    }
}
