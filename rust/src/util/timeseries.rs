//! Time-series helpers for the monitor and the figure benches: moving
//! averages (Fig. 9 uses a 40-step moving average), EMA smoothing, and
//! summary statistics (mean ± std as reported in Tables 1–3).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { count: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    Summary {
        count: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Centered-window-free trailing moving average (paper's Fig. 9 smoothing).
pub fn moving_average(xs: &[f64], window: usize) -> Vec<f64> {
    assert!(window > 0);
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        sum += x;
        if i >= window {
            sum -= xs[i - window];
        }
        let n = (i + 1).min(window) as f64;
        out.push(sum / n);
    }
    out
}

pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let next = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        out.push(next);
        acc = Some(next);
    }
    out
}

/// Percentile via linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// "mean ± std" formatting used by the table benches.
pub fn fmt_mean_std(s: &Summary) -> String {
    format!("{:.2} ± {:.2}", s.mean, s.std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - 1.118).abs() < 1e-3);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).count, 0);
    }

    #[test]
    fn moving_average_warmup_and_steady() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let ma = moving_average(&xs, 2);
        assert_eq!(ma, vec![1.0, 1.5, 2.5, 3.5, 4.5]);
    }

    #[test]
    fn ema_converges() {
        let xs = vec![10.0; 50];
        let e = ema(&xs, 0.1);
        assert!((e[49] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.1);
    }
}
