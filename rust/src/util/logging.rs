//! Leveled logger with wall-clock timestamps and a global level switch.
//!
//! Deliberately tiny: stderr sink, `RUST_LOG`-style level from env or
//! `set_level`, and elapsed-time prefixes so coordinator traces read like
//! the paper's monitor output.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    /// Suppress all log output (`TRINITY_LOG=off`).
    Off = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Resolve `TRINITY_LOG` to a level.  Unset means the Info default;
/// `info` and `off` are accepted explicitly; anything else falls back
/// to Info with a one-line warning (instead of being silently eaten).
pub fn init_from_env() {
    let level = match std::env::var("TRINITY_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("info") => Level::Info,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        Ok("off") => Level::Off,
        Ok(other) => {
            eprintln!(
                "[trinity] unrecognized TRINITY_LOG={other:?} (expected debug|info|warn|error|off); using info"
            );
            Level::Info
        }
        Err(_) => Level::Info,
    };
    set_level(level);
    START.get_or_init(Instant::now);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let elapsed = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
        Level::Off => return, // never a message level
    };
    eprintln!("[{:>9.3}s {} {}] {}", elapsed.as_secs_f64(), tag, target, msg);
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test, not several: LEVEL is process-global and the harness
    // runs tests concurrently.
    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Off);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Warn));
        assert!(!enabled(Level::Error));
        set_level(Level::Info);
    }
}
