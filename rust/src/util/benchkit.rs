//! Shared harness for the paper-reproduction benches (criterion is not in
//! the offline registry): env-tunable scale knobs, aligned table printing,
//! and JSON result dumps under `bench_out/`.

use std::path::PathBuf;

use super::json::Value;

/// Scale knob: benches honor `TRINITY_BENCH_SCALE` (0.1 = smoke, 1.0 =
/// default, larger = closer to the paper's step counts).
pub fn scale() -> f64 {
    std::env::var("TRINITY_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

pub fn scaled(base: usize) -> usize {
    ((base as f64 * scale()).round() as usize).max(1)
}

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Aligned table printer (paper-style rows).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate().take(ncols) {
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = widths[i] + 2));
                } else {
                    line.push_str(&format!("{:>width$}", c, width = widths[i] + 2));
                }
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncols));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", Value::str(self.title.clone())),
            ("headers", Value::arr(self.headers.iter().map(|h| Value::str(h.clone())).collect())),
            (
                "rows",
                Value::arr(
                    self.rows
                        .iter()
                        .map(|r| Value::arr(r.iter().map(|c| Value::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Write a bench result JSON under bench_out/.
pub fn write_json(name: &str, value: &Value) {
    let dir = PathBuf::from("bench_out");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, value.to_string_pretty()).is_ok() {
        println!("[bench] wrote {path:?}");
    }
}

/// Series -> compact sparkline-ish string for console figures.
pub fn sparkline(values: &[f64]) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: &[char] = &['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let min = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-12);
    values
        .iter()
        .map(|v| BARS[(((v - min) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_and_serializes() {
        let mut t = Table::new("Test", &["mode", "speedup"]);
        t.row(vec!["sync".into(), "1.00x".into()]);
        t.row(vec!["async".into(), "1.61x".into()]);
        t.print();
        let v = t.to_json();
        assert_eq!(v.get("rows").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
    }

    #[test]
    fn scale_default_is_one() {
        assert_eq!(scaled(10), (10.0 * scale()).round() as usize);
    }
}
