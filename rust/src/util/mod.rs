//! Substrate utilities built from scratch (no serde/clap/rand available
//! in the offline registry — see DESIGN.md §1).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod logging;
pub mod registry;
pub mod rng;
pub mod timeseries;
pub mod yamlite;

pub use registry::Registry;
