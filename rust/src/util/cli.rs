//! Declarative CLI parser (the offline registry has no clap).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, typed
//! accessors with defaults, and generated help text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

#[derive(Debug, Default)]
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

#[derive(Debug)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown command '{0}'")]
    UnknownCommand(String),
    #[error("unknown argument '--{0}'")]
    UnknownArg(String),
    #[error("argument '--{0}' requires a value")]
    MissingValue(String),
    #[error("no command given\n{0}")]
    NoCommand(String),
    #[error("help requested\n{0}")]
    Help(String),
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Cli {
        Cli { program, about, commands: vec![] }
    }

    pub fn command(mut self, name: &'static str, about: &'static str, args: Vec<ArgSpec>) -> Cli {
        self.commands.push(Command { name, about, args });
        self
    }

    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nCOMMANDS:\n", self.program, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        out.push_str("\nRun with '<command> --help' for per-command options.\n");
        out
    }

    pub fn command_help(&self, cmd: &Command) -> String {
        let mut out = format!("{} {} — {}\n\nOPTIONS:\n", self.program, cmd.name, cmd.about);
        for a in &cmd.args {
            let value = if a.takes_value { " <value>" } else { "" };
            let default = a.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            out.push_str(&format!("  --{:<20} {}{}\n", format!("{}{}", a.name, value), a.help, default));
        }
        out
    }

    pub fn parse(&self, argv: &[String]) -> Result<Matches, CliError> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
            return Err(CliError::NoCommand(self.help()));
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError::UnknownCommand(cmd_name.clone()))?;
        let mut m = Matches {
            command: cmd_name.clone(),
            values: BTreeMap::new(),
            flags: vec![],
            positional: vec![],
        };
        for spec in &cmd.args {
            if let (true, Some(d)) = (spec.takes_value, spec.default) {
                m.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help(self.command_help(cmd)));
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = cmd
                    .args
                    .iter()
                    .find(|a| a.name == name)
                    .ok_or_else(|| CliError::UnknownArg(name.to_string()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.to_string()))?
                        }
                    };
                    m.values.insert(name.to_string(), val);
                } else {
                    m.flags.push(name.to_string());
                }
            } else {
                m.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(m)
    }
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub fn arg(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { name, help, takes_value: true, default: None }
}

pub fn arg_default(name: &'static str, help: &'static str, default: &'static str) -> ArgSpec {
    ArgSpec { name, help, takes_value: true, default: Some(default) }
}

pub fn flag(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { name, help, takes_value: false, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("trinity", "test").command(
            "run",
            "run a config",
            vec![
                arg("config", "path"),
                arg_default("mode", "rft mode", "both"),
                flag("verbose", "loud"),
            ],
        )
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_defaults() {
        let m = cli().parse(&argv(&["run", "--config", "c.yaml", "--verbose"])).unwrap();
        assert_eq!(m.command, "run");
        assert_eq!(m.get("config"), Some("c.yaml"));
        assert_eq!(m.get("mode"), Some("both"));
        assert!(m.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let m = cli().parse(&argv(&["run", "--mode=train"])).unwrap();
        assert_eq!(m.get("mode"), Some("train"));
    }

    #[test]
    fn errors() {
        assert!(matches!(cli().parse(&argv(&["nope"])), Err(CliError::UnknownCommand(_))));
        assert!(matches!(cli().parse(&argv(&["run", "--bogus"])), Err(CliError::UnknownArg(_))));
        assert!(matches!(cli().parse(&argv(&["run", "--config"])), Err(CliError::MissingValue(_))));
        assert!(matches!(cli().parse(&argv(&[])), Err(CliError::NoCommand(_))));
    }

    #[test]
    fn typed_accessors() {
        let m = cli().parse(&argv(&["run", "--config", "x", "--mode", "7"])).unwrap();
        assert_eq!(m.get_usize("mode", 0), 7);
        assert_eq!(m.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn positional_args() {
        let m = cli().parse(&argv(&["run", "task1", "task2"])).unwrap();
        assert_eq!(m.positional, vec!["task1", "task2"]);
    }
}
