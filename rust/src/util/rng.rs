//! PCG-XSH-RR 64/32 PRNG with the distributions the stack needs
//! (uniform, normal, categorical, Gumbel for sampling, shuffling).
//!
//! Deterministic and splittable via `fork`, so every component (task
//! generator, sampler, annotator sim, ...) gets an independent,
//! reproducible stream from the run seed.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    cached_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Rng {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1, cached_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (stable: same parent state +
    /// same tag -> same child).
    pub fn fork(&self, tag: u64) -> Rng {
        Rng::with_stream(self.state.wrapping_add(tag.wrapping_mul(0x9e3779b97f4a7c15)), tag | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal (Box–Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / rate
    }

    /// Pareto (long-tail) sample with scale x_m and shape alpha — used to
    /// model long-tailed rollout/annotation latencies.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m / self.uniform().max(1e-300).powf(1.0 / alpha)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len() as u64) as usize;
        }
        let mut target = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from logits with temperature + optional top-k / top-p — the
    /// generation-engine sampler.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32, top_k: usize, top_p: f32) -> usize {
        if temperature <= 1e-6 {
            // greedy
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let k = if top_k == 0 { logits.len() } else { top_k.min(logits.len()) };
        let max_logit = logits[idx[0]] as f64;
        let t = temperature as f64;
        let mut probs: Vec<f64> = Vec::with_capacity(k);
        for &i in idx.iter().take(k) {
            probs.push(((logits[i] as f64 - max_logit) / t).exp());
        }
        let total: f64 = probs.iter().sum();
        // nucleus cut on the sorted (descending) probabilities
        if top_p < 1.0 {
            let mut acc = 0.0;
            let mut cut = probs.len();
            for (j, p) in probs.iter().enumerate() {
                acc += p / total;
                if acc >= top_p as f64 {
                    cut = j + 1;
                    break;
                }
            }
            probs.truncate(cut);
        }
        idx[self.categorical(&probs)]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fork_is_independent_and_stable() {
        let base = Rng::new(1);
        let mut f1 = base.fork(10);
        let mut f2 = base.fork(10);
        let mut f3 = base.fork(11);
        assert_eq!(f1.next_u64(), f2.next_u64());
        assert_ne!(f1.next_u64(), f3.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_follows_weights() {
        let mut rng = Rng::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.02);
        assert!((counts[1] as f64 / 30_000.0 - 0.2).abs() < 0.02);
    }

    #[test]
    fn greedy_sampling_picks_argmax() {
        let mut rng = Rng::new(7);
        let logits = vec![0.1f32, 5.0, -2.0, 4.9];
        for _ in 0..10 {
            assert_eq!(rng.sample_logits(&logits, 0.0, 0, 1.0), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(8);
        let logits = vec![10.0f32, 9.0, -50.0, -60.0];
        for _ in 0..200 {
            let s = rng.sample_logits(&logits, 1.0, 2, 1.0);
            assert!(s == 0 || s == 1);
        }
    }

    #[test]
    fn top_p_restricts_support() {
        let mut rng = Rng::new(9);
        // p(0) ~ 0.88 -> top_p=0.5 keeps only index 0
        let logits = vec![3.0f32, 1.0, 0.0, -1.0];
        for _ in 0..100 {
            assert_eq!(rng.sample_logits(&logits, 1.0, 0, 0.5), 0);
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(10);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pareto_is_long_tailed() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.pareto(1.0, 2.0)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let max = samples.iter().cloned().fold(0.0, f64::max);
        assert!((mean - 2.0).abs() < 0.2); // E = alpha/(alpha-1) = 2
        assert!(max > 10.0); // tail actually shows up
    }
}
