//! Generic registry substrate: the one RwLock'd BTreeMap + catalog-error
//! pattern that `AlgorithmRegistry`, `WeightSyncRegistry` and
//! `SyncPolicyRegistry` used to each hand-roll.  A wrapper owns a
//! `Registry<T>` and keeps its domain-specific API (typed `register`,
//! `build`, `get`); the substrate owns storage, optional case folding,
//! and the "unknown name → full catalog + how-to-register hint" error.

use std::collections::BTreeMap;
use std::sync::RwLock;

use anyhow::{anyhow, Result};

pub struct Registry<T: Clone> {
    /// Singular noun for errors ("algorithm", "sync method", ...).
    noun: &'static str,
    /// Plural used in the catalog clause ("algorithms", "methods", ...).
    plural: &'static str,
    /// Trailing hint telling the user how to register a custom entry.
    hint: &'static str,
    /// Fold keys to trimmed lowercase (name lookup case-insensitive).
    fold_case: bool,
    entries: RwLock<BTreeMap<String, T>>,
}

impl<T: Clone> Registry<T> {
    pub fn new(
        noun: &'static str,
        plural: &'static str,
        hint: &'static str,
        fold_case: bool,
    ) -> Registry<T> {
        Registry { noun, plural, hint, fold_case, entries: RwLock::new(BTreeMap::new()) }
    }

    fn key(&self, name: &str) -> String {
        if self.fold_case {
            name.trim().to_ascii_lowercase()
        } else {
            name.to_string()
        }
    }

    /// Insert under `name` (latest wins, so registration is idempotent).
    pub fn insert(&self, name: &str, value: T) {
        self.entries.write().unwrap().insert(self.key(name), value);
    }

    /// Resolve `name`, or fail with the full catalog and the register hint.
    pub fn lookup(&self, name: &str) -> Result<T> {
        // one guard for lookup AND the error's name list: a second read()
        // here could deadlock behind a queued writer
        let entries = self.entries.read().unwrap();
        match entries.get(&self.key(name)) {
            Some(v) => Ok(v.clone()),
            None => Err(anyhow!(
                "unknown {} '{name}' — registered {}: [{}]; {}",
                self.noun,
                self.plural,
                entries.keys().cloned().collect::<Vec<_>>().join(", "),
                self.hint
            )),
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.read().unwrap().contains_key(&self.key(name))
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.read().unwrap().keys().cloned().collect()
    }

    /// Registered values, sorted by name.
    pub fn values(&self) -> Vec<T> {
        self.entries.read().unwrap().values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg(fold: bool) -> Registry<u32> {
        Registry::new("widget", "widgets", "register custom widgets with Widgets::register(..)", fold)
    }

    #[test]
    fn insert_lookup_latest_wins() {
        let r = reg(false);
        r.insert("a", 1);
        r.insert("b", 2);
        r.insert("a", 3);
        assert_eq!(r.lookup("a").unwrap(), 3);
        assert_eq!(r.names(), vec!["a", "b"]);
        assert_eq!(r.values(), vec![3, 2]);
        assert!(r.contains("b") && !r.contains("c"));
    }

    #[test]
    fn case_folding_is_opt_in() {
        let folded = reg(true);
        folded.insert("Alpha", 1);
        assert_eq!(folded.lookup(" ALPHA ").unwrap(), 1);
        assert_eq!(folded.names(), vec!["alpha"]);
        let exact = reg(false);
        exact.insert("Alpha", 1);
        assert!(exact.lookup("alpha").is_err());
        assert_eq!(exact.lookup("Alpha").unwrap(), 1);
    }

    #[test]
    fn unknown_name_error_lists_catalog_and_hint() {
        let r = reg(false);
        r.insert("a", 1);
        r.insert("b", 2);
        let err = r.lookup("zzz").unwrap_err().to_string();
        assert!(err.contains("unknown widget 'zzz'"), "{err}");
        assert!(err.contains("registered widgets: [a, b]"), "{err}");
        assert!(err.contains("register custom widgets"), "{err}");
    }
}
