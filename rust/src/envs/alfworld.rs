//! Multi-turn text grid-world (the ALFWorld stand-in).
//!
//! Rooms contain containers and objects; the goal is a pick-and-place
//! ("put key in box") that may require navigating rooms and opening a
//! closed container.  Properties preserved from the real benchmark for
//! Table 2's phenomenology: multi-turn interaction, long-tailed episode
//! lengths (optimal plans of 2–6 steps plus model stochasticity), sparse
//! terminal rewards, and expensive environment creation that the paper's
//! reset-instead-of-reinit optimization amortizes.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::rng::Rng;

pub const DEFAULT_MAX_STEPS: usize = 12;
pub const STEP_PENALTY: f32 = -0.1;

#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    Go(String),
    Take(String),
    Put(String, String),
    Open(String),
    Look,
    Invalid(String),
}

/// Parse a model response into an action (first recognized command wins).
pub fn parse_action(response: &str) -> Action {
    let words: Vec<&str> = response.split_whitespace().collect();
    for (i, w) in words.iter().enumerate() {
        match *w {
            "go" if i + 1 < words.len() => return Action::Go(words[i + 1].to_string()),
            "take" if i + 1 < words.len() => return Action::Take(words[i + 1].to_string()),
            "open" if i + 1 < words.len() => return Action::Open(words[i + 1].to_string()),
            "look" => return Action::Look,
            "put" if i + 3 < words.len() && words[i + 2] == "in" => {
                return Action::Put(words[i + 1].to_string(), words[i + 3].to_string())
            }
            _ => {}
        }
    }
    Action::Invalid(response.chars().take(24).collect())
}

#[derive(Debug, Clone)]
struct Room {
    objects: Vec<String>,
    container: Option<String>,
    container_open: bool,
}

#[derive(Debug, Clone)]
pub struct Layout {
    rooms: BTreeMap<String, Room>,
    goal_object: String,
    goal_container: String,
    start_room: String,
    object_room: String,
    container_room: String,
    container_closed: bool,
}

/// The environment instance.  `create` carries a configurable setup cost
/// (the paper's point: re-initializing ALFWorld per episode is expensive;
/// `reset` reuses the layout for free).
pub struct AlfworldEnv {
    layout: Layout,
    rooms: BTreeMap<String, Room>,
    agent_room: String,
    holding: Option<String>,
    pub steps: usize,
    pub max_steps: usize,
    pub done: bool,
    init_cost: Duration,
    pub create_count: usize,
    pub reset_count: usize,
}

const ROOMS: &[&str] = &["kitchen", "hall", "office", "garden"];
const OBJECTS: &[&str] = &["apple", "key", "ball", "lamp", "book", "cup"];
const CONTAINERS: &[&str] = &["box", "chest", "drawer", "shelf"];

fn generate_layout(rng: &mut Rng) -> Layout {
    let n_rooms = rng.range_i64(2, 4) as usize;
    let mut room_names: Vec<String> = ROOMS.iter().map(|s| s.to_string()).collect();
    rng.shuffle(&mut room_names);
    room_names.truncate(n_rooms);

    let goal_object = rng.choice(OBJECTS).to_string();
    let goal_container = rng.choice(CONTAINERS).to_string();
    let object_room = rng.choice(&room_names).clone();
    let container_room = rng.choice(&room_names).clone();
    let start_room = rng.choice(&room_names).clone();
    let container_closed = rng.bool(0.4);

    let mut rooms = BTreeMap::new();
    for name in &room_names {
        let mut objects = vec![];
        if *name == object_room {
            objects.push(goal_object.clone());
        }
        // distractor object
        if rng.bool(0.5) {
            let d = rng.choice(OBJECTS).to_string();
            if d != goal_object {
                objects.push(d);
            }
        }
        let container = if *name == container_room {
            Some(goal_container.clone())
        } else if rng.bool(0.3) {
            let c = rng.choice(CONTAINERS).to_string();
            if c != goal_container {
                Some(c)
            } else {
                None
            }
        } else {
            None
        };
        rooms.insert(
            name.clone(),
            Room { objects, container, container_open: !container_closed },
        );
    }
    Layout {
        rooms,
        goal_object,
        goal_container,
        start_room,
        object_room,
        container_room,
        container_closed,
    }
}

impl AlfworldEnv {
    /// Create a fresh environment (expensive path — the cost is simulated
    /// so benches can show the reset-reuse win).
    pub fn create(seed: u64, max_steps: usize, init_cost: Duration) -> AlfworldEnv {
        if !init_cost.is_zero() {
            std::thread::sleep(init_cost);
        }
        let mut rng = Rng::new(seed);
        let layout = generate_layout(&mut rng);
        let mut env = AlfworldEnv {
            rooms: layout.rooms.clone(),
            agent_room: layout.start_room.clone(),
            holding: None,
            steps: 0,
            max_steps,
            done: false,
            layout,
            init_cost,
            create_count: 1,
            reset_count: 0,
        };
        env.apply_closed_state();
        env
    }

    fn apply_closed_state(&mut self) {
        for (name, room) in self.rooms.iter_mut() {
            if *name == self.layout.container_room {
                room.container_open = !self.layout.container_closed;
            }
        }
    }

    /// Cheap reset: restore the existing layout without paying init cost.
    pub fn reset(&mut self) -> String {
        self.rooms = self.layout.rooms.clone();
        self.agent_room = self.layout.start_room.clone();
        self.holding = None;
        self.steps = 0;
        self.done = false;
        self.reset_count += 1;
        self.apply_closed_state();
        self.observe()
    }

    /// Reset AND regenerate the layout (new task, same env object).
    pub fn reset_with_seed(&mut self, seed: u64) -> String {
        let mut rng = Rng::new(seed);
        self.layout = generate_layout(&mut rng);
        self.reset()
    }

    /// The simulated creation cost this env was built with.
    pub fn init_cost(&self) -> Duration {
        self.init_cost
    }

    pub fn goal_text(&self) -> String {
        format!("goal put {} in {}", self.layout.goal_object, self.layout.goal_container)
    }

    pub fn observe(&self) -> String {
        let room = &self.rooms[&self.agent_room];
        let mut parts = vec![format!("you are in {}", self.agent_room)];
        if !room.objects.is_empty() {
            parts.push(format!("see {}", room.objects.join(" and ")));
        }
        if let Some(c) = &room.container {
            if room.container_open {
                parts.push(format!("see {c}"));
            } else {
                parts.push(format!("see closed {c}"));
            }
        }
        match &self.holding {
            Some(o) => parts.push(format!("holding {o}")),
            None => parts.push("holding nothing".to_string()),
        }
        parts.join(" . ")
    }

    pub fn room_names(&self) -> Vec<String> {
        self.rooms.keys().cloned().collect()
    }

    /// Execute an action. Returns (observation, reward, done).
    pub fn step(&mut self, action: &Action) -> (String, f32, bool) {
        assert!(!self.done, "step on finished episode");
        self.steps += 1;
        let mut reward = STEP_PENALTY;
        let mut obs = match action {
            Action::Go(room) => {
                if self.rooms.contains_key(room) {
                    self.agent_room = room.clone();
                    self.observe()
                } else {
                    format!("there is no {room}")
                }
            }
            Action::Take(obj) => {
                let room = self.rooms.get_mut(&self.agent_room).unwrap();
                if self.holding.is_none() {
                    if let Some(idx) = room.objects.iter().position(|o| o == obj) {
                        room.objects.remove(idx);
                        self.holding = Some(obj.clone());
                        format!("you take the {obj}")
                    } else {
                        format!("no {obj} here")
                    }
                } else {
                    "you are holding it".to_string()
                }
            }
            Action::Open(cont) => {
                let room = self.rooms.get_mut(&self.agent_room).unwrap();
                if room.container.as_deref() == Some(cont.as_str()) {
                    room.container_open = true;
                    format!("the {cont} is open")
                } else {
                    format!("no {cont} here")
                }
            }
            Action::Put(obj, cont) => {
                let holding_goal = self.holding.as_deref() == Some(obj.as_str());
                let room = self.rooms.get_mut(&self.agent_room).unwrap();
                let container_here = room.container.as_deref() == Some(cont.as_str());
                if holding_goal && container_here && room.container_open {
                    self.holding = None;
                    if *obj == self.layout.goal_object && *cont == self.layout.goal_container {
                        self.done = true;
                        reward = 1.0;
                        "done task".to_string()
                    } else {
                        format!("you put {obj} in {cont}")
                    }
                } else if container_here && !room.container_open {
                    format!("the {cont} is closed")
                } else {
                    "you can not do that".to_string()
                }
            }
            Action::Look => self.observe(),
            Action::Invalid(_) => "i do not understand".to_string(),
        };
        if self.steps >= self.max_steps && !self.done {
            self.done = true;
            obs.push_str(" . task failed");
        }
        (obs, reward, self.done)
    }

    /// Optimal plan length for the current layout (used to build expert
    /// trajectories for MIX, and as a difficulty proxy for curricula).
    pub fn optimal_plan(&self) -> Vec<Action> {
        let mut plan = vec![];
        let mut at = self.layout.start_room.clone();
        if at != self.layout.object_room {
            plan.push(Action::Go(self.layout.object_room.clone()));
            at = self.layout.object_room.clone();
        }
        plan.push(Action::Take(self.layout.goal_object.clone()));
        if at != self.layout.container_room {
            plan.push(Action::Go(self.layout.container_room.clone()));
        }
        if self.layout.container_closed {
            plan.push(Action::Open(self.layout.goal_container.clone()));
        }
        plan.push(Action::Put(self.layout.goal_object.clone(), self.layout.goal_container.clone()));
        plan
    }

    pub fn action_text(a: &Action) -> String {
        match a {
            Action::Go(r) => format!("go {r}"),
            Action::Take(o) => format!("take {o}"),
            Action::Put(o, c) => format!("put {o} in {c}"),
            Action::Open(c) => format!("open {c}"),
            Action::Look => "look".to_string(),
            Action::Invalid(s) => s.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_actions() {
        assert_eq!(parse_action("go kitchen"), Action::Go("kitchen".into()));
        assert_eq!(parse_action("i will take apple"), Action::Take("apple".into()));
        assert_eq!(parse_action("put key in box"), Action::Put("key".into(), "box".into()));
        assert_eq!(parse_action("open chest now"), Action::Open("chest".into()));
        assert_eq!(parse_action("look around"), Action::Look);
        assert!(matches!(parse_action("gibberish 123"), Action::Invalid(_)));
    }

    #[test]
    fn optimal_plan_succeeds() {
        for seed in 0..50 {
            let mut env = AlfworldEnv::create(seed, DEFAULT_MAX_STEPS, Duration::ZERO);
            let plan = env.optimal_plan();
            assert!(plan.len() <= 5);
            let mut final_reward = 0.0;
            for a in &plan {
                let (_, r, done) = env.step(a);
                final_reward = r;
                if done {
                    break;
                }
            }
            assert_eq!(final_reward, 1.0, "optimal plan failed for seed {seed}");
            assert!(env.done);
        }
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut env = AlfworldEnv::create(3, DEFAULT_MAX_STEPS, Duration::ZERO);
        let obs0 = env.observe();
        let plan = env.optimal_plan();
        for a in &plan {
            if env.done {
                break;
            }
            env.step(a);
        }
        let obs1 = env.reset();
        assert_eq!(obs0, obs1);
        assert_eq!(env.steps, 0);
        assert!(!env.done);
        // and the plan succeeds again
        let mut r_final = 0.0;
        for a in &plan {
            let (_, r, done) = env.step(a);
            r_final = r;
            if done {
                break;
            }
        }
        assert_eq!(r_final, 1.0);
    }

    #[test]
    fn reset_with_seed_changes_layout() {
        let mut env = AlfworldEnv::create(1, DEFAULT_MAX_STEPS, Duration::ZERO);
        let goal0 = env.goal_text();
        let mut changed = false;
        for s in 100..120 {
            env.reset_with_seed(s);
            if env.goal_text() != goal0 {
                changed = true;
                break;
            }
        }
        assert!(changed);
    }

    #[test]
    fn episode_caps_at_max_steps() {
        let mut env = AlfworldEnv::create(9, 3, Duration::ZERO);
        let mut steps = 0;
        while !env.done {
            let (_, r, _) = env.step(&Action::Look);
            assert_eq!(r, STEP_PENALTY);
            steps += 1;
            assert!(steps <= 3);
        }
        assert_eq!(steps, 3);
    }

    #[test]
    fn closed_container_requires_open() {
        // find a seed with a closed container
        for seed in 0..100 {
            let mut env = AlfworldEnv::create(seed, DEFAULT_MAX_STEPS, Duration::ZERO);
            if !env.layout.container_closed {
                continue;
            }
            // try the plan without the open step
            let plan: Vec<Action> =
                env.optimal_plan().into_iter().filter(|a| !matches!(a, Action::Open(_))).collect();
            let mut succeeded = false;
            for a in &plan {
                let (_, r, done) = env.step(a);
                if done && r == 1.0 {
                    succeeded = true;
                }
                if done {
                    break;
                }
            }
            assert!(!succeeded, "seed {seed}: closed container should block put");
            return;
        }
        panic!("no closed-container seed found");
    }

    #[test]
    fn plan_lengths_have_spread() {
        let lens: Vec<usize> = (0..200)
            .map(|s| AlfworldEnv::create(s, DEFAULT_MAX_STEPS, Duration::ZERO).optimal_plan().len())
            .collect();
        let min = *lens.iter().min().unwrap();
        let max = *lens.iter().max().unwrap();
        assert!(min <= 2 && max >= 4, "lengths {min}..{max} lack spread");
    }

    #[test]
    fn observation_is_tokenizer_friendly() {
        let tok = crate::tokenizer::Tokenizer::new();
        let env = AlfworldEnv::create(4, DEFAULT_MAX_STEPS, Duration::ZERO);
        let obs = env.observe();
        let ids = tok.encode(&obs);
        assert_eq!(tok.decode(&ids), obs);
        // observations stay short enough for the small cache bucket
        assert!(ids.len() < 40, "obs too long: {obs}");
    }
}
