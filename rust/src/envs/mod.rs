//! Environments: the paper's workloads rebuilt as synthetic, verifiable
//! substrates (DESIGN.md §Hardware-Adaptation documents each substitution).
//!
//! * [`math`] — GSM8K stand-in: generated arithmetic (word) problems with
//!   exact-match verifiable answers and a difficulty knob; four held-out
//!   benchmark tiers stand in for AIME24/AIME25/AMC/MATH500.
//! * [`alfworld`] — ALFWorld stand-in: a multi-turn text grid-world with
//!   pick-and-place goals, long-tailed episode lengths and reset-vs-reinit
//!   cost semantics.
//! * [`bandit`] — the Appendix-A tabular softmax bandit for the OPMD study.

pub mod alfworld;
pub mod bandit;
pub mod math;
