//! Synthetic verifiable math tasks (the GSM8K stand-in).
//!
//! Difficulty d ∈ 1..=8 controls the number of operators and operand
//! magnitude.  Low difficulties are single-op single-digit problems —
//! learnable from scratch by the tiny/small presets under RL — while high
//! difficulties give the curriculum and benchmark tiers real spread.
//!
//! The verifier is exact-match on the final integer in the response
//! (rule-based reward, as in the paper's MathWorkflow), with an optional
//! small format bonus used by the reward-shaping experiments.

use crate::util::json::Value;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct MathTask {
    pub id: String,
    pub question: String,
    pub answer: i64,
    pub difficulty: usize,
}

impl MathTask {
    pub fn to_payload(&self) -> Value {
        Value::obj(vec![
            ("question", Value::str(self.question.clone())),
            ("answer", Value::str(self.answer.to_string())),
            ("difficulty", Value::int(self.difficulty as i64)),
        ])
    }
}

/// Deterministic task generator; `split` seeds are disjoint so train and
/// the four benchmark tiers never overlap.
pub struct MathTaskGen {
    rng: Rng,
    counter: u64,
    split: String,
}

impl MathTaskGen {
    pub fn new(seed: u64, split: &str) -> MathTaskGen {
        // hash the split name into the stream so splits are disjoint
        let tag = split.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
        MathTaskGen { rng: Rng::with_stream(seed, tag | 1), counter: 0, split: split.to_string() }
    }

    /// Benchmark tiers standing in for the paper's evaluation suites,
    /// ordered by difficulty like the real ones.
    pub fn benchmark_difficulty(tier: &str) -> (usize, usize) {
        match tier {
            "math500s" => (1, 2),
            "amcs" => (2, 4),
            "aime24s" => (4, 6),
            "aime25s" => (5, 8),
            _ => (1, 8),
        }
    }

    pub fn gen(&mut self, difficulty: usize) -> MathTask {
        let difficulty = difficulty.clamp(1, 8);
        self.counter += 1;
        let id = format!("math-{}-{}", self.split, self.counter);
        let mut rng = self.rng.fork(self.counter);
        if difficulty >= 4 && rng.bool(0.5) {
            self.gen_word_problem(&mut rng, id, difficulty)
        } else {
            self.gen_expression(&mut rng, id, difficulty)
        }
    }

    /// Plain expression: `what is 3 + 4 * 2 ?`
    fn gen_expression(&self, rng: &mut Rng, id: String, difficulty: usize) -> MathTask {
        let n_ops = 1 + (difficulty - 1) / 2; // 1..=4 operators
        let max_operand = match difficulty {
            1 => 9,
            2..=3 => 12,
            4..=5 => 30,
            _ => 99,
        };
        let mut expr = String::new();
        let mut terms: Vec<i64> = vec![rng.range_i64(1, max_operand)];
        let mut ops: Vec<char> = vec![];
        expr.push_str(&terms[0].to_string());
        for _ in 0..n_ops {
            // multiplication only at higher difficulty, kept small
            let op = if difficulty >= 3 && rng.bool(0.3) { '*' } else if rng.bool(0.5) { '+' } else { '-' };
            let operand = if op == '*' { rng.range_i64(2, 9) } else { rng.range_i64(1, max_operand) };
            ops.push(op);
            terms.push(operand);
            expr.push_str(&format!(" {op} {operand}"));
        }
        let answer = eval_expression(&terms, &ops);
        MathTask { id, question: format!("what is {expr} ?"), answer, difficulty }
    }

    /// One-sentence templated word problem.
    fn gen_word_problem(&self, rng: &mut Rng, id: String, difficulty: usize) -> MathTask {
        let max = if difficulty >= 6 { 50 } else { 20 };
        let a = rng.range_i64(2, max);
        let b = rng.range_i64(1, max / 2 + 1);
        let item = *rng.choice(&["apples", "coins", "books"]);
        let (question, answer) = match rng.below(3) {
            0 => (format!("tom has {a} {item} and buys {b} more . how many {item} now ?"), a + b),
            1 => {
                let c = rng.range_i64(1, a.max(2) - 1);
                (format!("tom has {a} {item} and gives {c} away . how many left ?"), a - c)
            }
            _ => {
                let c = rng.range_i64(1, a + b - 1);
                (
                    format!("tom starts with {a} {item} , gets {b} more and loses {c} . how many now ?"),
                    a + b - c,
                )
            }
        };
        MathTask { id, question, answer, difficulty }
    }

    pub fn gen_batch(&mut self, n: usize, min_d: usize, max_d: usize) -> Vec<MathTask> {
        (0..n)
            .map(|i| {
                let d = min_d + (i % (max_d - min_d + 1));
                self.gen(d)
            })
            .collect()
    }
}

/// Left-to-right with `*` precedence (matches grade-school reading and the
/// generator's intent).
fn eval_expression(terms: &[i64], ops: &[char]) -> i64 {
    // first pass: fold multiplications
    let mut vals = vec![terms[0]];
    let mut add_ops: Vec<char> = vec![];
    for (i, &op) in ops.iter().enumerate() {
        let rhs = terms[i + 1];
        if op == '*' {
            let last = vals.last_mut().unwrap();
            *last *= rhs;
        } else {
            add_ops.push(op);
            vals.push(rhs);
        }
    }
    let mut acc = vals[0];
    for (i, &op) in add_ops.iter().enumerate() {
        match op {
            '+' => acc += vals[i + 1],
            '-' => acc -= vals[i + 1],
            _ => unreachable!(),
        }
    }
    acc
}

/// Extract the final integer from a model response ("the answer is -12" ->
/// -12).  Mirrors the rule-based reward of the paper's MathWorkflow.
pub fn extract_answer(response: &str) -> Option<i64> {
    let mut best: Option<i64> = None;
    let bytes = response.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        let neg = bytes[i] == b'-'
            && i + 1 < bytes.len()
            && bytes[i + 1].is_ascii_digit()
            // a '-' directly after a digit is arithmetic, not a sign
            && (i == 0 || !bytes[i - 1].is_ascii_digit());
        if neg || bytes[i].is_ascii_digit() {
            let start = i;
            if neg {
                i += 1;
            }
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                i += 1;
            }
            if let Ok(v) = response[start..i].parse::<i64>() {
                best = Some(v);
            }
        } else {
            i += 1;
        }
    }
    best
}

/// Rule-based verifier: 1.0 for exact match, 0.0 otherwise.
pub fn verify(response: &str, answer: i64) -> f32 {
    match extract_answer(response) {
        Some(v) if v == answer => 1.0,
        _ => 0.0,
    }
}

/// Well-formedness score in [0, 1] used by the quality-shaping experiments:
/// short, clean numeric answers score high; empty or rambling output low.
pub fn format_score(response: &str) -> f32 {
    let trimmed = response.trim();
    if trimmed.is_empty() {
        return 0.0;
    }
    let mut score: f32 = 0.4;
    if extract_answer(trimmed).is_some() {
        score += 0.4;
    }
    if trimmed.len() <= 12 {
        score += 0.2;
    } else if trimmed.len() > 40 {
        score -= 0.2;
    }
    score.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_per_seed_and_split() {
        let mut a = MathTaskGen::new(1, "train");
        let mut b = MathTaskGen::new(1, "train");
        let mut c = MathTaskGen::new(1, "eval");
        let (ta, tb, tc) = (a.gen(3), b.gen(3), c.gen(3));
        assert_eq!(ta.question, tb.question);
        assert_ne!(ta.question, tc.question);
    }

    #[test]
    fn answers_are_correct_for_expressions() {
        let mut g = MathTaskGen::new(7, "t");
        for d in 1..=8 {
            for _ in 0..50 {
                let t = g.gen(d);
                // re-derive the answer by parsing the question
                if let Some(expr) = t.question.strip_prefix("what is ").and_then(|s| s.strip_suffix(" ?")) {
                    let toks: Vec<&str> = expr.split(' ').collect();
                    let terms: Vec<i64> =
                        toks.iter().step_by(2).map(|s| s.parse().unwrap()).collect();
                    let ops: Vec<char> =
                        toks.iter().skip(1).step_by(2).map(|s| s.chars().next().unwrap()).collect();
                    assert_eq!(eval_expression(&terms, &ops), t.answer, "{}", t.question);
                }
            }
        }
    }

    #[test]
    fn word_problems_have_nonnegative_answers() {
        let mut g = MathTaskGen::new(3, "w");
        for _ in 0..200 {
            let t = g.gen(6);
            assert!(t.answer >= 0 || t.question.starts_with("what is"), "{t:?}");
        }
    }

    #[test]
    fn eval_expression_precedence() {
        assert_eq!(eval_expression(&[3, 4, 2], &['+', '*']), 11);
        assert_eq!(eval_expression(&[2, 3, 4], &['*', '-']), 2);
        assert_eq!(eval_expression(&[10, 2, 3], &['-', '-']), 5);
    }

    #[test]
    fn extract_answer_cases() {
        assert_eq!(extract_answer("42"), Some(42));
        assert_eq!(extract_answer("the answer is 7 ."), Some(7));
        assert_eq!(extract_answer("3 + 4 = 7"), Some(7));
        assert_eq!(extract_answer("-12"), Some(-12));
        assert_eq!(extract_answer("5-3"), Some(3)); // arithmetic minus, not sign
        assert_eq!(extract_answer("no number"), None);
    }

    #[test]
    fn verify_and_format() {
        assert_eq!(verify("7", 7), 1.0);
        assert_eq!(verify("i think 8", 7), 0.0);
        assert_eq!(verify("", 7), 0.0);
        assert!(format_score("7") > format_score(""));
        assert!(format_score("42") > format_score("well let me think about this for a very long time 42"));
    }

    #[test]
    fn difficulty_affects_length() {
        let mut g = MathTaskGen::new(5, "d");
        let easy: f64 =
            (0..100).map(|_| g.gen(1).question.len() as f64).sum::<f64>() / 100.0;
        let hard: f64 =
            (0..100).map(|_| g.gen(8).question.len() as f64).sum::<f64>() / 100.0;
        assert!(hard > easy, "difficulty should lengthen questions ({easy} vs {hard})");
    }

    #[test]
    fn benchmark_tiers_ordered() {
        let tiers = ["math500s", "amcs", "aime24s", "aime25s"];
        let mids: Vec<f64> = tiers
            .iter()
            .map(|t| {
                let (lo, hi) = MathTaskGen::benchmark_difficulty(t);
                (lo + hi) as f64 / 2.0
            })
            .collect();
        assert!(mids.windows(2).all(|w| w[0] < w[1]));
    }
}
