//! Tabular softmax bandit for the Appendix-A OPMD study.
//!
//! The paper derives three OPMD variants in the bandit setting and reports
//! that the "embarrassingly simple" variant equals the group-baseline
//! policy gradient.  This module implements all three with analytic
//! gradients over a tabular softmax policy, so the Appendix-A bench can
//! reproduce the comparison (and verify the gradient identity) without any
//! LLM in the loop.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Bandit {
    pub means: Vec<f64>,
    pub noise_std: f64,
}

impl Bandit {
    pub fn new(means: Vec<f64>, noise_std: f64) -> Bandit {
        Bandit { means, noise_std }
    }

    pub fn n_arms(&self) -> usize {
        self.means.len()
    }

    pub fn pull(&self, arm: usize, rng: &mut Rng) -> f64 {
        self.means[arm] + self.noise_std * rng.normal()
    }

    pub fn best_mean(&self) -> f64 {
        self.means.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[derive(Debug, Clone)]
pub struct SoftmaxPolicy {
    pub logits: Vec<f64>,
}

impl SoftmaxPolicy {
    pub fn uniform(n: usize) -> SoftmaxPolicy {
        SoftmaxPolicy { logits: vec![0.0; n] }
    }

    pub fn probs(&self) -> Vec<f64> {
        let max = self.logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = self.logits.iter().map(|&l| (l - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.categorical(&self.probs())
    }

    pub fn log_prob(&self, arm: usize) -> f64 {
        let p = self.probs();
        p[arm].max(1e-300).ln()
    }

    /// d log pi(arm) / d logits = onehot(arm) - probs.
    pub fn grad_log_prob(&self, arm: usize) -> Vec<f64> {
        let mut g: Vec<f64> = self.probs().iter().map(|p| -p).collect();
        g[arm] += 1.0;
        g
    }

    pub fn apply_grad(&mut self, grad: &[f64], lr: f64) {
        for (l, g) in self.logits.iter_mut().zip(grad) {
            *l += lr * g;
        }
    }

    pub fn expected_reward(&self, bandit: &Bandit) -> f64 {
        self.probs().iter().zip(&bandit.means).map(|(p, m)| p * m).sum()
    }
}

/// One sampled group: arms pulled from the *rollout* policy (which may be
/// stale — that's the off-policy knob) plus their rewards and rollout
/// log-probs.
#[derive(Debug, Clone)]
pub struct Group {
    pub arms: Vec<usize>,
    pub rewards: Vec<f64>,
    pub rollout_log_probs: Vec<f64>,
}

pub fn sample_group(bandit: &Bandit, rollout: &SoftmaxPolicy, k: usize, rng: &mut Rng) -> Group {
    let mut arms = Vec::with_capacity(k);
    let mut rewards = Vec::with_capacity(k);
    let mut lps = Vec::with_capacity(k);
    for _ in 0..k {
        let a = rollout.sample(rng);
        rewards.push(bandit.pull(a, rng));
        lps.push(rollout.log_prob(a));
        arms.push(a);
    }
    Group { arms, rewards, rollout_log_probs: lps }
}

/// Gradient of the surrogate loss for each OPMD variant, wrt the *current*
/// policy's logits, evaluated at the current policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpmdVariant {
    /// Appendix A.1 — Kimi k1.5 squared-residual surrogate.
    Kimi,
    /// Appendix A.2 — pairwise surrogate (Z eliminated).
    Pairwise,
    /// Appendix A.3 — baseline-subtracted PG scaled by 1/(1+tau).
    Simple,
    /// Vanilla on-policy PG with group-mean baseline (reference).
    VanillaPg,
}

pub fn surrogate_grad(
    variant: OpmdVariant,
    policy: &SoftmaxPolicy,
    group: &Group,
    tau: f64,
) -> Vec<f64> {
    let k = group.arms.len();
    let n = policy.logits.len();
    let mut grad = vec![0.0; n]; // gradient of the LOSS (descend this)
    match variant {
        OpmdVariant::Kimi => {
            // loss = sum_i (r_i - tau log Z - tau (log pi - log pi_ref))^2
            let max = group.rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let z: f64 =
                group.rewards.iter().map(|r| ((r - max) / tau).exp()).sum::<f64>() / k as f64;
            let log_z = tau * z.ln() + max;
            for i in 0..k {
                let a_i = group.rewards[i]
                    - log_z
                    - tau * (policy.log_prob(group.arms[i]) - group.rollout_log_probs[i]);
                let g = policy.grad_log_prob(group.arms[i]);
                for j in 0..n {
                    grad[j] += 2.0 * a_i * (-tau) * g[j];
                }
            }
        }
        OpmdVariant::Pairwise => {
            // loss = sum_{i<j} (a_i - a_j)^2, a_i = r_i - tau (lp - lp_ref)
            let a: Vec<f64> = (0..k)
                .map(|i| {
                    group.rewards[i]
                        - tau * (policy.log_prob(group.arms[i]) - group.rollout_log_probs[i])
                })
                .collect();
            let sum_a: f64 = a.iter().sum();
            for i in 0..k {
                // d loss / d a_i = 2 (K a_i - sum a); d a_i/d logits = -tau grad_lp
                let coeff = 2.0 * (k as f64 * a[i] - sum_a) * (-tau);
                let g = policy.grad_log_prob(group.arms[i]);
                for j in 0..n {
                    grad[j] += coeff * g[j] / (k as f64 * k as f64); // scale-normalized
                }
            }
        }
        OpmdVariant::Simple => {
            // loss = -1/(1+tau) sum_i (r_i - rbar) log pi(y_i)
            let rbar: f64 = group.rewards.iter().sum::<f64>() / k as f64;
            for i in 0..k {
                let adv = group.rewards[i] - rbar;
                let g = policy.grad_log_prob(group.arms[i]);
                for j in 0..n {
                    grad[j] += -adv * g[j] / (1.0 + tau);
                }
            }
        }
        OpmdVariant::VanillaPg => {
            let rbar: f64 = group.rewards.iter().sum::<f64>() / k as f64;
            for i in 0..k {
                let adv = group.rewards[i] - rbar;
                let g = policy.grad_log_prob(group.arms[i]);
                for j in 0..n {
                    grad[j] += -adv * g[j];
                }
            }
        }
    }
    grad
}

/// Run a full bandit learning curve; returns expected reward per step.
/// `staleness` = how many steps the rollout policy lags the trained policy
/// (0 = on-policy), the bandit-level analog of sync_interval.
pub fn run_learning(
    variant: OpmdVariant,
    bandit: &Bandit,
    steps: usize,
    group_size: usize,
    lr: f64,
    tau: f64,
    staleness: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut policy = SoftmaxPolicy::uniform(bandit.n_arms());
    let mut rollout = policy.clone();
    let mut curve = Vec::with_capacity(steps);
    for step in 0..steps {
        if staleness == 0 || step % staleness == 0 {
            rollout = policy.clone();
        }
        let group = sample_group(bandit, &rollout, group_size, &mut rng);
        let grad = surrogate_grad(variant, &policy, &group, tau);
        // descend the loss
        let neg: Vec<f64> = grad.iter().map(|g| -g).collect();
        policy.apply_grad(&neg, lr);
        curve.push(policy.expected_reward(bandit));
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_bandit() -> Bandit {
        Bandit::new(vec![0.1, 0.3, 0.9, 0.2], 0.05)
    }

    #[test]
    fn softmax_probs_normalize() {
        let p = SoftmaxPolicy { logits: vec![1.0, 2.0, 3.0] };
        let probs = p.probs();
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(probs[2] > probs[1] && probs[1] > probs[0]);
    }

    #[test]
    fn grad_log_prob_sums_to_zero() {
        let p = SoftmaxPolicy { logits: vec![0.5, -1.0, 2.0] };
        let g = p.grad_log_prob(1);
        assert!(g.iter().sum::<f64>().abs() < 1e-12);
        assert!(g[1] > 0.0);
    }

    #[test]
    fn simple_opmd_equals_scaled_vanilla_pg() {
        // Appendix A.3's punchline, verified exactly at the bandit level.
        let policy = SoftmaxPolicy { logits: vec![0.2, -0.3, 0.1, 0.7] };
        let mut rng = Rng::new(5);
        let group = sample_group(&test_bandit(), &policy, 8, &mut rng);
        let tau = 1.5;
        let g_simple = surrogate_grad(OpmdVariant::Simple, &policy, &group, tau);
        let g_pg = surrogate_grad(OpmdVariant::VanillaPg, &policy, &group, tau);
        for (a, b) in g_simple.iter().zip(&g_pg) {
            assert!((a * (1.0 + tau) - b).abs() < 1e-10);
        }
    }

    #[test]
    fn kimi_grad_matches_finite_difference() {
        let policy = SoftmaxPolicy { logits: vec![0.3, -0.2, 0.5] };
        let mut rng = Rng::new(6);
        let bandit = Bandit::new(vec![0.2, 0.8, 0.5], 0.0);
        let group = sample_group(&bandit, &policy, 6, &mut rng);
        let tau = 0.7;
        let loss = |p: &SoftmaxPolicy| -> f64 {
            let k = group.arms.len();
            let max = group.rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let z: f64 =
                group.rewards.iter().map(|r| ((r - max) / tau).exp()).sum::<f64>() / k as f64;
            let log_z = tau * z.ln() + max;
            (0..k)
                .map(|i| {
                    let a = group.rewards[i]
                        - log_z
                        - tau * (p.log_prob(group.arms[i]) - group.rollout_log_probs[i]);
                    a * a
                })
                .sum()
        };
        let g = surrogate_grad(OpmdVariant::Kimi, &policy, &group, tau);
        let eps = 1e-6;
        for j in 0..3 {
            let mut p_hi = policy.clone();
            p_hi.logits[j] += eps;
            let mut p_lo = policy.clone();
            p_lo.logits[j] -= eps;
            let fd = (loss(&p_hi) - loss(&p_lo)) / (2.0 * eps);
            assert!((fd - g[j]).abs() < 1e-4, "arm {j}: fd {fd} vs analytic {}", g[j]);
        }
    }

    #[test]
    fn pairwise_grad_matches_finite_difference() {
        let policy = SoftmaxPolicy { logits: vec![0.1, 0.4, -0.6] };
        let mut rng = Rng::new(7);
        let bandit = Bandit::new(vec![0.2, 0.8, 0.5], 0.0);
        let group = sample_group(&bandit, &policy, 5, &mut rng);
        let tau = 1.2;
        let k = group.arms.len() as f64;
        let loss = |p: &SoftmaxPolicy| -> f64 {
            let a: Vec<f64> = group
                .arms
                .iter()
                .zip(&group.rewards)
                .zip(&group.rollout_log_probs)
                .map(|((&arm, &r), &lp_ref)| r - tau * (p.log_prob(arm) - lp_ref))
                .collect();
            let sum: f64 = a.iter().sum();
            let sq: f64 = a.iter().map(|x| x * x).sum();
            (k * sq - sum * sum) / (k * k)
        };
        let g = surrogate_grad(OpmdVariant::Pairwise, &policy, &group, tau);
        let eps = 1e-6;
        for j in 0..3 {
            let mut hi = policy.clone();
            hi.logits[j] += eps;
            let mut lo = policy.clone();
            lo.logits[j] -= eps;
            let fd = (loss(&hi) - loss(&lo)) / (2.0 * eps);
            assert!((fd - g[j]).abs() < 1e-4, "arm {j}: fd {fd} vs analytic {}", g[j]);
        }
    }

    #[test]
    fn all_variants_learn_the_bandit() {
        let bandit = test_bandit();
        for variant in
            [OpmdVariant::Kimi, OpmdVariant::Pairwise, OpmdVariant::Simple, OpmdVariant::VanillaPg]
        {
            let curve = run_learning(variant, &bandit, 400, 8, 0.3, 1.0, 0, 11);
            let start = curve[0];
            let late: f64 = curve[380..].iter().sum::<f64>() / 20.0;
            assert!(
                late > start && late > 0.8,
                "{variant:?} failed to learn: {start:.3} -> {late:.3}"
            );
        }
    }

    #[test]
    fn off_policy_staleness_still_learns_with_simple() {
        let bandit = test_bandit();
        let curve = run_learning(OpmdVariant::Simple, &bandit, 600, 8, 0.2, 1.0, 10, 13);
        let late: f64 = curve[560..].iter().sum::<f64>() / 40.0;
        assert!(late > 0.7, "stale rollouts should still converge: {late:.3}");
    }
}
