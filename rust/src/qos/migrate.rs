//! Live session migration (DESIGN.md §11): when the affinity policy
//! rejects the prefix-holding replica as `Cold(Overloaded)` or
//! `Cold(Quarantined)`, move the parked session to a healthy replica
//! instead of re-prefilling the whole transcript from scratch.
//!
//! Mechanically a migration is an in-process handoff: the service
//! *extracts* the `ParkedSession` from the holder's park (the same
//! `claim` used by resume), *adopts* it into the destination's park,
//! and routes the request there — where the ordinary
//! `try_resume`/`extend_row` path claims it and feeds only the delta
//! tokens.  Byte-identity is inherited from that path: a resumed row
//! is exactly a cold re-chat of transcript + delta under the same
//! weights.
//!
//! [`SessionState`] is the serializable control-plane descriptor of a
//! parked session — session keys, per-row transcript leases and the
//! weight-version stamp, in a stable little-endian byte format.  It is
//! what a future cross-process `SessionStateCache` would ship; today
//! it sizes the migration (prefill tokens saved) and documents the
//! contract, and the byte round-trip is unit-tested.

use anyhow::{bail, Result};

use crate::cache::{Fallback, ParkedSession, ReplicaView, RowLease};

/// Serialized per-row lease: the episode key plus the transcript whose
/// KV the row holds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowState {
    pub key: u64,
    pub transcript: Vec<i32>,
}

/// Serializable descriptor of a parked session: everything needed to
/// account for (or, cross-process, rebuild) the session except the
/// device-resident KV payload itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionState {
    /// Weight version every byte of the session's KV was produced under.
    pub version: u64,
    /// Per-row leases; `None` for rows that finished without a lease.
    pub rows: Vec<Option<RowState>>,
}

/// Serialization magic: "TQS" + format version 1.
const MAGIC: [u8; 4] = *b"TQS1";

impl SessionState {
    /// Describe a parked session (payload-agnostic: the KV itself never
    /// leaves the engine; the descriptor is the control-plane view).
    pub fn describe<S>(parked: &ParkedSession<S>) -> SessionState {
        SessionState {
            version: parked.version,
            rows: parked
                .rows
                .iter()
                .map(|r| {
                    r.as_ref().map(|l| RowState { key: l.key, transcript: l.transcript.clone() })
                })
                .collect(),
        }
    }

    /// Total transcript tokens under lease — the prefill a destination
    /// replica skips by resuming instead of serving cold.
    pub fn prefill_tokens(&self) -> usize {
        self.rows.iter().flatten().map(|r| r.transcript.len()).sum()
    }

    /// Prefill tokens a follow-up `prompt` for `key` would save if this
    /// session were resumed (the longest resumable lease), 0 when no
    /// row resumes.
    pub fn saved_for(&self, key: u64, prompt: &[i32], cache_len: usize) -> usize {
        self.rows
            .iter()
            .flatten()
            .filter(|r| {
                RowLease { key: r.key, transcript: r.transcript.clone() }
                    .resumes(key, prompt, cache_len)
            })
            .map(|r| r.transcript.len())
            .max()
            .unwrap_or(0)
    }

    /// Stable little-endian byte encoding (magic, version, row count,
    /// then per row a presence tag + key + transcript).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + 8 * self.rows.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(self.rows.len() as u32).to_le_bytes());
        for row in &self.rows {
            match row {
                None => out.push(0),
                Some(r) => {
                    out.push(1);
                    out.extend_from_slice(&r.key.to_le_bytes());
                    out.extend_from_slice(&(r.transcript.len() as u32).to_le_bytes());
                    for &t in &r.transcript {
                        out.extend_from_slice(&t.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Inverse of [`to_bytes`](Self::to_bytes); rejects truncated or
    /// foreign input loudly.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionState> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
            if *at + n > bytes.len() {
                bail!("session state truncated at byte {} (want {n} more)", *at);
            }
            let s = &bytes[*at..*at + n];
            *at += n;
            Ok(s)
        };
        if take(&mut at, 4)? != MAGIC {
            bail!("not a serialized session state (bad magic)");
        }
        let version = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
        let n_rows = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        let mut rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            match take(&mut at, 1)?[0] {
                0 => rows.push(None),
                1 => {
                    let key = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
                    let len = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
                    let mut transcript = Vec::with_capacity(len);
                    for _ in 0..len {
                        transcript
                            .push(i32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()));
                    }
                    rows.push(Some(RowState { key, transcript }));
                }
                tag => bail!("bad row tag {tag}"),
            }
        }
        if at != bytes.len() {
            bail!("{} trailing bytes after session state", bytes.len() - at);
        }
        Ok(SessionState { version, rows })
    }
}

/// Is this affinity fallback a migration trigger?  Only holder-side
/// conditions qualify: `Stale` KV is incorrect anywhere, `ShortPrefix`
/// is not worth moving, `Unknown` has nothing to move.
pub fn migratable(reason: Fallback) -> bool {
    matches!(reason, Fallback::Overloaded | Fallback::Quarantined)
}

/// Net benefit of landing a migrated session on a destination with
/// `dest_load` pending rows: prefill tokens saved minus the estimated
/// prefill already queued ahead of it (load × fleet mean prompt).
pub fn migration_gain(saved_tokens: usize, dest_load: usize, mean_prompt_tokens: u64) -> i64 {
    saved_tokens as i64 - (dest_load as i64).saturating_mul(mean_prompt_tokens as i64)
}

/// Cost-aware destination choice: among ready peers of the holder that
/// serve exactly the session's weight version (a resumed KV must match
/// the weights that continue it), pick the one with the best
/// [`migration_gain`]; `None` when no destination nets positive — a
/// cold serve is then at least as cheap as migrating.
pub fn choose_destination(
    replicas: &[ReplicaView],
    holder: usize,
    version: u64,
    saved_tokens: usize,
    mean_prompt_tokens: u64,
) -> Option<usize> {
    replicas
        .iter()
        .filter(|r| r.ready && r.id != holder && r.version == version)
        .map(|r| (migration_gain(saved_tokens, r.load, mean_prompt_tokens), r))
        .filter(|(gain, _)| *gain > 0)
        .max_by_key(|(gain, r)| (*gain, std::cmp::Reverse(r.id)))
        .map(|(_, r)| r.id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    fn state() -> SessionState {
        SessionState {
            version: 7,
            rows: vec![
                Some(RowState { key: 42, transcript: vec![1, -2, 3, 4] }),
                None,
                Some(RowState { key: 43, transcript: vec![5] }),
            ],
        }
    }

    #[test]
    fn byte_roundtrip_is_identity() {
        let s = state();
        let bytes = s.to_bytes();
        assert_eq!(SessionState::from_bytes(&bytes).unwrap(), s);
        assert_eq!(s.prefill_tokens(), 5);
    }

    #[test]
    fn corrupt_bytes_rejected() {
        let s = state();
        let mut bytes = s.to_bytes();
        assert!(SessionState::from_bytes(&bytes[..bytes.len() - 1]).is_err(), "truncated");
        bytes.push(0);
        assert!(SessionState::from_bytes(&bytes).is_err(), "trailing");
        let mut bad = s.to_bytes();
        bad[0] = b'X';
        assert!(SessionState::from_bytes(&bad).is_err(), "magic");
    }

    #[test]
    fn describe_mirrors_parked_leases() {
        let parked = ParkedSession {
            state: 0u32,
            version: 9,
            rows: vec![Some(RowLease { key: 5, transcript: vec![1, 2] }), None],
            expires: Instant::now() + Duration::from_secs(1),
        };
        let s = SessionState::describe(&parked);
        assert_eq!(s.version, 9);
        assert_eq!(s.rows[0], Some(RowState { key: 5, transcript: vec![1, 2] }));
        assert_eq!(s.rows[1], None);
        assert_eq!(s.saved_for(5, &[1, 2, 3], 64), 2);
        assert_eq!(s.saved_for(6, &[1, 2, 3], 64), 0, "wrong key saves nothing");
    }

    #[test]
    fn migratable_only_on_holder_side_fallbacks() {
        assert!(migratable(Fallback::Overloaded));
        assert!(migratable(Fallback::Quarantined));
        assert!(!migratable(Fallback::Stale));
        assert!(!migratable(Fallback::ShortPrefix));
        assert!(!migratable(Fallback::Unknown));
    }

    #[test]
    fn destination_weighs_saved_tokens_against_load() {
        let pool = vec![
            ReplicaView { id: 0, load: 20, ready: true, version: 1 },  // the holder
            ReplicaView { id: 1, load: 3, ready: true, version: 1 },
            ReplicaView { id: 2, load: 0, ready: true, version: 1 },
            ReplicaView { id: 3, load: 0, ready: false, version: 1 }, // quarantined
            ReplicaView { id: 4, load: 0, ready: true, version: 2 },  // wrong weights
        ];
        // 64 tokens saved, mean prompt 8: replica 2 (gain 64) beats 1 (gain 40)
        assert_eq!(choose_destination(&pool, 0, 1, 64, 8), Some(2));
        // tiny savings against deep queues: nobody nets positive
        assert_eq!(choose_destination(&pool[..2].to_vec(), 0, 1, 4, 8), None);
        // version mismatch and quarantine are never destinations
        assert_eq!(choose_destination(&pool[3..].to_vec(), 9, 1, 64, 8), None);
    }
}
