//! QoS serving plane (DESIGN.md §11): request classes, weighted fair
//! scheduling, and live session migration for the rollout service.
//!
//! The rollout service absorbs a mixed workload — bulk training
//! rollouts, continuous benchmark evaluation, and latency-sensitive
//! interactive probes — through one queue per replica.  This module
//! turns that single-tier queue into a serving plane:
//!
//! * [`class`] — [`RequestClass`] (TrainRollout / Eval / Interactive)
//!   carried on `SamplingArgs` into every `RowJob`, with per-class
//!   deadline defaults and class-tagged telemetry.
//! * [`sched`] — [`DrrScheduler`]: weighted deficit-round-robin across
//!   per-class queues with starvation-proof aging, so heavy training
//!   traffic cannot starve interactive or eval requests.
//! * [`migrate`] — [`SessionState`] descriptors and cost-aware
//!   destination choice for moving a parked multi-turn session off an
//!   overloaded or quarantined holder onto a healthy replica, where the
//!   existing `extend_row` resume path continues it without
//!   re-prefilling.
//!
//! Everything is gated behind [`QosConfig::enabled`] (the `[qos]`
//! config section): disabled, the service dequeues FIFO, deadlines
//! come from `request_timeout`, and no migration happens — behavior is
//! byte-identical to a build without this module.

pub mod class;
pub mod migrate;
pub mod sched;

pub use class::{RequestClass, CLASS_COUNT};
pub use migrate::{choose_destination, migratable, migration_gain, RowState, SessionState};
pub use sched::DrrScheduler;

use std::time::Duration;

use anyhow::{bail, Result};

/// Typed `[qos]` knobs (`QosSection` in the run config converts into
/// this; it rides on `ServiceConfig`).
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// Master switch: off = FIFO dequeue, shared deadline, no
    /// migration — byte-identical to the pre-QoS service.
    pub enabled: bool,
    /// DRR weight per class (index = `RequestClass::index()`); the
    /// backlogged bandwidth share is proportional to these.
    pub weights: [u32; CLASS_COUNT],
    /// Deficit replenished per cursor visit is `weight × quantum`
    /// jobs; 1 gives the smoothest interleave.
    pub quantum: u32,
    /// A queued head older than this pre-empts the deficit order
    /// (starvation escape hatch); 0 disables aging.
    pub aging: Duration,
    /// Per-class deadline override; `ZERO` inherits the service-wide
    /// `request_timeout`.
    pub deadlines: [Duration; CLASS_COUNT],
    /// Per-class queued-job cap consulted by the `[control]` admission
    /// gate (pressure 1.0 at the cap); 0 = uncapped.
    pub class_caps: [usize; CLASS_COUNT],
    /// Migrate parked sessions off overloaded/quarantined holders.
    pub migration: bool,
    /// Minimum prefill tokens a migration must save to be attempted.
    pub migrate_min_tokens: usize,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            enabled: false,
            weights: [4, 2, 2],
            quantum: 1,
            aging: Duration::from_millis(500),
            deadlines: [Duration::ZERO; CLASS_COUNT],
            class_caps: [0; CLASS_COUNT],
            migration: true,
            migrate_min_tokens: 16,
        }
    }
}

impl QosConfig {
    /// Reject configurations that would wedge the scheduler.  A no-op
    /// when disabled, mirroring the other config sections.
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.weights.iter().any(|&w| w == 0) {
            bail!("qos.weights must all be >= 1 (a zero-weight class would never be served)");
        }
        if self.quantum == 0 {
            bail!("qos.quantum must be >= 1");
        }
        if self.migration && self.migrate_min_tokens == 0 {
            bail!("qos.migrate_min_tokens must be >= 1 when migration is enabled");
        }
        Ok(())
    }

    /// Effective deadline for a class: the per-class override when set,
    /// else the service-wide default.  Disabled QoS always uses the
    /// default (byte-identity with the pre-QoS service).
    pub fn deadline_for(&self, class: RequestClass, default: Duration) -> Duration {
        if !self.enabled {
            return default;
        }
        let d = self.deadlines[class.index()];
        if d.is_zero() {
            default
        } else {
            d
        }
    }

    /// The admission cap for a class, when one is configured.
    pub fn cap_for(&self, class: RequestClass) -> Option<usize> {
        if !self.enabled {
            return None;
        }
        match self.class_caps[class.index()] {
            0 => None,
            cap => Some(cap),
        }
    }

    /// Should this fallback trigger a migration attempt?
    pub fn wants_migration(&self, reason: crate::cache::Fallback) -> bool {
        self.enabled && self.migration && migratable(reason)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_permissive_and_inert() {
        let cfg = QosConfig { weights: [0; CLASS_COUNT], ..QosConfig::default() };
        assert!(cfg.validate().is_ok(), "disabled skips validation");
        let d = Duration::from_secs(120);
        assert_eq!(cfg.deadline_for(RequestClass::Interactive, d), d);
        assert_eq!(cfg.cap_for(RequestClass::TrainRollout), None);
        assert!(!cfg.wants_migration(crate::cache::Fallback::Overloaded));
    }

    #[test]
    fn enabled_validates_weights_and_quantum() {
        let mut cfg = QosConfig { enabled: true, ..QosConfig::default() };
        assert!(cfg.validate().is_ok());
        cfg.weights[1] = 0;
        assert!(cfg.validate().is_err());
        cfg.weights[1] = 2;
        cfg.quantum = 0;
        assert!(cfg.validate().is_err());
        cfg.quantum = 1;
        cfg.migrate_min_tokens = 0;
        assert!(cfg.validate().is_err());
        cfg.migration = false;
        assert!(cfg.validate().is_ok(), "min-tokens only matters with migration on");
    }

    #[test]
    fn per_class_deadlines_and_caps() {
        let mut cfg = QosConfig { enabled: true, ..QosConfig::default() };
        cfg.deadlines[RequestClass::Interactive.index()] = Duration::from_millis(250);
        cfg.class_caps[RequestClass::TrainRollout.index()] = 64;
        let d = Duration::from_secs(120);
        assert_eq!(cfg.deadline_for(RequestClass::Interactive, d), Duration::from_millis(250));
        assert_eq!(cfg.deadline_for(RequestClass::Eval, d), d, "unset inherits default");
        assert_eq!(cfg.cap_for(RequestClass::TrainRollout), Some(64));
        assert_eq!(cfg.cap_for(RequestClass::Eval), None);
        assert!(cfg.wants_migration(crate::cache::Fallback::Quarantined));
        assert!(!cfg.wants_migration(crate::cache::Fallback::Stale));
    }
}
