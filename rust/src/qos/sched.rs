//! Weighted deficit-round-robin over per-class queues (DESIGN.md §11).
//!
//! The scheduler itself is a tiny pure state machine — the batcher's
//! `RequestQueue` owns the per-class deques and asks [`DrrScheduler`]
//! *which class to serve next*; the scheduler never touches jobs.
//! That keeps fairness unit-testable without threads: feed it queue
//! lengths and head waits, count what it picks.
//!
//! Classic DRR with one-job-per-pick: the cursor parks on a class
//! until its deficit is spent, then advances and replenishes the next
//! class's deficit by `weight × quantum`.  Two departures from the
//! textbook version:
//!
//! * **Job cost is 1** (a row, not bytes) — prompt-length imbalance is
//!   handled by routing (`route_job`'s pending-prefill tie-break), not
//!   by the dequeue order.
//! * **Starvation-proof aging**: any class whose head job has waited
//!   longer than `aging` pre-empts the deficit order outright (oldest
//!   head first).  Aging does not charge the class's deficit — it is
//!   an escape hatch, and normal fairness resumes immediately after.

use std::time::Duration;

use super::{QosConfig, CLASS_COUNT};

/// Cost charged per dequeued job.
const JOB_COST: u64 = 1;

/// Deficit-round-robin pick state for one queue.
#[derive(Debug, Default)]
pub struct DrrScheduler {
    deficit: [u64; CLASS_COUNT],
    cursor: usize,
}

impl DrrScheduler {
    pub fn new() -> DrrScheduler {
        DrrScheduler::default()
    }

    /// Current deficit counters (telemetry / tests).
    pub fn deficits(&self) -> [u64; CLASS_COUNT] {
        self.deficit
    }

    /// Choose which class the queue should dequeue from next.
    ///
    /// `lens[c]` is the number of queued jobs of class `c` and
    /// `head_wait[c]` how long the oldest of them has been waiting
    /// (`None` when empty).  Returns `None` only when every class is
    /// empty.  The caller must actually dequeue from the returned
    /// class — the pick charges its deficit.
    pub fn pick(
        &mut self,
        lens: &[usize; CLASS_COUNT],
        head_wait: &[Option<Duration>; CLASS_COUNT],
        cfg: &QosConfig,
    ) -> Option<usize> {
        if lens.iter().all(|&l| l == 0) {
            return None;
        }
        // Aging override: serve the oldest starved head regardless of
        // deficits, without charging — fairness resumes right after.
        if !cfg.aging.is_zero() {
            let aged = (0..CLASS_COUNT)
                .filter(|&c| lens[c] > 0)
                .filter_map(|c| head_wait[c].map(|w| (w, c)))
                .filter(|&(w, _)| w > cfg.aging)
                .max_by_key(|&(w, c)| (w, std::cmp::Reverse(c)));
            if let Some((_, c)) = aged {
                return Some(c);
            }
        }
        // DRR proper: spend the parked class's deficit, else advance
        // the cursor and replenish on arrival.  Bounded: within two
        // sweeps some non-empty class replenishes to >= JOB_COST
        // (weights are validated >= 1).
        for _ in 0..2 * CLASS_COUNT + 1 {
            let c = self.cursor;
            if lens[c] > 0 && self.deficit[c] >= JOB_COST {
                self.deficit[c] -= JOB_COST;
                return Some(c);
            }
            if lens[c] == 0 {
                // classic DRR: an emptied class forfeits leftover
                // deficit, so it cannot bank credit while idle
                self.deficit[c] = 0;
            }
            self.cursor = (self.cursor + 1) % CLASS_COUNT;
            let n = self.cursor;
            if lens[n] > 0 {
                self.deficit[n] =
                    self.deficit[n].saturating_add(cfg.weights[n] as u64 * cfg.quantum as u64);
            }
        }
        // Unreachable with validated config; serve any non-empty class
        // rather than stall the worker.
        (0..CLASS_COUNT).find(|&c| lens[c] > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::RequestClass;

    fn cfg(weights: [u32; CLASS_COUNT], aging_ms: u64) -> QosConfig {
        QosConfig {
            enabled: true,
            weights,
            quantum: 1,
            aging: Duration::from_millis(aging_ms),
            ..QosConfig::default()
        }
    }

    /// Serve `total` picks from always-backlogged queues; return per-class counts.
    fn shares(weights: [u32; CLASS_COUNT], total: usize) -> [usize; CLASS_COUNT] {
        let cfg = cfg(weights, 0);
        let mut drr = DrrScheduler::new();
        let lens = [1000usize; CLASS_COUNT];
        let waits = [Some(Duration::from_millis(1)); CLASS_COUNT];
        let mut served = [0usize; CLASS_COUNT];
        for _ in 0..total {
            let c = drr.pick(&lens, &waits, &cfg).unwrap();
            served[c] += 1;
        }
        served
    }

    #[test]
    fn backlogged_shares_track_weights() {
        let served = shares([4, 2, 2], 800);
        // 4:2:2 over 800 picks -> 400/200/200, allow rounding slack
        assert!((served[0] as i64 - 400).abs() <= 8, "{served:?}");
        assert!((served[1] as i64 - 200).abs() <= 8, "{served:?}");
        assert!((served[2] as i64 - 200).abs() <= 8, "{served:?}");
    }

    #[test]
    fn single_class_gets_everything() {
        let cfg = cfg([4, 2, 2], 0);
        let mut drr = DrrScheduler::new();
        let mut lens = [0usize; CLASS_COUNT];
        lens[RequestClass::Interactive.index()] = 5;
        let mut waits = [None; CLASS_COUNT];
        waits[RequestClass::Interactive.index()] = Some(Duration::from_millis(1));
        for _ in 0..5 {
            assert_eq!(drr.pick(&lens, &waits, &cfg), Some(RequestClass::Interactive.index()));
        }
        assert_eq!(drr.pick(&[0; CLASS_COUNT], &[None; CLASS_COUNT], &cfg), None);
    }

    #[test]
    fn aging_preempts_deficit_order() {
        let cfg = cfg([1000, 1, 1], 50);
        let mut drr = DrrScheduler::new();
        let lens = [1000, 0, 3];
        let mut waits = [Some(Duration::from_millis(1)), None, Some(Duration::from_millis(200))];
        // interactive head has starved past the aging bound: it wins
        // even against a monster train weight
        assert_eq!(drr.pick(&lens, &waits, &cfg), Some(2));
        // once its head is fresh again, train's weight dominates
        waits[2] = Some(Duration::from_millis(1));
        let mut train = 0;
        for _ in 0..100 {
            if drr.pick(&lens, &waits, &cfg) == Some(0) {
                train += 1;
            }
        }
        assert!(train >= 95, "train served {train}/100");
    }

    #[test]
    fn idle_class_forfeits_banked_deficit() {
        let cfg = cfg([1, 1, 4], 0);
        let mut drr = DrrScheduler::new();
        // interactive banks deficit while backlogged...
        let lens = [10, 10, 10];
        let waits = [Some(Duration::from_millis(1)); CLASS_COUNT];
        for _ in 0..12 {
            drr.pick(&lens, &waits, &cfg);
        }
        // ...then drains; its stored credit must not survive idling
        let idle = [10, 10, 0];
        for _ in 0..CLASS_COUNT + 1 {
            drr.pick(&idle, &waits, &cfg);
        }
        assert_eq!(drr.deficits()[2], 0);
    }
}
