//! Request classes: the unit of differentiation in the QoS serving
//! plane (DESIGN.md §11).
//!
//! A [`RequestClass`] rides on `SamplingArgs` from the workflow that
//! issued the request all the way into the service's `RowJob`s, where
//! the fair scheduler, per-class deadlines and class-tagged telemetry
//! read it.  The default is [`RequestClass::TrainRollout`], so code
//! that never mentions classes behaves exactly as before.

/// Traffic class of a rollout request.
///
/// Classes are deliberately coarse — they describe *why* the tokens
/// are being generated, which is what scheduling policy cares about:
///
/// * [`TrainRollout`](RequestClass::TrainRollout) — bulk experience
///   generation for the trainer; throughput-oriented, deadline-tolerant.
/// * [`Eval`](RequestClass::Eval) — benchmark / held-out evaluation
///   passes running alongside training; should not be starved by
///   rollout bursts, moderate latency expectations.
/// * [`Interactive`](RequestClass::Interactive) — human-in-the-loop or
///   probe traffic; low volume, latency-sensitive, tightest deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RequestClass {
    /// Bulk training rollouts (the overwhelming majority of traffic).
    #[default]
    TrainRollout,
    /// Benchmark / held-out evaluation requests.
    Eval,
    /// Human-in-the-loop or latency-sensitive probe requests.
    Interactive,
}

/// Number of request classes; sizes all per-class state arrays.
pub const CLASS_COUNT: usize = 3;

impl RequestClass {
    /// Every class, in index order (stable: telemetry arrays and the
    /// DRR deficit table are indexed by this order).
    pub const ALL: [RequestClass; CLASS_COUNT] =
        [RequestClass::TrainRollout, RequestClass::Eval, RequestClass::Interactive];

    /// Stable dense index into per-class arrays.
    pub fn index(self) -> usize {
        match self {
            RequestClass::TrainRollout => 0,
            RequestClass::Eval => 1,
            RequestClass::Interactive => 2,
        }
    }

    /// Inverse of [`index`](Self::index).
    pub fn from_index(i: usize) -> Option<RequestClass> {
        RequestClass::ALL.get(i).copied()
    }

    /// Short label used in config keys, telemetry field names and the
    /// `trinity run` per-class summary line.
    pub fn as_str(self) -> &'static str {
        match self {
            RequestClass::TrainRollout => "train",
            RequestClass::Eval => "eval",
            RequestClass::Interactive => "interactive",
        }
    }

    /// Parse a config-file label (accepts the long spelling too).
    pub fn parse(s: &str) -> Option<RequestClass> {
        match s {
            "train" | "train_rollout" => Some(RequestClass::TrainRollout),
            "eval" => Some(RequestClass::Eval),
            "interactive" => Some(RequestClass::Interactive),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip_and_labels() {
        for (i, c) in RequestClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(RequestClass::from_index(i), Some(*c));
            assert_eq!(RequestClass::parse(c.as_str()), Some(*c));
        }
        assert_eq!(RequestClass::from_index(CLASS_COUNT), None);
        assert_eq!(RequestClass::parse("bulk"), None);
        assert_eq!(RequestClass::parse("train_rollout"), Some(RequestClass::TrainRollout));
        assert_eq!(RequestClass::default(), RequestClass::TrainRollout);
    }
}
