//! The Trainer actor: sample -> build -> fused train step -> metrics,
//! plus weight publication through the sync service.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::buffer::{ExperienceBatch, SampleStrategy};
use crate::model::{ParamStore, WeightSnapshot, WeightSync};
use crate::runtime::{ModelEngine, TrainState};

use super::batch::build_batch;
use super::spec::{AlgorithmConfig, AlgorithmSpec};

#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub algorithm: AlgorithmConfig,
    /// Checkpoint/publish version counter starts here.
    pub initial_version: u64,
}

impl TrainerConfig {
    /// Resolve `alg` through the global [`AlgorithmRegistry`]
    /// (errors on unregistered names).
    ///
    /// [`AlgorithmRegistry`]: super::registry::AlgorithmRegistry
    pub fn new(alg: &str) -> Result<TrainerConfig> {
        Ok(TrainerConfig { algorithm: AlgorithmConfig::new(alg)?, initial_version: 0 })
    }

    pub fn from_spec(spec: Arc<AlgorithmSpec>) -> TrainerConfig {
        TrainerConfig { algorithm: AlgorithmConfig::from_spec(spec), initial_version: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub named: Vec<(String, f32)>,
    pub mean_reward: f64,
    pub mean_response_len: f64,
    /// Seconds spent waiting for the batch (pipeline bubble indicator).
    pub sample_wait_s: f64,
    /// Seconds in the fused PJRT train step.
    pub compute_s: f64,
}

impl StepMetrics {
    pub fn get(&self, name: &str) -> Option<f32> {
        self.named.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// What one [`Trainer::publish_weights`] call did, for the coordinator's
/// telemetry: how many leaf buffers the new snapshot shares with the
/// previously published one (changed leaves = `total_leaves -
/// reused_leaves`) and how long the trainer stalled building it.
#[derive(Debug, Clone, Copy)]
pub struct PublishStats {
    pub version: u64,
    pub total_leaves: usize,
    /// Leaves whose content fingerprint matched the previous publish, so
    /// the prior `Arc` buffer was shared instead of re-allocated.
    pub reused_leaves: usize,
    /// Seconds spent snapshotting device weights into the shared buffer.
    pub stall_s: f64,
}

pub struct Trainer {
    engine: Arc<ModelEngine>,
    state: TrainState,
    strategy: Box<dyn SampleStrategy>,
    pub config: TrainerConfig,
    version: u64,
    history: Vec<StepMetrics>,
    /// The last snapshot handed to the sync service; unchanged leaves of
    /// the next publish share its buffers.
    last_published: Option<Arc<WeightSnapshot>>,
}

impl Trainer {
    pub fn new(
        engine: Arc<ModelEngine>,
        params: ParamStore,
        strategy: Box<dyn SampleStrategy>,
        config: TrainerConfig,
    ) -> Result<Trainer> {
        let state = TrainState::new(params)?;
        Ok(Trainer {
            engine,
            state,
            strategy,
            version: config.initial_version,
            config,
            history: vec![],
            last_published: None,
        })
    }

    pub fn step(&self) -> u64 {
        self.state.step
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn params(&self) -> &ParamStore {
        &self.state.params
    }

    pub fn history(&self) -> &[StepMetrics] {
        &self.history
    }

    /// One full training step: sample a batch from the buffer (blocking on
    /// the strategy's policy), build tensors, execute the fused artifact.
    pub fn train_step(&mut self) -> Result<StepMetrics> {
        let alg = &self.config.algorithm;
        let spec = Arc::clone(&alg.spec);
        let (b, t, k) = self.engine.train_shape(&spec.artifact)?;

        let t0 = Instant::now();
        // preference-pair algorithms consume 2x the artifact batch
        let sample_n = spec.experiences_per_step(b);
        let exps = self
            .strategy
            .sample(self.state.step + 1, sample_n)
            .with_context(|| format!("sampling batch for step {}", self.state.step + 1))?;
        let sample_wait_s = t0.elapsed().as_secs_f64();

        let batch_stats = ExperienceBatch { experiences: exps.clone() };
        let mean_reward = batch_stats.mean_reward();
        let mean_response_len = batch_stats.mean_response_len();

        let built = build_batch(alg, exps, b, t, k)?;
        let data_refs: Vec<&crate::runtime::Tensor> = built.tensors.iter().collect();

        let t1 = Instant::now();
        let hyper = alg.hyper.to_vec();
        let mut named = self.engine.train_step(&spec.artifact, &mut self.state, &hyper, &data_refs)?;
        named.push(("truncated_seqs".to_string(), built.truncated_seqs as f32));
        // trainer "device utilization" = compute_s / wall (accounted by the
        // coordinator's monitor per synchronization window)
        let compute_s = t1.elapsed().as_secs_f64();

        let metrics = StepMetrics {
            step: self.state.step,
            named,
            mean_reward,
            mean_response_len,
            sample_wait_s,
            compute_s,
        };
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Publish current weights as the next version.
    ///
    /// Builds an immutable [`WeightSnapshot`] from the device params,
    /// sharing the buffer of every leaf whose fingerprint matches the
    /// previous publish, then hands the `Arc` to the sync service — no
    /// further weight copies happen on the distribution path.
    pub fn publish_weights(&mut self, sync: &dyn WeightSync) -> Result<PublishStats> {
        self.version += 1;
        let t0 = Instant::now();
        let snap = self.state.params.to_snapshot(self.last_published.as_deref())?;
        let stall_s = t0.elapsed().as_secs_f64();
        let reused = match self.last_published.as_deref() {
            Some(prev) => snap.shared_leaves(prev),
            None => 0,
        };
        let stats = PublishStats {
            version: self.version,
            total_leaves: snap.leaf_count(),
            reused_leaves: reused,
            stall_s,
        };
        sync.publish(self.version, self.state.step, Arc::clone(&snap))?;
        self.last_published = Some(snap);
        Ok(stats)
    }

    /// Save a checkpoint of the current state.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let snap = self.state.params.snapshot()?;
        let leaves: Vec<(String, Vec<usize>, &[f32])> = self
            .state
            .params
            .model
            .params
            .iter()
            .zip(&snap)
            .map(|(p, w)| (p.name.clone(), p.shape.clone(), w.as_slice()))
            .collect();
        crate::model::save_checkpoint(
            path,
            &self.state.params.model.name,
            self.state.step,
            self.version,
            &leaves,
        )
    }

    /// Load weights (e.g. a published checkpoint) into the trainer,
    /// keeping or resetting the optimizer state.
    pub fn load_weights(&mut self, weights: &[Vec<f32>], version: u64, reset_optimizer: bool) -> Result<()> {
        self.state.params.load_snapshot(weights, version)?;
        self.version = version;
        if reset_optimizer {
            self.state.reset_optimizer()?;
        }
        Ok(())
    }
}
