//! The Trainer actor: sample -> build -> fused train step -> metrics,
//! plus weight publication through the sync service.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::buffer::{ExperienceBatch, SampleStrategy};
use crate::model::{ParamStore, WeightSync};
use crate::runtime::{ModelEngine, TrainState};

use super::batch::build_batch;
use super::spec::{AlgorithmConfig, AlgorithmSpec};

#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub algorithm: AlgorithmConfig,
    /// Checkpoint/publish version counter starts here.
    pub initial_version: u64,
}

impl TrainerConfig {
    /// Resolve `alg` through the global [`AlgorithmRegistry`]
    /// (errors on unregistered names).
    ///
    /// [`AlgorithmRegistry`]: super::registry::AlgorithmRegistry
    pub fn new(alg: &str) -> Result<TrainerConfig> {
        Ok(TrainerConfig { algorithm: AlgorithmConfig::new(alg)?, initial_version: 0 })
    }

    pub fn from_spec(spec: Arc<AlgorithmSpec>) -> TrainerConfig {
        TrainerConfig { algorithm: AlgorithmConfig::from_spec(spec), initial_version: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub named: Vec<(String, f32)>,
    pub mean_reward: f64,
    pub mean_response_len: f64,
    /// Seconds spent waiting for the batch (pipeline bubble indicator).
    pub sample_wait_s: f64,
    /// Seconds in the fused PJRT train step.
    pub compute_s: f64,
}

impl StepMetrics {
    pub fn get(&self, name: &str) -> Option<f32> {
        self.named.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

pub struct Trainer {
    engine: Arc<ModelEngine>,
    state: TrainState,
    strategy: Box<dyn SampleStrategy>,
    pub config: TrainerConfig,
    version: u64,
    history: Vec<StepMetrics>,
}

impl Trainer {
    pub fn new(
        engine: Arc<ModelEngine>,
        params: ParamStore,
        strategy: Box<dyn SampleStrategy>,
        config: TrainerConfig,
    ) -> Result<Trainer> {
        let state = TrainState::new(params)?;
        Ok(Trainer { engine, state, strategy, version: config.initial_version, config, history: vec![] })
    }

    pub fn step(&self) -> u64 {
        self.state.step
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn params(&self) -> &ParamStore {
        &self.state.params
    }

    pub fn history(&self) -> &[StepMetrics] {
        &self.history
    }

    /// One full training step: sample a batch from the buffer (blocking on
    /// the strategy's policy), build tensors, execute the fused artifact.
    pub fn train_step(&mut self) -> Result<StepMetrics> {
        let alg = &self.config.algorithm;
        let spec = Arc::clone(&alg.spec);
        let (b, t, k) = self.engine.train_shape(&spec.artifact)?;

        let t0 = Instant::now();
        // preference-pair algorithms consume 2x the artifact batch
        let sample_n = spec.experiences_per_step(b);
        let exps = self
            .strategy
            .sample(self.state.step + 1, sample_n)
            .with_context(|| format!("sampling batch for step {}", self.state.step + 1))?;
        let sample_wait_s = t0.elapsed().as_secs_f64();

        let batch_stats = ExperienceBatch { experiences: exps.clone() };
        let mean_reward = batch_stats.mean_reward();
        let mean_response_len = batch_stats.mean_response_len();

        let built = build_batch(alg, exps, b, t, k)?;
        let data_refs: Vec<&crate::runtime::Tensor> = built.tensors.iter().collect();

        let t1 = Instant::now();
        let hyper = alg.hyper.to_vec();
        let mut named = self.engine.train_step(&spec.artifact, &mut self.state, &hyper, &data_refs)?;
        named.push(("truncated_seqs".to_string(), built.truncated_seqs as f32));
        // trainer "device utilization" = compute_s / wall (accounted by the
        // coordinator's monitor per synchronization window)
        let compute_s = t1.elapsed().as_secs_f64();

        let metrics = StepMetrics {
            step: self.state.step,
            named,
            mean_reward,
            mean_response_len,
            sample_wait_s,
            compute_s,
        };
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Publish current weights as the next version.
    pub fn publish_weights(&mut self, sync: &dyn WeightSync) -> Result<u64> {
        self.version += 1;
        let snap = self.state.params.snapshot()?;
        sync.publish(self.version, self.state.step, snap)?;
        Ok(self.version)
    }

    /// Save a checkpoint of the current state.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let snap = self.state.params.snapshot()?;
        let leaves: Vec<(String, Vec<usize>, &[f32])> = self
            .state
            .params
            .model
            .params
            .iter()
            .zip(&snap)
            .map(|(p, w)| (p.name.clone(), p.shape.clone(), w.as_slice()))
            .collect();
        crate::model::save_checkpoint(
            path,
            &self.state.params.model.name,
            self.state.step,
            self.version,
            &leaves,
        )
    }

    /// Load weights (e.g. a published checkpoint) into the trainer,
    /// keeping or resetting the optimizer state.
    pub fn load_weights(&mut self, weights: &[Vec<f32>], version: u64, reset_optimizer: bool) -> Result<()> {
        self.state.params.load_snapshot(weights, version)?;
        self.version = version;
        if reset_optimizer {
            self.state.reset_optimizer()?;
        }
        Ok(())
    }
}
