//! Algorithm registry: batch builders that turn sampled experiences into
//! the exact data tensors each train-step artifact expects (paper §3.2's
//! AlgorithmType, with GRPO/PPO/SFT/DPO/MIX and the Appendix-A OPMD
//! family).

use anyhow::{bail, ensure, Result};

use crate::buffer::{Experience, ExperienceBatch, Source};
use crate::runtime::Tensor;

/// The 8 hyper slots of every train artifact (manifest `hyper_slots`).
#[derive(Debug, Clone)]
pub struct HyperParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub clip_eps: f32,
    /// tau for OPMD, beta for DPO.
    pub tau_or_beta: f32,
    pub mu: f32,
    pub kl_coef: f32,
}

impl Default for HyperParams {
    fn default() -> Self {
        HyperParams {
            lr: 1e-4,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            clip_eps: 0.2,
            tau_or_beta: 1.0,
            mu: 0.1,
            kl_coef: 0.0,
        }
    }
}

impl HyperParams {
    pub fn to_vec(&self) -> Vec<f32> {
        vec![
            self.lr,
            self.beta1,
            self.beta2,
            self.adam_eps,
            self.clip_eps,
            self.tau_or_beta,
            self.mu,
            self.kl_coef,
        ]
    }
}

#[derive(Debug, Clone)]
pub struct AlgorithmConfig {
    pub name: String,
    pub hyper: HyperParams,
    /// Std-normalize group advantages (GRPO flavor).
    pub adv_std_normalize: bool,
}

impl AlgorithmConfig {
    pub fn new(name: &str) -> AlgorithmConfig {
        AlgorithmConfig { name: name.to_string(), hyper: HyperParams::default(), adv_std_normalize: false }
    }

    /// Which buffer data tensors this algorithm needs, mirroring
    /// `aot.py::_train_data_spec`.
    pub fn is_group_based(&self) -> bool {
        self.name.starts_with("opmd")
    }
}

/// Pack tokens / per-token arrays into fixed [b, t] tensors, truncating
/// long sequences and padding short ones.  Index 0's mask is forced to 0
/// (the logprob convention: lp[:, 0] is undefined).
fn pack(exps: &[Experience], b: usize, t: usize) -> (Tensor, Tensor, Tensor) {
    let mut tokens = vec![0i32; b * t];
    let mut mask = vec![0f32; b * t];
    let mut old_lp = vec![0f32; b * t];
    for (i, e) in exps.iter().enumerate().take(b) {
        let n = e.tokens.len().min(t);
        for j in 0..n {
            tokens[i * t + j] = e.tokens[j];
            mask[i * t + j] = e.loss_mask[j];
            old_lp[i * t + j] = e.logprobs[j];
        }
        mask[i * t] = 0.0;
    }
    (
        Tensor::from_i32(vec![b, t], tokens),
        Tensor::from_f32(vec![b, t], mask),
        Tensor::from_f32(vec![b, t], old_lp),
    )
}

/// Sort experiences so same-group rollouts are contiguous and complete
/// groups of size `k` (required by the OPMD artifacts' group reshape).
fn order_groups(exps: &mut Vec<Experience>, k: usize) -> Result<()> {
    ensure!(k >= 1, "group size must be >= 1");
    exps.sort_by_key(|e| e.group);
    ensure!(exps.len() % k == 0, "batch of {} not divisible by group size {k}", exps.len());
    for chunk in exps.chunks(k) {
        let g = chunk[0].group;
        ensure!(
            chunk.iter().all(|e| e.group == g),
            "incomplete group {g}: OPMD batches need {k} rollouts per task"
        );
    }
    Ok(())
}

/// Build the data tensor list for `alg` from a sampled batch.
/// `(b, t, k)` is the train artifact's shape bucket.
pub fn build_batch(
    cfg: &AlgorithmConfig,
    mut exps: Vec<Experience>,
    b: usize,
    t: usize,
    k: usize,
) -> Result<Vec<Tensor>> {
    // DPO artifacts are shaped [pairs, T]; a batch of `b` pairs consumes
    // 2*b experiences (chosen + rejected).
    let expected = if cfg.name == "dpo" { 2 * b } else { b };
    ensure!(
        exps.len() == expected,
        "algorithm '{}' needs exactly {expected} experiences, got {}",
        cfg.name,
        exps.len()
    );
    match cfg.name.as_str() {
        "grpo" | "ppo" => {
            let batch = ExperienceBatch { experiences: exps };
            let adv = batch.group_advantages(cfg.adv_std_normalize);
            let (tokens, mask, old_lp) = pack(&batch.experiences, b, t);
            Ok(vec![tokens, mask, Tensor::from_f32(vec![b], adv), old_lp])
        }
        "sft" => {
            let (tokens, mask, _) = pack(&exps, b, t);
            Ok(vec![tokens, mask])
        }
        "mix" => {
            let batch = ExperienceBatch { experiences: exps };
            let adv = batch.group_advantages(cfg.adv_std_normalize);
            let (tokens, mask, old_lp) = pack(&batch.experiences, b, t);
            let is_expert: Vec<f32> = batch
                .experiences
                .iter()
                .map(|e| if matches!(e.source, Source::Expert | Source::Synthetic | Source::Human) { 1.0 } else { 0.0 })
                .collect();
            Ok(vec![
                tokens,
                mask,
                Tensor::from_f32(vec![b], adv),
                old_lp,
                Tensor::from_f32(vec![b], is_expert),
            ])
        }
        "opmd_kimi" | "opmd_pairwise" | "opmd_simple" => {
            order_groups(&mut exps, k)?;
            let rewards: Vec<f32> = exps.iter().map(|e| e.reward).collect();
            let (tokens, mask, old_lp) = pack(&exps, b, t);
            Ok(vec![tokens, mask, Tensor::from_f32(vec![b], rewards), old_lp])
        }
        "dpo" => {
            // experiences carry metadata role=chosen/rejected + pair ids
            let mut chosen: Vec<&Experience> = vec![];
            let mut rejected: Vec<&Experience> = vec![];
            for e in &exps {
                match e.metadata.get("role").and_then(crate::util::json::Value::as_str) {
                    Some("chosen") => chosen.push(e),
                    Some("rejected") => rejected.push(e),
                    _ => bail!("dpo experiences need metadata.role chosen/rejected"),
                }
            }
            ensure!(
                chosen.len() == rejected.len() && chosen.len() == b,
                "dpo batch must be {b}/{b} chosen/rejected"
            );
            // align pairs by pair id
            let pair_of = |e: &Experience| e.meta_f64("pair").unwrap_or(0.0) as u64;
            chosen.sort_by_key(|e| pair_of(e));
            rejected.sort_by_key(|e| pair_of(e));
            for (c, r) in chosen.iter().zip(&rejected) {
                ensure!(pair_of(c) == pair_of(r), "unmatched dpo pair ids");
            }
            let cvec: Vec<Experience> = chosen.into_iter().cloned().collect();
            let rvec: Vec<Experience> = rejected.into_iter().cloned().collect();
            let (tok_c, mask_c, _) = pack(&cvec, b, t);
            let (tok_r, mask_r, _) = pack(&rvec, b, t);
            let ref_c: Vec<f32> = cvec.iter().map(Experience::rollout_seq_logprob).collect();
            let ref_r: Vec<f32> = rvec.iter().map(Experience::rollout_seq_logprob).collect();
            Ok(vec![
                tok_c,
                mask_c,
                tok_r,
                mask_r,
                Tensor::from_f32(vec![b], ref_c),
                Tensor::from_f32(vec![b], ref_r),
            ])
        }
        other => bail!("unknown algorithm '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    fn exp(group: u64, reward: f32, tokens: Vec<i32>, plen: usize) -> Experience {
        let mut e = Experience::new(&format!("g{group}"), tokens, plen, reward);
        e.group = group;
        e.logprobs.iter_mut().skip(plen).for_each(|l| *l = -1.0);
        e
    }

    #[test]
    fn grpo_batch_shapes_and_advantages() {
        let cfg = AlgorithmConfig::new("grpo");
        let exps = vec![
            exp(1, 1.0, vec![1, 10, 11, 2], 2),
            exp(1, 0.0, vec![1, 10, 12, 2], 2),
            exp(2, 0.5, vec![1, 20, 2], 1),
            exp(2, 0.5, vec![1, 21, 2], 1),
        ];
        let out = build_batch(&cfg, exps, 4, 8, 1).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].shape(), &[4, 8]);
        let adv = out[2].f32_data().unwrap();
        assert!((adv[0] - 0.5).abs() < 1e-6);
        assert!((adv[1] + 0.5).abs() < 1e-6);
        assert_eq!(adv[2], 0.0);
        // padding masked out
        let mask = out[1].f32_data().unwrap();
        assert_eq!(mask[0], 0.0); // index 0 forced off
        assert_eq!(mask[6], 0.0); // beyond sequence
    }

    #[test]
    fn truncation_respects_bucket() {
        let cfg = AlgorithmConfig::new("sft");
        let long = exp(1, 1.0, (0..50).collect(), 3);
        let out = build_batch(&cfg, vec![long], 1, 8, 1).unwrap();
        assert_eq!(out[0].shape(), &[1, 8]);
        assert_eq!(out[0].i32_data().unwrap()[7], 7);
    }

    #[test]
    fn opmd_requires_complete_groups() {
        let cfg = AlgorithmConfig::new("opmd_simple");
        // groups of 2, interleaved order — must be sorted contiguous
        let exps = vec![
            exp(5, 1.0, vec![1, 2, 3], 1),
            exp(9, 0.3, vec![1, 2, 3], 1),
            exp(5, 0.0, vec![1, 2, 3], 1),
            exp(9, 0.6, vec![1, 2, 3], 1),
        ];
        let out = build_batch(&cfg, exps, 4, 4, 2).unwrap();
        let rewards = out[2].f32_data().unwrap();
        // sorted by group: [5, 5, 9, 9]
        assert_eq!(rewards, &[1.0, 0.0, 0.3, 0.6]);
        // incomplete group errors
        let bad = vec![
            exp(1, 1.0, vec![1, 2], 1),
            exp(1, 0.0, vec![1, 2], 1),
            exp(2, 0.5, vec![1, 2], 1),
            exp(3, 0.5, vec![1, 2], 1),
        ];
        assert!(build_batch(&cfg, bad, 4, 4, 2).is_err());
    }

    #[test]
    fn mix_batch_flags_non_explorer_sources() {
        let cfg = AlgorithmConfig::new("mix");
        let mut e1 = exp(1, 1.0, vec![1, 2, 3], 1);
        let mut e2 = exp(1, 0.0, vec![1, 2, 3], 1);
        e1.source = Source::Expert;
        e2.source = Source::Explorer;
        let mut e3 = exp(2, 0.0, vec![1, 2, 3], 1);
        e3.source = Source::Synthetic;
        let e4 = exp(2, 1.0, vec![1, 2, 3], 1);
        let out = build_batch(&cfg, vec![e1, e2, e3, e4], 4, 4, 1).unwrap();
        assert_eq!(out[4].f32_data().unwrap(), &[1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn dpo_batch_pairs_by_id() {
        let cfg = AlgorithmConfig::new("dpo");
        let mk = |pair: u64, role: &str, reward: f32| {
            let mut e = exp(pair, reward, vec![1, 5, 6, 2], 1);
            e.set_meta("pair", Value::num(pair as f64));
            e.set_meta("role", Value::str(role));
            e
        };
        let exps =
            vec![mk(2, "rejected", 0.0), mk(1, "chosen", 1.0), mk(2, "chosen", 1.0), mk(1, "rejected", 0.0)];
        let out = build_batch(&cfg, exps, 2, 8, 1).unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(out[0].shape(), &[2, 8]);
        assert_eq!(out[4].shape(), &[2]);
        // ref logprobs are masked rollout sums: 3 response tokens * -1.0
        for v in out[4].f32_data().unwrap() {
            assert!((*v + 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn wrong_batch_size_errors() {
        let cfg = AlgorithmConfig::new("grpo");
        assert!(build_batch(&cfg, vec![exp(1, 0.0, vec![1, 2], 1)], 4, 8, 1).is_err());
    }

    #[test]
    fn hyper_vec_layout_matches_manifest() {
        let h = HyperParams { lr: 0.5, ..Default::default() };
        let v = h.to_vec();
        assert_eq!(v.len(), 8);
        assert_eq!(v[0], 0.5); // lr first (manifest hyper_slots[0])
    }
}
