//! The composable algorithm API (paper §3.2): an RL algorithm is an
//! [`AlgorithmSpec`] assembled from pluggable modules — an
//! [`AdvantageFn`], a [`LossSpec`], a [`GroupingPolicy`], a batch
//! [`Pairing`] layout, and a linked sample-strategy factory — instead of
//! a `match` arm inside the trainer.  A new algorithm is a registration
//! in the [`AlgorithmRegistry`](super::registry::AlgorithmRegistry),
//! not a fork of the trainer (see `examples/mix_algorithm.rs`).

use std::fmt;
use std::sync::Arc;

use anyhow::Result;

use crate::buffer::{FifoFactory, SampleStrategyFactory};

use super::advantage::{AdvantageFn, ExtraInputFn, NoAdvantage};

/// The 8 hyper slots of every train artifact (manifest `hyper_slots`).
/// This is the artifact ABI: slot 5 is the shared tau/beta slot and slot
/// 6 the MIX mu slot.  Configuration no longer overloads these directly
/// — the typed per-algorithm config sections fill them through
/// [`TauSlot`] (see `coordinator::config`).
#[derive(Debug, Clone)]
pub struct HyperParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub clip_eps: f32,
    /// ABI slot 5: tau for OPMD, beta for DPO (see [`TauSlot`]).
    pub tau_or_beta: f32,
    /// ABI slot 6: MIX's SFT weight.
    pub mu: f32,
    pub kl_coef: f32,
}

impl Default for HyperParams {
    fn default() -> Self {
        HyperParams {
            lr: 1e-4,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            clip_eps: 0.2,
            tau_or_beta: 1.0,
            mu: 0.1,
            kl_coef: 0.0,
        }
    }
}

impl HyperParams {
    pub fn to_vec(&self) -> Vec<f32> {
        vec![
            self.lr,
            self.beta1,
            self.beta2,
            self.adam_eps,
            self.clip_eps,
            self.tau_or_beta,
            self.mu,
            self.kl_coef,
        ]
    }
}

/// Which typed config value fills the shared tau/beta ABI slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TauSlot {
    /// `algorithm.opmd.tau` (KL-regularized mirror descent temperature).
    OpmdTau,
    /// `algorithm.dpo.beta` (preference sharpness).
    DpoBeta,
    /// The slot is unused; the raw `HyperParams` value passes through.
    Unused,
}

impl TauSlot {
    pub fn as_str(&self) -> &'static str {
        match self {
            TauSlot::OpmdTau => "opmd.tau",
            TauSlot::DpoBeta => "dpo.beta",
            TauSlot::Unused => "-",
        }
    }
}

/// The OPMD loss flavors of Appendix A.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpmdFlavor {
    Kimi,
    Pairwise,
    Simple,
}

/// Which fused policy loss the train artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyLoss {
    /// PPO-style clipped policy gradient (GRPO/PPO artifacts).
    PgClip,
    /// Clipped PG on rollouts + NLL on expert rows (the MIX loss).
    PgClipExpertMix,
    /// Plain negative log-likelihood (SFT).
    Nll,
    /// Pairwise preference loss over chosen/rejected (DPO).
    Preference,
    /// KL-regularized mirror descent over reward groups (OPMD family).
    MirrorDescent(OpmdFlavor),
}

impl PolicyLoss {
    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyLoss::PgClip => "pg_clip",
            PolicyLoss::PgClipExpertMix => "pg_clip+sft_mix",
            PolicyLoss::Nll => "nll",
            PolicyLoss::Preference => "preference",
            PolicyLoss::MirrorDescent(OpmdFlavor::Kimi) => "opmd_kimi",
            PolicyLoss::MirrorDescent(OpmdFlavor::Pairwise) => "opmd_pairwise",
            PolicyLoss::MirrorDescent(OpmdFlavor::Simple) => "opmd_simple",
        }
    }
}

/// The loss term of a spec: policy loss plus regularizer coefficients.
///
/// `kl_coef` seeds the artifact's KL slot default; `entropy_coef` is
/// declarative for now (the current fused artifacts report entropy as a
/// metric but bake no bonus) and is reserved for artifact regeneration.
#[derive(Debug, Clone)]
pub struct LossSpec {
    pub policy: PolicyLoss,
    pub tau_slot: TauSlot,
    pub kl_coef: f32,
    pub entropy_coef: f32,
}

impl LossSpec {
    pub fn pg_clip() -> LossSpec {
        LossSpec { policy: PolicyLoss::PgClip, tau_slot: TauSlot::Unused, kl_coef: 0.0, entropy_coef: 0.0 }
    }
    pub fn pg_clip_mix() -> LossSpec {
        LossSpec {
            policy: PolicyLoss::PgClipExpertMix,
            tau_slot: TauSlot::Unused,
            kl_coef: 0.0,
            entropy_coef: 0.0,
        }
    }
    pub fn nll() -> LossSpec {
        LossSpec { policy: PolicyLoss::Nll, tau_slot: TauSlot::Unused, kl_coef: 0.0, entropy_coef: 0.0 }
    }
    pub fn preference() -> LossSpec {
        LossSpec { policy: PolicyLoss::Preference, tau_slot: TauSlot::DpoBeta, kl_coef: 0.0, entropy_coef: 0.0 }
    }
    pub fn mirror_descent(flavor: OpmdFlavor) -> LossSpec {
        LossSpec {
            policy: PolicyLoss::MirrorDescent(flavor),
            tau_slot: TauSlot::OpmdTau,
            kl_coef: 0.0,
            entropy_coef: 0.0,
        }
    }
}

/// What group structure the algorithm's batches require.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupingPolicy {
    /// No group structure (SFT, DPO).
    None,
    /// Sequences carry group ids and advantages use a per-group
    /// baseline, but incomplete groups are fine (GRPO/PPO/MIX).
    GroupBaseline,
    /// Batches must consist of contiguous, complete groups of the
    /// artifact's group size `k` (the OPMD `[b/k, k]` reshape).
    CompleteGroups,
}

impl GroupingPolicy {
    /// Whether the algorithm interprets group ids at all.
    pub fn is_group_based(&self) -> bool {
        !matches!(self, GroupingPolicy::None)
    }
    /// Whether the batch builder must sort and verify complete groups.
    pub fn requires_complete_groups(&self) -> bool {
        matches!(self, GroupingPolicy::CompleteGroups)
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            GroupingPolicy::None => "none",
            GroupingPolicy::GroupBaseline => "group_baseline",
            GroupingPolicy::CompleteGroups => "complete_groups",
        }
    }
}

/// How experiences map onto artifact rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pairing {
    /// One experience per artifact row.
    Single,
    /// Chosen/rejected preference pairs: a batch of `b` rows consumes
    /// `2*b` experiences carrying `metadata.role` + `metadata.pair`.
    PreferencePairs,
}

impl Pairing {
    pub fn as_str(&self) -> &'static str {
        match self {
            Pairing::Single => "single",
            Pairing::PreferencePairs => "preference_pairs",
        }
    }
}

/// A complete algorithm: the declarative assembly of pluggable modules
/// the trainer executes.  Specs are immutable once registered; runtime
/// knobs live in [`AlgorithmConfig`].
pub struct AlgorithmSpec {
    /// Registry key (`algorithm.name` in configs).
    pub name: String,
    /// Train-artifact key in the AOT manifest.  Custom algorithms reuse
    /// a compiled artifact (e.g. `"grpo"`) under their own name.
    pub artifact: String,
    pub advantage: Arc<dyn AdvantageFn>,
    pub grouping: GroupingPolicy,
    pub pairing: Pairing,
    pub loss: LossSpec,
    /// Whether the artifact consumes rollout (old-policy) log-probs.
    pub old_logprobs: bool,
    /// Extra per-sequence inputs appended after the standard block.
    pub extras: Vec<Arc<dyn ExtraInputFn>>,
    /// How the trainer pulls batches for this algorithm.
    pub sample: Arc<dyn SampleStrategyFactory>,
    /// One-line description for `trinity algorithms list`.
    pub about: String,
}

impl fmt::Debug for AlgorithmSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AlgorithmSpec")
            .field("name", &self.name)
            .field("artifact", &self.artifact)
            .field("advantage", &self.advantage.name())
            .field("grouping", &self.grouping)
            .field("pairing", &self.pairing)
            .field("loss", &self.loss)
            .field("old_logprobs", &self.old_logprobs)
            .field("extras", &self.extras.iter().map(|e| e.name()).collect::<Vec<_>>())
            .field("sample", &self.sample.name())
            .finish()
    }
}

impl AlgorithmSpec {
    /// A minimal spec: NLL loss, no advantage, no grouping, FIFO
    /// sampling.  Builder methods refine it (see the registry's builtin
    /// registrations and `examples/mix_algorithm.rs`).
    pub fn new(name: &str, artifact: &str) -> AlgorithmSpec {
        AlgorithmSpec {
            name: name.to_string(),
            artifact: artifact.to_string(),
            advantage: Arc::new(NoAdvantage),
            grouping: GroupingPolicy::None,
            pairing: Pairing::Single,
            loss: LossSpec::nll(),
            old_logprobs: false,
            extras: vec![],
            sample: Arc::new(FifoFactory),
            about: String::new(),
        }
    }

    pub fn advantage(mut self, a: impl AdvantageFn + 'static) -> AlgorithmSpec {
        self.advantage = Arc::new(a);
        self
    }
    pub fn grouping(mut self, g: GroupingPolicy) -> AlgorithmSpec {
        self.grouping = g;
        self
    }
    pub fn pairing(mut self, p: Pairing) -> AlgorithmSpec {
        self.pairing = p;
        self
    }
    pub fn loss(mut self, l: LossSpec) -> AlgorithmSpec {
        self.loss = l;
        self
    }
    pub fn old_logprobs(mut self, on: bool) -> AlgorithmSpec {
        self.old_logprobs = on;
        self
    }
    pub fn extra(mut self, e: impl ExtraInputFn + 'static) -> AlgorithmSpec {
        self.extras.push(Arc::new(e));
        self
    }
    pub fn sample(mut self, s: impl SampleStrategyFactory + 'static) -> AlgorithmSpec {
        self.sample = Arc::new(s);
        self
    }
    pub fn about(mut self, text: &str) -> AlgorithmSpec {
        self.about = text.to_string();
        self
    }

    /// Experiences consumed per train step for an artifact batch of `b`.
    pub fn experiences_per_step(&self, b: usize) -> usize {
        match self.pairing {
            Pairing::Single => b,
            Pairing::PreferencePairs => 2 * b,
        }
    }

    /// Default hyper-parameters seeded from the spec's declarative loss
    /// coefficients.
    pub fn default_hyper(&self) -> HyperParams {
        HyperParams { kl_coef: self.loss.kl_coef, ..Default::default() }
    }
}

/// Runtime configuration of a registered algorithm: the immutable spec
/// plus the per-run knobs (hyper-parameters, normalization override).
#[derive(Debug, Clone)]
pub struct AlgorithmConfig {
    pub spec: Arc<AlgorithmSpec>,
    pub hyper: HyperParams,
    /// Config-level override: std-normalize group advantages (GRPO
    /// flavor).  Ignored by advantage functions without a baseline.
    pub adv_std_normalize: bool,
}

impl AlgorithmConfig {
    /// Look `name` up in the global registry.
    pub fn new(name: &str) -> Result<AlgorithmConfig> {
        Ok(Self::from_spec(super::registry::AlgorithmRegistry::global().get(name)?))
    }

    pub fn from_spec(spec: Arc<AlgorithmSpec>) -> AlgorithmConfig {
        let hyper = spec.default_hyper();
        AlgorithmConfig { spec, hyper, adv_std_normalize: false }
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Group-completeness requirements come from the spec's
    /// [`GroupingPolicy`], not from name prefixes.
    pub fn is_group_based(&self) -> bool {
        self.spec.grouping.is_group_based()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hyper_vec_layout_matches_manifest() {
        let h = HyperParams { lr: 0.5, ..Default::default() };
        let v = h.to_vec();
        assert_eq!(v.len(), 8);
        assert_eq!(v[0], 0.5); // lr first (manifest hyper_slots[0])
        assert_eq!(v[5], 1.0); // tau/beta slot
    }

    #[test]
    fn grouping_policy_declares_requirements() {
        assert!(!GroupingPolicy::None.is_group_based());
        assert!(GroupingPolicy::GroupBaseline.is_group_based());
        assert!(!GroupingPolicy::GroupBaseline.requires_complete_groups());
        assert!(GroupingPolicy::CompleteGroups.requires_complete_groups());
    }

    #[test]
    fn pairing_scales_experience_demand() {
        let spec = AlgorithmSpec::new("x", "x").pairing(Pairing::PreferencePairs);
        assert_eq!(spec.experiences_per_step(4), 8);
        assert_eq!(AlgorithmSpec::new("y", "y").experiences_per_step(4), 4);
    }

    #[test]
    fn grpo_declares_group_baseline_not_name_prefix() {
        // the satellite fix: GRPO is group-based through its declared
        // policy, OPMD through CompleteGroups — no `starts_with("opmd")`
        let grpo = AlgorithmConfig::new("grpo").unwrap();
        assert!(grpo.is_group_based());
        assert!(!grpo.spec.grouping.requires_complete_groups());
        let opmd = AlgorithmConfig::new("opmd_simple").unwrap();
        assert!(opmd.is_group_based());
        assert!(opmd.spec.grouping.requires_complete_groups());
        let sft = AlgorithmConfig::new("sft").unwrap();
        assert!(!sft.is_group_based());
    }
}
