//! Advantage functions — the first pluggable module of an
//! [`AlgorithmSpec`](super::spec::AlgorithmSpec) (paper §3.2).
//!
//! An advantage function turns a sampled batch into the per-sequence
//! scalar the train artifact consumes as its advantage/reward input.
//! Algorithms whose artifacts take no such input (SFT, DPO) use
//! [`NoAdvantage`].  Custom algorithms implement [`AdvantageFn`] and
//! register a spec — no trainer changes required.

use crate::buffer::{group_advantages, Experience, Source};

/// Per-sequence advantage/reward computation.
///
/// `std_normalize` is the config-level normalization override
/// (`algorithm.adv_std_normalize`); it is only meaningful for
/// group-baseline-style advantages and implementations are free to
/// ignore it.
pub trait AdvantageFn: Send + Sync {
    fn name(&self) -> &'static str;
    /// The per-sequence scalars for the artifact's advantage/reward
    /// input, or `None` if the algorithm's artifact takes no such input.
    fn compute(&self, exps: &[Experience], std_normalize: bool) -> Option<Vec<f32>>;
}

/// The artifact takes no advantage/reward tensor (SFT, DPO).
pub struct NoAdvantage;

impl AdvantageFn for NoAdvantage {
    fn name(&self) -> &'static str {
        "none"
    }
    fn compute(&self, _exps: &[Experience], _std_normalize: bool) -> Option<Vec<f32>> {
        None
    }
}

/// Group-mean-baseline advantages (GRPO): `r - mean(group rewards)`,
/// optionally std-normalized.  The spec-level `std_normalize` is OR-ed
/// with the config-level override.
pub struct GroupBaseline {
    pub std_normalize: bool,
}

impl AdvantageFn for GroupBaseline {
    fn name(&self) -> &'static str {
        "group_baseline"
    }
    fn compute(&self, exps: &[Experience], std_normalize: bool) -> Option<Vec<f32>> {
        Some(group_advantages(exps, self.std_normalize || std_normalize))
    }
}

/// Raw rewards passed straight through (OPMD family: the artifact's
/// fused loss applies its own in-kernel group baseline over the
/// `[b/k, k]` reshape, so the host must not pre-subtract anything).
pub struct RawReward;

impl AdvantageFn for RawReward {
    fn name(&self) -> &'static str {
        "raw_reward"
    }
    fn compute(&self, exps: &[Experience], _std_normalize: bool) -> Option<Vec<f32>> {
        Some(exps.iter().map(|e| e.reward).collect())
    }
}

/// Extra per-sequence input tensors appended after the standard
/// tokens/mask/advantage/logprob block (e.g. MIX's `is_expert` flag).
pub trait ExtraInputFn: Send + Sync {
    fn name(&self) -> &'static str;
    fn compute(&self, exps: &[Experience]) -> Vec<f32>;
}

/// 1.0 for experiences from non-explorer sources (expert / synthetic /
/// human trajectories) — the MIX loss routes these through its SFT term.
pub struct IsExpertFlag;

impl ExtraInputFn for IsExpertFlag {
    fn name(&self) -> &'static str {
        "is_expert"
    }
    fn compute(&self, exps: &[Experience]) -> Vec<f32> {
        exps.iter()
            .map(|e| {
                if matches!(e.source, Source::Expert | Source::Synthetic | Source::Human) {
                    1.0
                } else {
                    0.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp(group: u64, reward: f32, source: Source) -> Experience {
        let mut e = Experience::new(&format!("g{group}"), vec![1, 2, 3], 1, reward);
        e.group = group;
        e.source = source;
        e
    }

    #[test]
    fn group_baseline_subtracts_group_mean() {
        let exps = vec![
            exp(1, 1.0, Source::Explorer),
            exp(1, 0.0, Source::Explorer),
            exp(2, 0.5, Source::Explorer),
            exp(2, 0.5, Source::Explorer),
        ];
        let adv = GroupBaseline { std_normalize: false }.compute(&exps, false).unwrap();
        assert!((adv[0] - 0.5).abs() < 1e-6);
        assert!((adv[1] + 0.5).abs() < 1e-6);
        assert_eq!(adv[2], 0.0);
    }

    #[test]
    fn config_override_turns_on_normalization() {
        let exps = vec![exp(1, 1.0, Source::Explorer), exp(1, 0.0, Source::Explorer)];
        let raw = GroupBaseline { std_normalize: false }.compute(&exps, false).unwrap();
        let norm = GroupBaseline { std_normalize: false }.compute(&exps, true).unwrap();
        assert!((raw[0] - 0.5).abs() < 1e-6);
        assert!(norm[0] > raw[0], "std 0.5 divides the advantage up: {norm:?}");
    }

    #[test]
    fn raw_reward_passes_through() {
        let exps = vec![exp(1, 0.3, Source::Explorer), exp(1, 0.9, Source::Explorer)];
        assert_eq!(RawReward.compute(&exps, true).unwrap(), vec![0.3, 0.9]);
    }

    #[test]
    fn no_advantage_emits_nothing() {
        assert!(NoAdvantage.compute(&[], false).is_none());
    }

    #[test]
    fn is_expert_flags_non_explorer_sources() {
        let exps = vec![
            exp(1, 0.0, Source::Expert),
            exp(1, 0.0, Source::Explorer),
            exp(2, 0.0, Source::Synthetic),
            exp(2, 0.0, Source::Human),
        ];
        assert_eq!(IsExpertFlag.compute(&exps), vec![1.0, 0.0, 1.0, 1.0]);
    }
}
