//! The global algorithm registry (paper §3.2's AlgorithmType table):
//! every algorithm — the 8 builtins and any user-registered custom one —
//! is an [`AlgorithmSpec`] keyed by name.  The trainer, the coordinator
//! and the `trinity algorithms list` CLI all resolve algorithms here;
//! nothing in `trainer/` dispatches on name strings.

use std::sync::{Arc, OnceLock};

use anyhow::Result;

use crate::buffer::MixFactory;
use crate::util::Registry;

use super::advantage::{GroupBaseline, IsExpertFlag, RawReward};
use super::spec::{AlgorithmSpec, GroupingPolicy, LossSpec, OpmdFlavor, Pairing};

pub struct AlgorithmRegistry {
    specs: Registry<Arc<AlgorithmSpec>>,
}

impl AlgorithmRegistry {
    /// An empty registry (tests); production code uses [`global`].
    pub fn new() -> AlgorithmRegistry {
        AlgorithmRegistry {
            // algorithm names are case-sensitive identifiers (they key
            // artifact lookup), so no case folding here
            specs: Registry::new(
                "algorithm",
                "algorithms",
                "register custom algorithms with \
                 AlgorithmRegistry::global().register(AlgorithmSpec::new(..))",
                false,
            ),
        }
    }

    /// A registry pre-populated with the 8 builtin algorithms.
    pub fn with_builtins() -> AlgorithmRegistry {
        let r = AlgorithmRegistry::new();
        for spec in builtin_specs() {
            r.register(spec);
        }
        r
    }

    /// The process-wide registry, seeded with the builtins.  Custom
    /// algorithms register here before building a session:
    ///
    /// ```ignore
    /// AlgorithmRegistry::global().register(
    ///     AlgorithmSpec::new("my_alg", "grpo")
    ///         .advantage(GroupBaseline { std_normalize: true })
    ///         .grouping(GroupingPolicy::GroupBaseline)
    ///         .old_logprobs(true)
    ///         .loss(LossSpec::pg_clip()),
    /// );
    /// ```
    pub fn global() -> &'static AlgorithmRegistry {
        static GLOBAL: OnceLock<AlgorithmRegistry> = OnceLock::new();
        GLOBAL.get_or_init(AlgorithmRegistry::with_builtins)
    }

    /// Register a spec under its name.  Re-registering a name replaces
    /// the previous spec (latest wins), so registration is idempotent.
    pub fn register(&self, spec: AlgorithmSpec) -> Arc<AlgorithmSpec> {
        let spec = Arc::new(spec);
        self.specs.insert(spec.name.as_str(), Arc::clone(&spec));
        spec
    }

    pub fn get(&self, name: &str) -> Result<Arc<AlgorithmSpec>> {
        self.specs.lookup(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.specs.contains(name)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.specs.names()
    }

    /// Registered specs, sorted by name.
    pub fn specs(&self) -> Vec<Arc<AlgorithmSpec>> {
        self.specs.values()
    }
}

impl Default for AlgorithmRegistry {
    fn default() -> Self {
        AlgorithmRegistry::new()
    }
}

/// The 8 seed algorithms, re-expressed as declarative registrations.
fn builtin_specs() -> Vec<AlgorithmSpec> {
    let opmd = |name: &str, flavor: OpmdFlavor, about: &str| {
        AlgorithmSpec::new(name, name)
            .advantage(RawReward)
            .grouping(GroupingPolicy::CompleteGroups)
            .old_logprobs(true)
            .loss(LossSpec::mirror_descent(flavor))
            .about(about)
    };
    vec![
        AlgorithmSpec::new("grpo", "grpo")
            .advantage(GroupBaseline { std_normalize: false })
            .grouping(GroupingPolicy::GroupBaseline)
            .old_logprobs(true)
            .loss(LossSpec::pg_clip())
            .about("group-relative policy optimization: clipped PG on group-mean-baseline advantages"),
        AlgorithmSpec::new("ppo", "ppo")
            .advantage(GroupBaseline { std_normalize: false })
            .grouping(GroupingPolicy::GroupBaseline)
            .old_logprobs(true)
            .loss(LossSpec::pg_clip())
            .about("clipped PG with the shared group-baseline advantage estimator"),
        AlgorithmSpec::new("sft", "sft")
            .loss(LossSpec::nll())
            .about("supervised fine-tuning: NLL on masked response tokens"),
        AlgorithmSpec::new("dpo", "dpo")
            .pairing(Pairing::PreferencePairs)
            .loss(LossSpec::preference())
            .about("direct preference optimization over chosen/rejected pairs (beta = algorithm.dpo.beta)"),
        AlgorithmSpec::new("mix", "mix")
            .advantage(GroupBaseline { std_normalize: false })
            .grouping(GroupingPolicy::GroupBaseline)
            .old_logprobs(true)
            .loss(LossSpec::pg_clip_mix())
            .extra(IsExpertFlag)
            .sample(MixFactory)
            .about("(1-mu)*GRPO on rollouts + mu*SFT on expert rows (paper §3.2, Fig. 8)"),
        opmd(
            "opmd_kimi",
            OpmdFlavor::Kimi,
            "online policy mirror descent, Kimi-style squared regression target",
        ),
        opmd("opmd_pairwise", OpmdFlavor::Pairwise, "OPMD with pairwise in-group reward differences"),
        opmd("opmd_simple", OpmdFlavor::Simple, "OPMD with the plain group-softmax target (Appendix A)"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::buffer::{Experience, Source};
    use crate::coordinator::RftConfig;
    use crate::trainer::{build_batch, AlgorithmConfig};
    use crate::util::json::Value;
    use crate::util::yamlite;

    const BUILTINS: [&str; 8] =
        ["grpo", "ppo", "sft", "dpo", "mix", "opmd_kimi", "opmd_pairwise", "opmd_simple"];

    #[test]
    fn all_builtins_registered() {
        let reg = AlgorithmRegistry::global();
        for name in BUILTINS {
            assert!(reg.contains(name), "builtin '{name}' missing from registry");
        }
    }

    #[test]
    fn unknown_name_error_lists_registered_algorithms() {
        let err = AlgorithmRegistry::global().get("nope").unwrap_err().to_string();
        assert!(err.contains("unknown algorithm 'nope'"), "{err}");
        assert!(err.contains("grpo"), "error should list registered names: {err}");
        assert!(err.contains("register custom algorithms"), "{err}");
    }

    /// Synthesize a batch matching a spec's structural demands.
    fn exps_for(spec: &crate::trainer::AlgorithmSpec, b: usize, k: usize) -> Vec<Experience> {
        let n = spec.experiences_per_step(b);
        (0..n)
            .map(|i| {
                let mut e = Experience::new(&format!("t{i}"), vec![1, 10 + i as i32, 2], 1, (i % 2) as f32);
                e.group = (i / k) as u64;
                if spec.pairing == crate::trainer::Pairing::PreferencePairs {
                    e.set_meta("pair", Value::num((i / 2) as f64));
                    e.set_meta("role", Value::str(if i % 2 == 0 { "chosen" } else { "rejected" }));
                }
                if i == 0 {
                    e.source = Source::Expert;
                }
                e
            })
            .collect()
    }

    #[test]
    fn every_builtin_roundtrips_config_parse_registry_lookup_batch_build() {
        let (b, t, k) = (4, 8, 2);
        for name in BUILTINS {
            // config parse -> registry lookup
            let yaml = format!("mode: train\nalgorithm:\n  name: {name}\n");
            let cfg = RftConfig::from_value(&yamlite::parse(&yaml).unwrap()).unwrap();
            assert_eq!(cfg.algorithm, name);
            let spec = AlgorithmRegistry::global().get(&cfg.algorithm).unwrap();
            assert_eq!(spec.name, name);
            // batch build with a structurally valid synthetic batch
            let exps = exps_for(&spec, b, k);
            let built = build_batch(&AlgorithmConfig::from_spec(Arc::clone(&spec)), exps, b, t, k)
                .unwrap_or_else(|e| panic!("batch build failed for '{name}': {e:#}"));
            let has_adv = spec.advantage.compute(&exps_for(&spec, b, k), false).is_some();
            let expected_tensors = match spec.pairing {
                crate::trainer::Pairing::PreferencePairs => 6,
                crate::trainer::Pairing::Single => {
                    2 + has_adv as usize + spec.old_logprobs as usize + spec.extras.len()
                }
            };
            assert_eq!(
                built.tensors.len(),
                expected_tensors,
                "tensor arity for '{name}' (spec {spec:?})"
            );
        }
    }

    #[test]
    fn custom_registration_builds_batches_without_trainer_changes() {
        // a new algorithm = advantage + grouping + loss + artifact reuse
        AlgorithmRegistry::global().register(
            AlgorithmSpec::new("unit_custom_pg", "grpo")
                .advantage(GroupBaseline { std_normalize: true })
                .grouping(GroupingPolicy::GroupBaseline)
                .old_logprobs(true)
                .loss(LossSpec::pg_clip())
                .about("test-registered custom algorithm"),
        );
        let cfg = AlgorithmConfig::new("unit_custom_pg").unwrap();
        assert_eq!(cfg.spec.artifact, "grpo");
        let exps = exps_for(&cfg.spec, 4, 2);
        let built = build_batch(&cfg, exps, 4, 8, 2).unwrap();
        assert_eq!(built.tensors.len(), 4); // tokens, mask, adv, old_lp
    }

    #[test]
    fn reregistration_replaces_latest_wins() {
        let reg = AlgorithmRegistry::new();
        reg.register(AlgorithmSpec::new("dup", "grpo").about("first"));
        reg.register(AlgorithmSpec::new("dup", "sft").about("second"));
        assert_eq!(reg.get("dup").unwrap().artifact, "sft");
        assert_eq!(reg.names(), vec!["dup"]);
    }
}
