//! The trainer — learning side of the trinity.  Algorithms are
//! composable specs (advantage fn + loss + grouping + sample strategy)
//! resolved through the [`AlgorithmRegistry`]; the batch builder and the
//! training loop are algorithm-agnostic.  See DESIGN.md §4.

pub mod advantage;
pub mod batch;
pub mod registry;
pub mod spec;
pub mod trainer;

pub use advantage::{AdvantageFn, ExtraInputFn, GroupBaseline, IsExpertFlag, NoAdvantage, RawReward};
pub use batch::{build_batch, BuiltBatch};
pub use registry::AlgorithmRegistry;
pub use spec::{
    AlgorithmConfig, AlgorithmSpec, GroupingPolicy, HyperParams, LossSpec, OpmdFlavor, Pairing,
    PolicyLoss, TauSlot,
};
pub use trainer::{PublishStats, StepMetrics, Trainer, TrainerConfig};
