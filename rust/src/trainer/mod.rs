//! The trainer — learning side of the trinity: sample strategies feed
//! batch builders, batch builders feed the fused train-step artifacts.

pub mod algorithms;
pub mod trainer;

pub use algorithms::{build_batch, AlgorithmConfig, HyperParams};
pub use trainer::{StepMetrics, Trainer, TrainerConfig};
