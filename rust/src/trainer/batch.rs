//! The generic batch builder: one structural pipeline driven entirely by
//! the [`AlgorithmSpec`](super::spec::AlgorithmSpec) — no per-algorithm
//! `match` dispatch.  Single-row algorithms pack
//! `tokens, mask [, advantage] [, old_logprobs] [, extras...]`;
//! preference-pair algorithms pack the chosen/rejected DPO layout.

use anyhow::{bail, ensure, Result};

use crate::buffer::Experience;
use crate::runtime::Tensor;

use super::spec::{AlgorithmConfig, Pairing};

/// A built training batch: the artifact's data tensors plus builder
/// diagnostics surfaced into `StepMetrics.named`.
#[derive(Debug)]
pub struct BuiltBatch {
    pub tensors: Vec<Tensor>,
    /// Sequences longer than the artifact's `t` bucket that were
    /// truncated during packing (reported as `truncated_seqs`).
    pub truncated_seqs: usize,
}

/// Pack tokens / per-token arrays into fixed [b, t] tensors, truncating
/// long sequences and padding short ones.  Index 0's mask is forced to 0
/// (the logprob convention: lp[:, 0] is undefined).  Returns the packed
/// tensors plus the number of truncated sequences.
fn pack(exps: &[Experience], b: usize, t: usize) -> (Tensor, Tensor, Tensor, usize) {
    let mut tokens = vec![0i32; b * t];
    let mut mask = vec![0f32; b * t];
    let mut old_lp = vec![0f32; b * t];
    let mut truncated = 0usize;
    for (i, e) in exps.iter().enumerate().take(b) {
        if e.tokens.len() > t {
            truncated += 1;
        }
        let n = e.tokens.len().min(t);
        for j in 0..n {
            tokens[i * t + j] = e.tokens[j];
            mask[i * t + j] = e.loss_mask[j];
            old_lp[i * t + j] = e.logprobs[j];
        }
        mask[i * t] = 0.0;
    }
    (
        Tensor::from_i32(vec![b, t], tokens),
        Tensor::from_f32(vec![b, t], mask),
        Tensor::from_f32(vec![b, t], old_lp),
        truncated,
    )
}

/// Sort experiences so same-group rollouts are contiguous and complete
/// groups of size `k` (required by the OPMD artifacts' group reshape).
fn order_groups(exps: &mut [Experience], k: usize) -> Result<()> {
    ensure!(k >= 1, "group size must be >= 1");
    exps.sort_by_key(|e| e.group);
    ensure!(exps.len() % k == 0, "batch of {} not divisible by group size {k}", exps.len());
    for chunk in exps.chunks(k) {
        let g = chunk[0].group;
        ensure!(
            chunk.iter().all(|e| e.group == g),
            "incomplete group {g}: complete-group batches need {k} rollouts per task"
        );
    }
    Ok(())
}

/// Build the data tensor list for a configured algorithm from a sampled
/// batch.  `(b, t, k)` is the train artifact's shape bucket.
pub fn build_batch(
    cfg: &AlgorithmConfig,
    mut exps: Vec<Experience>,
    b: usize,
    t: usize,
    k: usize,
) -> Result<BuiltBatch> {
    let spec = &cfg.spec;
    let expected = spec.experiences_per_step(b);
    ensure!(
        exps.len() == expected,
        "algorithm '{}' needs exactly {expected} experiences, got {}",
        spec.name,
        exps.len()
    );
    match spec.pairing {
        Pairing::PreferencePairs => build_preference_batch(&exps, b, t),
        Pairing::Single => {
            if spec.grouping.requires_complete_groups() {
                order_groups(&mut exps, k)?;
            }
            let adv = spec.advantage.compute(&exps, cfg.adv_std_normalize);
            let (tokens, mask, old_lp, truncated_seqs) = pack(&exps, b, t);
            let mut tensors = vec![tokens, mask];
            if let Some(a) = adv {
                ensure!(
                    a.len() == b,
                    "advantage fn '{}' produced {} values for batch of {b}",
                    spec.advantage.name(),
                    a.len()
                );
                tensors.push(Tensor::from_f32(vec![b], a));
            }
            if spec.old_logprobs {
                tensors.push(old_lp);
            }
            for extra in &spec.extras {
                let vals = extra.compute(&exps);
                ensure!(
                    vals.len() == b,
                    "extra input '{}' produced {} values for batch of {b}",
                    extra.name(),
                    vals.len()
                );
                tensors.push(Tensor::from_f32(vec![b], vals));
            }
            Ok(BuiltBatch { tensors, truncated_seqs })
        }
    }
}

/// The DPO layout: chosen/rejected tokens + masks + rollout reference
/// sequence log-probs, aligned by pair id.
fn build_preference_batch(exps: &[Experience], b: usize, t: usize) -> Result<BuiltBatch> {
    let mut chosen: Vec<&Experience> = vec![];
    let mut rejected: Vec<&Experience> = vec![];
    for e in exps {
        match e.metadata.get("role").and_then(crate::util::json::Value::as_str) {
            Some("chosen") => chosen.push(e),
            Some("rejected") => rejected.push(e),
            _ => bail!("preference-pair experiences need metadata.role chosen/rejected"),
        }
    }
    ensure!(
        chosen.len() == rejected.len() && chosen.len() == b,
        "preference batch must be {b}/{b} chosen/rejected"
    );
    // align pairs by pair id
    let pair_of = |e: &Experience| e.meta_f64("pair").unwrap_or(0.0) as u64;
    chosen.sort_by_key(|e| pair_of(e));
    rejected.sort_by_key(|e| pair_of(e));
    for (c, r) in chosen.iter().zip(&rejected) {
        ensure!(pair_of(c) == pair_of(r), "unmatched preference pair ids");
    }
    let cvec: Vec<Experience> = chosen.into_iter().cloned().collect();
    let rvec: Vec<Experience> = rejected.into_iter().cloned().collect();
    let (tok_c, mask_c, _, trunc_c) = pack(&cvec, b, t);
    let (tok_r, mask_r, _, trunc_r) = pack(&rvec, b, t);
    let ref_c: Vec<f32> = cvec.iter().map(Experience::rollout_seq_logprob).collect();
    let ref_r: Vec<f32> = rvec.iter().map(Experience::rollout_seq_logprob).collect();
    Ok(BuiltBatch {
        tensors: vec![
            tok_c,
            mask_c,
            tok_r,
            mask_r,
            Tensor::from_f32(vec![b], ref_c),
            Tensor::from_f32(vec![b], ref_r),
        ],
        truncated_seqs: trunc_c + trunc_r,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    fn exp(group: u64, reward: f32, tokens: Vec<i32>, plen: usize) -> Experience {
        let mut e = Experience::new(&format!("g{group}"), tokens, plen, reward);
        e.group = group;
        e.logprobs.iter_mut().skip(plen).for_each(|l| *l = -1.0);
        e
    }

    fn cfg(name: &str) -> AlgorithmConfig {
        AlgorithmConfig::new(name).unwrap()
    }

    #[test]
    fn grpo_batch_shapes_and_advantages() {
        let exps = vec![
            exp(1, 1.0, vec![1, 10, 11, 2], 2),
            exp(1, 0.0, vec![1, 10, 12, 2], 2),
            exp(2, 0.5, vec![1, 20, 2], 1),
            exp(2, 0.5, vec![1, 21, 2], 1),
        ];
        let out = build_batch(&cfg("grpo"), exps, 4, 8, 1).unwrap().tensors;
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].shape(), &[4, 8]);
        let adv = out[2].f32_data().unwrap();
        assert!((adv[0] - 0.5).abs() < 1e-6);
        assert!((adv[1] + 0.5).abs() < 1e-6);
        assert_eq!(adv[2], 0.0);
        // padding masked out
        let mask = out[1].f32_data().unwrap();
        assert_eq!(mask[0], 0.0); // index 0 forced off
        assert_eq!(mask[6], 0.0); // beyond sequence
    }

    #[test]
    fn truncation_respects_bucket_and_is_counted() {
        let long = exp(1, 1.0, (0..50).collect(), 3);
        let built = build_batch(&cfg("sft"), vec![long], 1, 8, 1).unwrap();
        assert_eq!(built.tensors[0].shape(), &[1, 8]);
        assert_eq!(built.tensors[0].i32_data().unwrap()[7], 7);
        assert_eq!(built.truncated_seqs, 1);
        // a fitting sequence is not counted
        let ok = build_batch(&cfg("sft"), vec![exp(1, 1.0, vec![1, 2, 3], 1)], 1, 8, 1).unwrap();
        assert_eq!(ok.truncated_seqs, 0);
    }

    #[test]
    fn opmd_requires_complete_groups() {
        // groups of 2, interleaved order — must be sorted contiguous
        let exps = vec![
            exp(5, 1.0, vec![1, 2, 3], 1),
            exp(9, 0.3, vec![1, 2, 3], 1),
            exp(5, 0.0, vec![1, 2, 3], 1),
            exp(9, 0.6, vec![1, 2, 3], 1),
        ];
        let out = build_batch(&cfg("opmd_simple"), exps, 4, 4, 2).unwrap().tensors;
        let rewards = out[2].f32_data().unwrap();
        // sorted by group: [5, 5, 9, 9]
        assert_eq!(rewards, &[1.0, 0.0, 0.3, 0.6]);
        // incomplete group errors
        let bad = vec![
            exp(1, 1.0, vec![1, 2], 1),
            exp(1, 0.0, vec![1, 2], 1),
            exp(2, 0.5, vec![1, 2], 1),
            exp(3, 0.5, vec![1, 2], 1),
        ];
        assert!(build_batch(&cfg("opmd_simple"), bad, 4, 4, 2).is_err());
    }

    #[test]
    fn mix_batch_flags_non_explorer_sources() {
        use crate::buffer::Source;
        let mut e1 = exp(1, 1.0, vec![1, 2, 3], 1);
        let mut e2 = exp(1, 0.0, vec![1, 2, 3], 1);
        e1.source = Source::Expert;
        e2.source = Source::Explorer;
        let mut e3 = exp(2, 0.0, vec![1, 2, 3], 1);
        e3.source = Source::Synthetic;
        let e4 = exp(2, 1.0, vec![1, 2, 3], 1);
        let out = build_batch(&cfg("mix"), vec![e1, e2, e3, e4], 4, 4, 1).unwrap().tensors;
        assert_eq!(out.len(), 5);
        assert_eq!(out[4].f32_data().unwrap(), &[1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn dpo_batch_pairs_by_id() {
        let mk = |pair: u64, role: &str, reward: f32| {
            let mut e = exp(pair, reward, vec![1, 5, 6, 2], 1);
            e.set_meta("pair", Value::num(pair as f64));
            e.set_meta("role", Value::str(role));
            e
        };
        let exps =
            vec![mk(2, "rejected", 0.0), mk(1, "chosen", 1.0), mk(2, "chosen", 1.0), mk(1, "rejected", 0.0)];
        let out = build_batch(&cfg("dpo"), exps, 2, 8, 1).unwrap().tensors;
        assert_eq!(out.len(), 6);
        assert_eq!(out[0].shape(), &[2, 8]);
        assert_eq!(out[4].shape(), &[2]);
        // ref logprobs are masked rollout sums: 3 response tokens * -1.0
        for v in out[4].f32_data().unwrap() {
            assert!((*v + 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn wrong_batch_size_errors() {
        assert!(build_batch(&cfg("grpo"), vec![exp(1, 0.0, vec![1, 2], 1)], 4, 8, 1).is_err());
    }

    #[test]
    fn std_normalize_override_changes_grpo_advantages() {
        let exps = || {
            vec![
                exp(1, 1.0, vec![1, 2, 3], 1),
                exp(1, 0.0, vec![1, 2, 3], 1),
                exp(2, 1.0, vec![1, 2, 3], 1),
                exp(2, 0.0, vec![1, 2, 3], 1),
            ]
        };
        let plain = build_batch(&cfg("grpo"), exps(), 4, 4, 1).unwrap().tensors;
        let mut normalized_cfg = cfg("grpo");
        normalized_cfg.adv_std_normalize = true;
        let normed = build_batch(&normalized_cfg, exps(), 4, 4, 1).unwrap().tensors;
        let a = plain[2].f32_data().unwrap();
        let b = normed[2].f32_data().unwrap();
        assert!((a[0] - 0.5).abs() < 1e-6);
        assert!(b[0] > a[0], "normalized {b:?} vs plain {a:?}");
    }
}
