//! [`RolloutService`]: the client-facing tier.  Implements
//! [`RolloutModel`] so workflow runners hold a [`ServiceHandle`] exactly
//! where they used to hold an engine, and [`RolloutEndpoint`] so the
//! scheduler's weight publishes roll across the replica pool.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::cache::{PrefixIndex, ReplicaView, RouteDecision};
use crate::exec::Promise;
use crate::explorer::generation::{
    GenOutput, GenerationEngine, RolloutEndpoint, RolloutModel, SamplingArgs,
};
use crate::model::{WeightSnapshot, WeightSync};
use crate::obs::{
    Anomaly, FlightRecorder, FlightSource, MigrateDetail, SpanKind, SpanRecorder,
};
use crate::qos::{choose_destination, RequestClass, SessionState};
use crate::util::json::Value;

use super::batcher::{route_job, run_worker, RowJob, WorkerSetup};
use super::replica::{
    Breaker, EngineReplica, ModelReplica, ReplicaEngine, ReplicaObs, ReplicaState,
};
use super::telemetry::{ServiceMetrics, ServiceSnapshot};
use super::ServiceConfig;

/// What a workflow runner holds: a shared handle on the service.
pub type ServiceHandle = Arc<RolloutService>;

pub struct RolloutService {
    cfg: ServiceConfig,
    replicas: Vec<Arc<ReplicaState>>,
    metrics: Arc<ServiceMetrics>,
    /// The prefix-reuse cache index (None when disabled): affinity
    /// routing in `chat`, entry admission in the workers, invalidation
    /// on the weight paths.
    prefix: Option<Arc<PrefixIndex>>,
    /// Span recorder threaded into workers and replicas (None = off).
    obs: Option<Arc<SpanRecorder>>,
    /// Flight recorder (None = off): breaker opens, deadline bursts and
    /// failed migrations fire anomaly dumps through it.
    flight: Option<Arc<FlightRecorder>>,
    shutdown: Arc<AtomicBool>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl RolloutService {
    /// Build over explicit replica engines; spawns one worker per replica.
    pub fn new(engines: Vec<Arc<dyn ReplicaEngine>>, cfg: ServiceConfig) -> Result<RolloutService> {
        let prefix = Self::build_index(&cfg);
        Self::assemble(engines, cfg, prefix, Arc::new(ServiceMetrics::new()), None, None)
    }

    /// The service-wide prefix index for a config (shared with the
    /// engine replicas so parked-session accounting lands in one place).
    fn build_index(cfg: &ServiceConfig) -> Option<Arc<PrefixIndex>> {
        cfg.cache.enabled.then(|| Arc::new(PrefixIndex::new(cfg.cache.clone())))
    }

    fn assemble(
        engines: Vec<Arc<dyn ReplicaEngine>>,
        cfg: ServiceConfig,
        prefix: Option<Arc<PrefixIndex>>,
        metrics: Arc<ServiceMetrics>,
        obs: Option<Arc<SpanRecorder>>,
        flight: Option<Arc<FlightRecorder>>,
    ) -> Result<RolloutService> {
        ensure!(!engines.is_empty(), "rollout service needs at least one replica");
        cfg.validate()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let replicas: Vec<Arc<ReplicaState>> = engines
            .into_iter()
            .enumerate()
            .map(|(id, engine)| {
                Arc::new(ReplicaState::with_qos(
                    id,
                    engine,
                    Breaker::new(cfg.breaker_failures, cfg.quarantine),
                    &cfg.qos,
                ))
            })
            .collect();
        let mut workers = Vec::with_capacity(replicas.len());
        for replica in &replicas {
            let setup = WorkerSetup {
                replica: Arc::clone(replica),
                peers: replicas.clone(),
                cfg: cfg.clone(),
                metrics: Arc::clone(&metrics),
                cache: prefix.clone(),
                obs: obs.clone(),
                flight: flight.clone(),
                shutdown: Arc::clone(&shutdown),
            };
            let poisoned_replica = Arc::clone(replica);
            let poisoned_metrics = Arc::clone(&metrics);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rollout-svc-{}", replica.id))
                    .spawn(move || {
                        let caught = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| run_worker(setup)),
                        );
                        if caught.is_err() {
                            // a dead worker must not wedge the service:
                            // park the replica out of rotation, reject
                            // its queue so routed work errors instead of
                            // hanging (in-flight completers were dropped
                            // by the unwind -> callers see worker-lost)
                            crate::log_warn!(
                                "service",
                                "replica {} worker panicked; replica poisoned",
                                poisoned_replica.id
                            );
                            poisoned_replica
                                .breaker
                                .lock()
                                .unwrap_or_else(|p| p.into_inner())
                                .quarantine_for(
                                    std::time::Instant::now(),
                                    std::time::Duration::from_secs(365 * 86_400),
                                );
                            for job in poisoned_replica.queue.close() {
                                poisoned_metrics.failed.fetch_add(1, Ordering::SeqCst);
                                job.completer.complete(Err(anyhow!(
                                    "replica worker died while this request was queued"
                                )));
                            }
                        }
                    })
                    .expect("spawn service worker"),
            );
        }
        // evidence section for flight dumps: per-class queue pressure
        // and per-replica health at the moment of the anomaly (acyclic:
        // the source holds Arcs into the pool, not the service)
        if let Some(f) = &flight {
            f.attach(Arc::new(QueuePressureSource {
                replicas: replicas.clone(),
                metrics: Arc::clone(&metrics),
            }));
        }
        Ok(RolloutService {
            cfg,
            replicas,
            metrics,
            prefix,
            obs,
            flight,
            shutdown,
            workers: Mutex::new(workers),
        })
    }

    /// A pool of generation-engine replicas (the production wiring).
    /// Each replica shares the service's prefix index so session-tagged
    /// turns park and resume real KV sessions on the replica that
    /// served their prefix.
    pub fn over_engines(
        engines: Vec<Arc<GenerationEngine>>,
        cfg: ServiceConfig,
    ) -> Result<RolloutService> {
        Self::over_engines_obs(engines, cfg, None)
    }

    /// [`over_engines`](Self::over_engines) with span tracing attached:
    /// every replica stamps prefill/resume/decode spans into `obs`.
    pub fn over_engines_obs(
        engines: Vec<Arc<GenerationEngine>>,
        cfg: ServiceConfig,
        obs: Option<Arc<SpanRecorder>>,
    ) -> Result<RolloutService> {
        Self::over_engines_diag(engines, cfg, obs, None)
    }

    /// [`over_engines_obs`](Self::over_engines_obs) with the full
    /// diagnostics plane: anomalies on the serving path (breaker opens,
    /// deadline bursts, failed migrations) fire flight dumps.
    pub fn over_engines_diag(
        engines: Vec<Arc<GenerationEngine>>,
        cfg: ServiceConfig,
        obs: Option<Arc<SpanRecorder>>,
        flight: Option<Arc<FlightRecorder>>,
    ) -> Result<RolloutService> {
        let refill_chunk = cfg.refill_chunk;
        let prefix = Self::build_index(&cfg);
        let metrics = Arc::new(ServiceMetrics::new());
        let replicas = engines
            .into_iter()
            .enumerate()
            .map(|(id, e)| {
                let mut replica = EngineReplica::with_cache(e, refill_chunk, prefix.clone());
                if let Some(spans) = &obs {
                    replica = replica.with_obs(ReplicaObs {
                        id: id as u32,
                        spans: Arc::clone(spans),
                        metrics: Arc::clone(&metrics),
                    });
                }
                Arc::new(replica) as Arc<dyn ReplicaEngine>
            })
            .collect();
        Self::assemble(replicas, cfg, prefix, metrics, obs, flight)
    }

    /// A pool over plain endpoints (mock engines in tests and benches).
    pub fn over_models(
        models: Vec<Arc<dyn RolloutEndpoint>>,
        cfg: ServiceConfig,
    ) -> Result<RolloutService> {
        Self::over_models_obs(models, cfg, None)
    }

    /// [`over_models`](Self::over_models) with span tracing attached.
    pub fn over_models_obs(
        models: Vec<Arc<dyn RolloutEndpoint>>,
        cfg: ServiceConfig,
        obs: Option<Arc<SpanRecorder>>,
    ) -> Result<RolloutService> {
        Self::over_models_diag(models, cfg, obs, None)
    }

    /// [`over_models_obs`](Self::over_models_obs) with the full
    /// diagnostics plane attached (see
    /// [`over_engines_diag`](Self::over_engines_diag)).
    pub fn over_models_diag(
        models: Vec<Arc<dyn RolloutEndpoint>>,
        cfg: ServiceConfig,
        obs: Option<Arc<SpanRecorder>>,
        flight: Option<Arc<FlightRecorder>>,
    ) -> Result<RolloutService> {
        let max_batch = if cfg.max_batch > 0 { cfg.max_batch } else { 8 };
        let prefix = Self::build_index(&cfg);
        let metrics = Arc::new(ServiceMetrics::new());
        let replicas = models
            .into_iter()
            .enumerate()
            .map(|(id, m)| {
                let mut replica = ModelReplica::new(m, max_batch);
                if let Some(spans) = &obs {
                    replica = replica.with_obs(ReplicaObs {
                        id: id as u32,
                        spans: Arc::clone(spans),
                        metrics: Arc::clone(&metrics),
                    });
                }
                Arc::new(replica) as Arc<dyn ReplicaEngine>
            })
            .collect();
        Self::assemble(replicas, cfg, prefix, metrics, obs, flight)
    }

    /// The span recorder, when observability is enabled.
    pub fn observer(&self) -> Option<&Arc<SpanRecorder>> {
        self.obs.as_ref()
    }

    /// The flight recorder, when diagnostics are enabled.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The prefix-reuse index, when the cache is enabled (tests and
    /// benches read hit/reuse telemetry through it).
    pub fn prefix_index(&self) -> Option<&Arc<PrefixIndex>> {
        self.prefix.as_ref()
    }

    /// Requests of one class queued across the pool right now (feeds
    /// the per-class gauges and the `[control]` admission caps).
    pub fn class_queued(&self, class: RequestClass) -> usize {
        self.replicas.iter().map(|r| r.queue.class_len(class)).sum()
    }

    /// Point-in-time telemetry (flows into `Monitor`/`ModeReport`).
    pub fn snapshot(&self) -> ServiceSnapshot {
        let replicas: Vec<_> = self.replicas.iter().map(|r| r.snapshot()).collect();
        let m = &self.metrics;
        ServiceSnapshot {
            submitted: m.submitted.load(Ordering::SeqCst),
            completed: m.completed.load(Ordering::SeqCst),
            failed: m.failed.load(Ordering::SeqCst),
            expired: m.expired.load(Ordering::SeqCst),
            retried: m.retried.load(Ordering::SeqCst),
            rerouted: m.rerouted.load(Ordering::SeqCst),
            sessions: m.sessions.load(Ordering::SeqCst),
            rows: m.rows.load(Ordering::SeqCst),
            refills: m.refills.load(Ordering::SeqCst),
            probes: m.probes.load(Ordering::SeqCst),
            mean_queue_wait_s: m.mean_queue_wait_s(),
            queue_wait: m.queue_wait.snapshot(),
            rollout: m.rollout.snapshot(),
            prefill: m.prefill.snapshot(),
            class_submitted: std::array::from_fn(|i| m.class_submitted[i].load(Ordering::SeqCst)),
            class_completed: std::array::from_fn(|i| m.class_completed[i].load(Ordering::SeqCst)),
            class_expired: std::array::from_fn(|i| m.class_expired[i].load(Ordering::SeqCst)),
            class_queue_wait: std::array::from_fn(|i| m.class_queue_wait[i].snapshot()),
            class_rollout: std::array::from_fn(|i| m.class_rollout[i].snapshot()),
            queued: replicas.iter().map(|r| r.queued).sum(),
            inflight: replicas.iter().map(|r| r.inflight).sum(),
            replicas,
            cache: self.prefix.as_ref().map(|p| p.snapshot()),
        }
    }

    /// Force-quarantine a replica (maintenance drain): opens its
    /// breaker for `cooldown`, so routing treats it as cold and — with
    /// the QoS plane on — its parked sessions become migration sources.
    /// Returns false for an unknown id.
    pub fn quarantine_replica(&self, id: usize, cooldown: Duration) -> bool {
        match self.replicas.iter().find(|r| r.id == id) {
            Some(r) => {
                r.breaker.lock().unwrap().quarantine_for(Instant::now(), cooldown);
                if let Some(f) = &self.flight {
                    f.trigger(
                        Anomaly::BreakerOpen,
                        &format!("replica {id} force-quarantined for {cooldown:?}"),
                    );
                }
                true
            }
            None => false,
        }
    }

    /// Live session migration (QoS plane, DESIGN.md §11): move episode
    /// `key`'s parked session off `holder` onto the cost-best
    /// same-version peer and rebind the prefix there, so the current
    /// turn resumes instead of re-prefilling `matched` tokens.  `None`
    /// = not worth it or not possible; callers cold-serve (always
    /// correct, just slower).
    #[allow(clippy::too_many_arguments)]
    fn try_migrate(
        &self,
        idx: &Arc<PrefixIndex>,
        key: u64,
        prompt: &[i32],
        holder: usize,
        version: u64,
        matched: usize,
        trace: u64,
        views: &[ReplicaView],
    ) -> Option<usize> {
        let mean_prompt = self.metrics.mean_prompt_tokens() as usize;
        let dest = choose_destination(views, holder, version, matched, mean_prompt)?;
        let holder_state = self.replicas.iter().find(|r| r.id == holder)?;
        let parked = holder_state.engine.extract_session(key, version)?;
        // descriptor-level sanity: a lease must actually resume this
        // prompt (the trie can match a prefix whose lease moved on)
        let state = SessionState::describe(&parked);
        let saved = state.saved_for(key, prompt, usize::MAX);
        if saved == 0 {
            let _ = holder_state.engine.adopt_session(parked);
            if let Some(f) = &self.flight {
                f.trigger(
                    Anomaly::MigrationFailure,
                    &format!("session {key:#x}: lease on replica {holder} resumes nothing"),
                );
            }
            return None;
        }
        let dest_state = self.replicas.iter().find(|r| r.id == dest)?;
        match dest_state.engine.adopt_session(parked) {
            Ok(()) => {
                idx.note_migrated(&prompt[..matched], dest, version, saved);
                if let Some(o) = &self.obs {
                    // detail packs the destination and the prefill
                    // tokens the move saves
                    let detail = MigrateDetail {
                        dest_replica: dest as u32,
                        saved_tokens: saved as u32,
                    };
                    o.mark(trace, SpanKind::Migrate, holder as u32, detail.pack());
                }
                Some(dest)
            }
            Err(parked) => {
                // destination refused (capacity / weights rolled since
                // the decision): restore the holder's park, cold-serve
                let _ = holder_state.engine.adopt_session(parked);
                if let Some(f) = &self.flight {
                    f.trigger(
                        Anomaly::MigrationFailure,
                        &format!("session {key:#x}: destination replica {dest} refused adoption"),
                    );
                }
                None
            }
        }
    }

    /// Stop accepting work, fail queued requests, join the workers.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        for replica in &self.replicas {
            for job in replica.queue.close() {
                self.metrics.failed.fetch_add(1, Ordering::SeqCst);
                job.completer.complete(Err(anyhow!("rollout service shut down")));
            }
        }
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in workers {
            let _ = w.join();
        }
    }
}

impl Drop for RolloutService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Flight-dump evidence section: per-class queue pressure and
/// per-replica health at the instant of the anomaly.  Holds `Arc`s into
/// the pool (not the service), keeping the recorder wiring acyclic.
struct QueuePressureSource {
    replicas: Vec<Arc<ReplicaState>>,
    metrics: Arc<ServiceMetrics>,
}

impl FlightSource for QueuePressureSource {
    fn name(&self) -> &'static str {
        "queues"
    }

    fn collect(&self) -> Value {
        let classes: Vec<(String, Value)> = RequestClass::ALL
            .iter()
            .map(|&class| {
                let i = class.index();
                let queued: usize =
                    self.replicas.iter().map(|r| r.queue.class_len(class)).sum();
                let count = |c: &std::sync::atomic::AtomicU64| c.load(Ordering::Relaxed) as i64;
                (
                    class.as_str().to_string(),
                    Value::obj(vec![
                        ("queued", Value::int(queued as i64)),
                        ("submitted", Value::int(count(&self.metrics.class_submitted[i]))),
                        ("completed", Value::int(count(&self.metrics.class_completed[i]))),
                        ("expired", Value::int(count(&self.metrics.class_expired[i]))),
                    ]),
                )
            })
            .collect();
        let replicas = self
            .replicas
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("id", Value::int(r.id as i64)),
                    ("queued", Value::int(r.queue.len() as i64)),
                    ("inflight", Value::int(r.inflight.load(Ordering::SeqCst) as i64)),
                    ("ready", Value::Bool(r.ready())),
                ])
            })
            .collect();
        Value::obj(vec![
            ("classes", Value::Object(classes)),
            ("replicas", Value::arr(replicas)),
        ])
    }
}

impl RolloutModel for RolloutService {
    /// Fan `n` completions out as independent row requests: rows are
    /// routed least-loaded and coalesced with *other* tasks' rows into
    /// shared sessions — this is where cross-runner batching happens.
    fn chat(&self, prompt: &[i32], n: usize, args: &SamplingArgs) -> Result<Vec<GenOutput>> {
        ensure!(n > 0, "chat needs n >= 1");
        ensure!(!self.shutdown.load(Ordering::SeqCst), "rollout service shut down");
        // session-tagged follow-up turns prefer the replica holding
        // their KV prefix — unless it is quarantined, stale or
        // overloaded.  With the QoS plane on, a quarantined/overloaded
        // holder's parked session is *migrated* to a healthy
        // same-version peer and resumed there; otherwise the rows take
        // the normal least-loaded path (cold prefill, always correct).
        let (preferred, reused) = match (&self.prefix, args.session) {
            (Some(idx), Some(key)) => {
                let views: Vec<ReplicaView> = self
                    .replicas
                    .iter()
                    .map(|r| ReplicaView {
                        id: r.id,
                        load: r.load(),
                        ready: r.ready(),
                        version: r.engine.weight_version(),
                    })
                    .collect();
                match idx.route_decision(prompt, &views) {
                    RouteDecision::Affinity { replica, matched } => (Some(replica), matched),
                    RouteDecision::Cold { holder, matched, version, reason }
                        if self.cfg.qos.wants_migration(reason)
                            && matched >= self.cfg.qos.migrate_min_tokens =>
                    {
                        let dest = self.try_migrate(
                            idx, key, prompt, holder, version, matched, args.trace, &views,
                        );
                        match dest {
                            Some(dest) => (Some(dest), matched),
                            None => (None, 0),
                        }
                    }
                    _ => (None, 0),
                }
            }
            _ => (None, 0),
        };
        let now = Instant::now();
        // per-class deadline (QoS plane); the fleet default otherwise
        let deadline = now + self.cfg.qos.deadline_for(args.class, self.cfg.request_timeout);
        self.metrics.note_submitted(n as u64, prompt.len() as u64, args.class);
        let mut promises = Vec::with_capacity(n);
        for i in 0..n {
            let (completer, promise) = Promise::pair();
            let mut row_args = args.clone();
            // every row samples an independent stream even when rows of
            // one task land in the same session
            row_args.seed = args.seed.wrapping_add((i as u64).wrapping_mul(0x9e3779b97f4a7c15));
            let job = RowJob {
                prompt: prompt.to_vec(),
                args: row_args,
                enqueued: now,
                deadline,
                attempts: 0,
                trace: args.trace,
                reused: reused as u32,
                completer,
            };
            route_job(&self.replicas, job, None, &self.metrics, preferred);
            promises.push(promise);
        }
        let mut outs = Vec::with_capacity(n);
        let mut first_err: Option<anyhow::Error> = None;
        for promise in promises {
            match promise.wait() {
                Ok(Ok(out)) => outs.push(out),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("service worker lost: {e}"));
                    }
                }
            }
        }
        self.metrics.note_rollout(now.elapsed(), args.class);
        match first_err {
            Some(e) => Err(e.context("rollout service request failed")),
            None => Ok(outs),
        }
    }

    /// The weakest replica version: what every routed request is
    /// guaranteed to be served with *at least*.
    fn weight_version(&self) -> u64 {
        self.replicas.iter().map(|r| r.engine.weight_version()).min().unwrap_or(0)
    }
}

impl RolloutEndpoint for RolloutService {
    /// Rolling weight update: the service fetches the published update
    /// **once** and applies the same shared `Arc<WeightSnapshot>` to
    /// each lagging replica in turn, so the others keep serving and the
    /// pool never holds more than one copy of the published weights —
    /// the old shape was N independent sync pulls, N deep copies.
    /// Succeeds if any replica applied; fails only when every replica
    /// failed.
    fn sync_weights(&self, sync: &dyn WeightSync) -> Result<bool> {
        // every explorer driver probes before every batch; skip the
        // fetch entirely when the whole pool is already current
        let pool_version = self.weight_version();
        if sync.latest_version() <= pool_version {
            return Ok(false);
        }
        let Some(update) = sync.fetch_if_newer(pool_version)? else {
            return Ok(false);
        };
        let mut updated = false;
        let mut failures = 0usize;
        let mut last_err: Option<anyhow::Error> = None;
        for replica in &self.replicas {
            match replica.engine.apply_update(&update) {
                Ok(true) => updated = true,
                Ok(false) => {}
                Err(e) => {
                    failures += 1;
                    crate::log_warn!(
                        "service",
                        "replica {} weight apply failed: {e:#}",
                        replica.id
                    );
                    last_err = Some(e);
                }
            }
        }
        if failures == self.replicas.len() {
            if let Some(e) = last_err {
                return Err(e.context("every replica failed to apply weights"));
            }
        }
        if updated {
            // invalidation-on-publish: prefixes older than the weakest
            // replica can never be resumed again (per-replica staleness
            // is additionally caught at lookup time)
            if let Some(prefix) = &self.prefix {
                prefix.invalidate_below(self.weight_version());
            }
        }
        Ok(updated)
    }

    fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        for replica in &self.replicas {
            replica.engine.set_weights(snapshot, version)?;
        }
        if let Some(prefix) = &self.prefix {
            prefix.invalidate_below(version);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::generation::MockModel;
    use crate::model::MemorySync;
    use std::time::Duration;

    fn service(models: Vec<MockModel>, cfg: ServiceConfig) -> RolloutService {
        let endpoints: Vec<Arc<dyn RolloutEndpoint>> =
            models.into_iter().map(|m| Arc::new(m) as Arc<dyn RolloutEndpoint>).collect();
        RolloutService::over_models(endpoints, cfg).unwrap()
    }

    #[test]
    fn chat_roundtrips_through_a_replica() {
        let svc = service(vec![MockModel::new(1, Duration::ZERO, 0.0)], ServiceConfig::default());
        let outs = svc.chat(&[1, 10, 11], 3, &SamplingArgs::default()).unwrap();
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert_eq!(o.prompt_len, 3);
            assert!(o.finished);
        }
        let snap = svc.snapshot();
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.submitted, 3);
        assert!(snap.sessions >= 1);
    }

    #[test]
    fn retry_rescues_transient_failures() {
        let mut cfg = ServiceConfig::default();
        cfg.max_attempts = 20;
        cfg.retry_backoff = Duration::from_millis(1);
        // threshold high enough that the breaker stays closed
        cfg.breaker_failures = 1000;
        let svc = service(vec![MockModel::new(2, Duration::ZERO, 0.5)], cfg);
        let outs = svc.chat(&[1, 5], 4, &SamplingArgs::default()).unwrap();
        assert_eq!(outs.len(), 4);
        let snap = svc.snapshot();
        assert!(snap.retried > 0, "expected retries under fail_rate=0.5: {snap:?}");
        assert_eq!(snap.failed, 0);
    }

    #[test]
    fn exhausted_attempts_surface_the_error() {
        let mut cfg = ServiceConfig::default();
        cfg.max_attempts = 2;
        cfg.retry_backoff = Duration::from_millis(1);
        cfg.breaker_failures = 1000;
        cfg.quarantine = Duration::from_millis(5);
        let svc = service(vec![MockModel::new(3, Duration::ZERO, 1.0)], cfg);
        let err = svc.chat(&[1], 1, &SamplingArgs::default()).unwrap_err().to_string();
        assert!(err.contains("rollout service request failed"), "{err}");
        let snap = svc.snapshot();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.retried, 1); // attempt 1 retried, attempt 2 terminal
    }

    #[test]
    fn weight_version_is_min_across_replicas_and_sync_rolls() {
        let a = MockModel::new(4, Duration::ZERO, 0.0);
        let b = MockModel::new(5, Duration::ZERO, 0.0);
        b.set_version(3);
        let svc = service(vec![a, b], ServiceConfig::default());
        assert_eq!(svc.weight_version(), 0);
        let sync = MemorySync::new();
        sync.publish(5, 50, WeightSnapshot::of(vec![vec![0.0]])).unwrap();
        assert!(svc.sync_weights(&sync).unwrap());
        assert_eq!(svc.weight_version(), 5);
        let snap = svc.snapshot();
        assert!(snap.replicas.iter().all(|r| r.weight_version == 5));
    }

    #[test]
    fn session_tagged_turns_hit_the_prefix_index() {
        let svc = service(vec![MockModel::new(9, Duration::ZERO, 0.0)], ServiceConfig::default());
        let args = SamplingArgs { session: Some(77), ..Default::default() };
        let turn1 = svc.chat(&[1, 10, 11, 12], 1, &args).unwrap().remove(0);
        // the next turn extends the full served transcript
        let mut prompt = turn1.tokens.clone();
        prompt.extend([13, 14]);
        svc.chat(&prompt, 1, &args).unwrap();
        let cache = svc.snapshot().cache.expect("cache enabled by default");
        assert_eq!(cache.lookups, 2);
        assert!(cache.hits >= 1, "turn 2 must reuse turn 1's prefix: {cache:?}");
        assert!(cache.reused_tokens >= turn1.tokens.len() as u64, "{cache:?}");
        // untagged traffic bypasses the cache entirely
        svc.chat(&[1, 2], 1, &SamplingArgs::default()).unwrap();
        assert_eq!(svc.snapshot().cache.unwrap().lookups, 2);
    }

    #[test]
    fn cache_disabled_service_reports_no_cache_telemetry() {
        let mut cfg = ServiceConfig::default();
        cfg.cache.enabled = false;
        let svc = service(vec![MockModel::new(10, Duration::ZERO, 0.0)], cfg);
        let args = SamplingArgs { session: Some(5), ..Default::default() };
        svc.chat(&[1, 2, 3], 1, &args).unwrap();
        assert!(svc.snapshot().cache.is_none());
    }

    #[test]
    fn shutdown_is_idempotent_and_rejects_new_work() {
        let svc = service(vec![MockModel::new(6, Duration::ZERO, 0.0)], ServiceConfig::default());
        svc.shutdown();
        svc.shutdown();
        assert!(svc.chat(&[1], 1, &SamplingArgs::default()).is_err());
    }
}
