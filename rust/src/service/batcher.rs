//! The microbatcher: per-replica request queues, admission-window
//! coalescing, and the worker loop that turns independent `chat` calls
//! into shared engine sessions.
//!
//! Each replica owns one worker thread.  The worker pops the first
//! queued request, waits up to the admission window for co-travellers
//! with the same sampling parameters, then serves the batch as ONE
//! shared session — refilling freed slots from the queue mid-session
//! (continuous batching).  Deadlines are enforced at pop time, failures
//! feed the replica's circuit breaker, and a newly quarantined replica
//! drains its queue to healthy peers.
//!
//! With the QoS plane enabled (`[qos]`, DESIGN.md §11) the queue splits
//! into per-class deques dequeued by weighted deficit-round-robin with
//! starvation-proof aging, so bulk training traffic cannot starve
//! interactive or eval requests; admission and refill matching then
//! stay within the leader's class (sessions are class-pure).  Disabled,
//! the single-FIFO path below is exactly the pre-QoS behavior.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cache::PrefixIndex;
use crate::exec::future::Completer;
use crate::explorer::generation::{GenOutput, SamplingArgs};
use crate::obs::{Anomaly, FlightRecorder, Span, SpanKind, SpanRecorder};
use crate::qos::{DrrScheduler, QosConfig, RequestClass, CLASS_COUNT};

use super::replica::{ReplicaState, ServeCtl};
use super::telemetry::ServiceMetrics;
use super::ServiceConfig;

/// One row request: a single completion of one prompt.  `chat(n)` fans
/// out into n row jobs that may land on different replicas/sessions.
pub struct RowJob {
    pub prompt: Vec<i32>,
    pub args: SamplingArgs,
    pub enqueued: Instant,
    pub deadline: Instant,
    /// Failed attempts so far (bounded by `service.max_attempts`).
    pub attempts: usize,
    /// Episode trace id threaded from `SamplingArgs` (0 = untraced);
    /// every span this job produces carries it.
    pub trace: u64,
    /// Prefix tokens the router matched for this request (0 = cold) —
    /// how mock-path replicas tell a resume from a cold prefill.
    pub reused: u32,
    pub completer: Completer<Result<GenOutput>>,
}

impl RowJob {
    pub fn batch_key(&self) -> SampleKey {
        SampleKey::of(&self.args)
    }

    pub fn expired(&self, now: Instant) -> bool {
        now >= self.deadline
    }
}

/// Sampling parameters a shared session must agree on (per-row budgets
/// and seeds may differ; temperature/top-k/top-p may not).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleKey {
    temperature_bits: u32,
    top_k: usize,
    top_p_bits: u32,
}

impl SampleKey {
    pub fn of(args: &SamplingArgs) -> SampleKey {
        SampleKey {
            temperature_bits: args.temperature.to_bits(),
            top_k: args.top_k,
            top_p_bits: args.top_p.to_bits(),
        }
    }
}

// ---------------------------------------------------------------------------
// request queue

struct QueueState {
    /// The single-FIFO path (QoS disabled) — exactly the pre-QoS queue.
    jobs: VecDeque<RowJob>,
    /// Per-class deques (QoS enabled), indexed by `RequestClass::index`.
    classes: [VecDeque<RowJob>; CLASS_COUNT],
    /// Deficit-round-robin state over `classes` (QoS enabled only).
    drr: DrrScheduler,
    closed: bool,
}

impl QueueState {
    fn total(&self) -> usize {
        self.jobs.len() + self.classes.iter().map(|q| q.len()).sum::<usize>()
    }

    fn drain_all(&mut self) -> Vec<RowJob> {
        let mut out: Vec<RowJob> = self.jobs.drain(..).collect();
        for q in self.classes.iter_mut() {
            out.extend(q.drain(..));
        }
        out
    }

    /// DRR-ordered pop (QoS path): feed per-class depths and head waits
    /// to the scheduler, pop the head of the class it picks.
    fn pop_fair(&mut self, cfg: &QosConfig) -> Option<RowJob> {
        let now = Instant::now();
        let mut lens = [0usize; CLASS_COUNT];
        let mut waits = [None; CLASS_COUNT];
        for c in 0..CLASS_COUNT {
            lens[c] = self.classes[c].len();
            waits[c] = self.classes[c].front().map(|j| now.saturating_duration_since(j.enqueued));
        }
        let c = self.drr.pick(&lens, &waits, cfg)?;
        self.classes[c].pop_front()
    }
}

/// A replica's request queue (condvar-based, like `exec::channel` but
/// with key-matching pops for sampling-compatible admission).
///
/// Built plain ([`RequestQueue::new`]) it is one FIFO.  Built with an
/// enabled [`QosConfig`] ([`RequestQueue::with_qos`]) it keeps one
/// deque per [`RequestClass`] and dequeues by weighted deficit-round-
/// robin, and key-matching pops (admission / refill) stay within the
/// session leader's class so batches are class-pure.
pub struct RequestQueue {
    state: Mutex<QueueState>,
    cvar: Condvar,
    /// `Some` = per-class DRR dequeue; `None` = plain FIFO.
    qos: Option<QosConfig>,
}

impl Default for RequestQueue {
    fn default() -> Self {
        RequestQueue::new()
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::build(None)
    }

    /// A queue honoring the QoS plane; falls back to the plain FIFO
    /// when `cfg.enabled` is false.
    pub fn with_qos(cfg: &QosConfig) -> RequestQueue {
        RequestQueue::build(cfg.enabled.then(|| cfg.clone()))
    }

    fn build(qos: Option<QosConfig>) -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                classes: std::array::from_fn(|_| VecDeque::new()),
                drr: DrrScheduler::new(),
                closed: false,
            }),
            cvar: Condvar::new(),
            qos,
        }
    }

    /// Whether this queue is running the per-class DRR path.
    pub fn qos_enabled(&self) -> bool {
        self.qos.is_some()
    }

    /// Enqueue; hands the job back if the queue is closed (shutdown).
    pub fn push(&self, job: RowJob) -> std::result::Result<(), RowJob> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(job);
        }
        match &self.qos {
            Some(_) => {
                let c = job.args.class.index();
                st.classes[c].push_back(job);
            }
            None => st.jobs.push_back(job),
        }
        drop(st);
        self.cvar.notify_all();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().total()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Jobs of one class waiting here (both paths scan; the FIFO path
    /// reads each job's class tag).  Feeds the per-class admission caps
    /// the `[control]` gate consults.
    pub fn class_len(&self, class: RequestClass) -> usize {
        let st = self.state.lock().unwrap();
        st.classes[class.index()].len()
            + st.jobs.iter().filter(|j| j.args.class == class).count()
    }

    /// Blocking pop bounded by `timeout`: the front job (FIFO path) or
    /// the DRR-scheduled class head (QoS path).
    pub fn pop_timeout(&self, timeout: Duration) -> Option<RowJob> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            let popped = match &self.qos {
                Some(cfg) => st.pop_fair(cfg),
                None => st.jobs.pop_front(),
            };
            if let Some(job) = popped {
                return Some(job);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cvar.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Non-blocking: remove the first job whose sampling key matches.
    /// On the QoS path only `class` (the session leader's) is scanned.
    pub fn try_pop_matching(&self, key: &SampleKey, class: RequestClass) -> Option<RowJob> {
        let mut st = self.state.lock().unwrap();
        match &self.qos {
            Some(_) => {
                let q = &mut st.classes[class.index()];
                let pos = q.iter().position(|j| j.batch_key() == *key)?;
                q.remove(pos)
            }
            None => {
                let pos = st.jobs.iter().position(|j| j.batch_key() == *key)?;
                st.jobs.remove(pos)
            }
        }
    }

    /// Key-matching pop that waits until `deadline` for a match (the
    /// admission window).  Same class restriction as
    /// [`try_pop_matching`](Self::try_pop_matching).
    pub fn pop_matching_until(
        &self,
        key: &SampleKey,
        class: RequestClass,
        deadline: Instant,
    ) -> Option<RowJob> {
        let mut st = self.state.lock().unwrap();
        loop {
            let pos = match &self.qos {
                Some(_) => {
                    let q = &st.classes[class.index()];
                    q.iter().position(|j| j.batch_key() == *key)
                }
                None => st.jobs.iter().position(|j| j.batch_key() == *key),
            };
            if let Some(pos) = pos {
                return match &self.qos {
                    Some(_) => st.classes[class.index()].remove(pos),
                    None => st.jobs.remove(pos),
                };
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cvar.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Remove everything (quarantine drain / shutdown).
    pub fn drain(&self) -> Vec<RowJob> {
        let mut st = self.state.lock().unwrap();
        st.drain_all()
    }

    /// Close the queue and hand back what was still waiting.
    pub fn close(&self) -> Vec<RowJob> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        let left = st.drain_all();
        drop(st);
        self.cvar.notify_all();
        left
    }
}

// ---------------------------------------------------------------------------
// routing

/// Least-loaded routing over ready replicas, with an optional affinity
/// override: `preferred` (the replica holding the request's KV prefix,
/// pre-vetted by the affinity policy) wins while it is still ready.
/// Least-loaded ties break by *pending estimated prefill tokens*
/// (queue depth × fleet mean prompt length) — two replicas with equal
/// in-flight load are not equal when one has a deeper prefill backlog.
/// When every replica is quarantined the job still lands somewhere: the
/// replica whose health probe is due soonest (requests are never
/// dropped by the router).
pub fn route_job(
    replicas: &[Arc<ReplicaState>],
    job: RowJob,
    exclude: Option<usize>,
    metrics: &ServiceMetrics,
    preferred: Option<usize>,
) {
    if let Some(p) = preferred {
        let holder = replicas.iter().find(|r| r.id == p && Some(r.id) != exclude && r.ready());
        if let Some(r) = holder {
            if let Err(job) = r.queue.push(job) {
                fail_now(job, "rollout service shut down", metrics);
            }
            return;
        }
        // the holder went unready between decision and push: fall
        // through to the normal cold path
    }
    let now = Instant::now();
    let mean_prompt = metrics.mean_prompt_tokens();
    let pending = |r: &ReplicaState| r.queue.len() as u64 * mean_prompt;
    let pick = replicas
        .iter()
        .filter(|r| Some(r.id) != exclude && r.ready())
        .min_by_key(|r| (r.load(), pending(r), r.id))
        .or_else(|| {
            // only the excluded replica is healthy — better it than none
            replicas.iter().filter(|r| r.ready()).min_by_key(|r| (r.load(), pending(r), r.id))
        })
        .or_else(|| {
            replicas.iter().min_by_key(|r| (r.probe_eta_ms(now), r.load(), r.id))
        });
    match pick {
        Some(r) => {
            if let Err(job) = r.queue.push(job) {
                fail_now(job, "rollout service shut down", metrics);
            }
        }
        None => fail_now(job, "rollout service has no replicas", metrics),
    }
}

/// Complete a job with a terminal error.
fn fail_now(job: RowJob, why: &str, metrics: &ServiceMetrics) {
    metrics.failed.fetch_add(1, Ordering::SeqCst);
    job.completer.complete(Err(anyhow!("{why}")));
}

/// Complete a job whose deadline passed while it was queued.  The
/// flight recorder (when present) counts the expiry toward its
/// deadline-burst trigger.
pub(super) fn expire_job(
    job: RowJob,
    metrics: &ServiceMetrics,
    flight: Option<&Arc<FlightRecorder>>,
) {
    metrics.note_expired(job.args.class);
    if let Some(f) = flight {
        f.note_expiry(job.args.class);
    }
    let waited = job.enqueued.elapsed();
    job.completer
        .complete(Err(anyhow!("request deadline exceeded after {waited:?} in queue")));
}

/// Record one job's queued-to-claimed wait: always into the metrics
/// histograms (fleet + the job's class), and as spans on the claiming
/// replica when tracing is enabled — a QueueWait span for every job,
/// plus a ClassWait span (detail = class index) for non-default classes
/// so per-class waits are separable in the trace.
fn note_claimed(
    job: &RowJob,
    now: Instant,
    replica_id: usize,
    metrics: &ServiceMetrics,
    obs: Option<&Arc<SpanRecorder>>,
) {
    let wait = now.saturating_duration_since(job.enqueued);
    metrics.note_queue_wait(wait, job.args.class);
    if let Some(o) = obs {
        o.record(Span {
            trace: job.trace,
            kind: SpanKind::QueueWait,
            replica: replica_id as u32,
            start_us: o.rel_us(job.enqueued),
            dur_us: wait.as_micros() as u64,
            detail: job.attempts as u64,
        });
        if job.args.class != RequestClass::TrainRollout {
            o.record(Span {
                trace: job.trace,
                kind: SpanKind::ClassWait,
                replica: replica_id as u32,
                start_us: o.rel_us(job.enqueued),
                dur_us: wait.as_micros() as u64,
                detail: job.args.class.index() as u64,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// the worker

pub struct WorkerSetup {
    pub replica: Arc<ReplicaState>,
    /// All replicas (for retry re-routing and quarantine drains).
    pub peers: Vec<Arc<ReplicaState>>,
    pub cfg: ServiceConfig,
    pub metrics: Arc<ServiceMetrics>,
    /// The service-wide prefix index, when the cache is enabled:
    /// completed session-tagged rows are admitted as reusable prefixes.
    pub cache: Option<Arc<PrefixIndex>>,
    /// Span recorder, when observability is enabled.
    pub obs: Option<Arc<SpanRecorder>>,
    /// Flight recorder, when diagnostics are enabled: breaker opens and
    /// deadline-expiry bursts fire anomaly dumps through it.
    pub flight: Option<Arc<FlightRecorder>>,
    pub shutdown: Arc<AtomicBool>,
}

/// Session-scoped [`ServeCtl`]: claims refills from the replica's queue,
/// completes finished rows, and collects failures for the post-session
/// retry pass.
struct WorkerCtl<'a> {
    replica: &'a ReplicaState,
    key: SampleKey,
    /// The session leader's request class: refill matching stays inside
    /// it on the QoS path (class-pure sessions).
    class: RequestClass,
    metrics: &'a ServiceMetrics,
    cache: Option<&'a Arc<PrefixIndex>>,
    obs: Option<&'a Arc<SpanRecorder>>,
    flight: Option<&'a Arc<FlightRecorder>>,
    /// Refills left before the session must end.  Bounds session
    /// lifetime so a steady stream of same-key traffic cannot starve a
    /// queued request with a different sampling key (which can only be
    /// popped — and deadline-checked — between sessions).
    refill_budget: usize,
    /// Concurrent-row cap (`service.max_batch`): idle-slot filling must
    /// not grow a session past the configured occupancy.
    max_inflight: usize,
    failed: Vec<(RowJob, anyhow::Error)>,
}

impl ServeCtl for WorkerCtl<'_> {
    fn refill(&mut self) -> Option<RowJob> {
        loop {
            if self.refill_budget == 0
                || self.replica.inflight.load(Ordering::SeqCst) >= self.max_inflight
            {
                return None;
            }
            let job = self.replica.queue.try_pop_matching(&self.key, self.class)?;
            let now = Instant::now();
            if job.expired(now) {
                expire_job(job, self.metrics, self.flight);
                continue;
            }
            note_claimed(&job, now, self.replica.id, self.metrics, self.obs);
            self.metrics.rows.fetch_add(1, Ordering::SeqCst);
            self.metrics.refills.fetch_add(1, Ordering::SeqCst);
            self.replica.inflight.fetch_add(1, Ordering::SeqCst);
            self.refill_budget -= 1;
            return Some(job);
        }
    }

    fn done(&mut self, job: RowJob, out: GenOutput) {
        self.replica.inflight.fetch_sub(1, Ordering::SeqCst);
        self.replica.rows_served.fetch_add(1, Ordering::SeqCst);
        self.replica.breaker.lock().unwrap().record_success();
        self.metrics.note_completed(job.args.class);
        // a session-tagged transcript is a reusable prefix for the
        // episode's next turn: index it under this replica and the
        // exact weight version that served it
        if job.args.session.is_some() {
            if let Some(cache) = self.cache {
                cache.admit(&out.tokens, self.replica.id, out.version);
            }
        }
        job.completer.complete(Ok(out));
    }

    fn fail(&mut self, job: RowJob, err: anyhow::Error) -> bool {
        self.replica.inflight.fetch_sub(1, Ordering::SeqCst);
        self.replica.failures.fetch_add(1, Ordering::SeqCst);
        let mut breaker = self.replica.breaker.lock().unwrap();
        let opened = breaker.record_failure(Instant::now());
        let open = breaker.is_open();
        // release before triggering: the flight dump's evidence sources
        // re-lock this breaker (QueuePressureSource reads replica health),
        // so firing under the guard would self-deadlock
        drop(breaker);
        if opened {
            self.replica.quarantines.fetch_add(1, Ordering::SeqCst);
            crate::log_warn!(
                "service",
                "replica {} quarantined after consecutive failures: {err:#}",
                self.replica.id
            );
            if let Some(f) = self.flight {
                f.trigger(
                    Anomaly::BreakerOpen,
                    &format!("replica {} quarantined after consecutive failures", self.replica.id),
                );
            }
        }
        self.failed.push((job, err));
        !open
    }
}

/// The per-replica serving loop.  Runs until shutdown with an empty
/// queue; a quarantined replica parks here until its probe heals it.
pub fn run_worker(setup: WorkerSetup) {
    let WorkerSetup { replica, peers, cfg, metrics, cache, obs, flight, shutdown } = setup;
    const PARK: Duration = Duration::from_millis(20);
    loop {
        // -- circuit breaker gate ------------------------------------
        let probe_wait = {
            let breaker = replica.breaker.lock().unwrap();
            breaker.time_to_probe(Instant::now())
        };
        if let Some(wait) = probe_wait {
            if shutdown.load(Ordering::SeqCst) {
                for job in replica.queue.drain() {
                    fail_now(job, "rollout service shut down", &metrics);
                }
                break;
            }
            // quarantined replicas still honor deadlines and hand their
            // queued traffic to healthy peers
            sweep_quarantined_queue(&replica, &peers, &metrics, obs.as_ref(), flight.as_ref());
            if wait > Duration::ZERO {
                std::thread::sleep(wait.min(PARK));
                continue;
            }
            metrics.probes.fetch_add(1, Ordering::SeqCst);
            match replica.engine.probe() {
                Ok(()) => {
                    replica.breaker.lock().unwrap().close();
                    crate::log_info!("service", "replica {} recovered (probe ok)", replica.id);
                }
                Err(e) => {
                    replica.breaker.lock().unwrap().reopen(Instant::now());
                    crate::log_debug!("service", "replica {} probe failed: {e:#}", replica.id);
                }
            }
            continue;
        }

        // -- admission: first request opens the batching window ------
        let Some(first) = replica.queue.pop_timeout(PARK) else {
            if shutdown.load(Ordering::SeqCst) && replica.queue.is_empty() {
                break;
            }
            continue;
        };
        let now = Instant::now();
        if first.expired(now) {
            expire_job(first, &metrics, flight.as_ref());
            continue;
        }
        note_claimed(&first, now, replica.id, &metrics, obs.as_ref());
        let key = first.batch_key();
        let class = first.args.class;
        let native = replica.engine.max_batch();
        let max_batch = if cfg.max_batch > 0 { cfg.max_batch.min(native) } else { native };
        let mut batch = vec![first];
        let admit_deadline = now + cfg.admission_window;
        while batch.len() < max_batch {
            match replica.queue.pop_matching_until(&key, class, admit_deadline) {
                Some(job) if job.expired(Instant::now()) => {
                    expire_job(job, &metrics, flight.as_ref())
                }
                Some(job) => {
                    note_claimed(&job, Instant::now(), replica.id, &metrics, obs.as_ref());
                    batch.push(job);
                }
                None => break,
            }
        }

        // -- one shared session --------------------------------------
        let claimed = batch.len();
        replica.inflight.fetch_add(claimed, Ordering::SeqCst);
        metrics.sessions.fetch_add(1, Ordering::SeqCst);
        metrics.rows.fetch_add(claimed as u64, Ordering::SeqCst);
        let mut ctl = WorkerCtl {
            replica: &replica,
            key,
            class,
            metrics: &metrics,
            cache: cache.as_ref(),
            obs: obs.as_ref(),
            flight: flight.as_ref(),
            refill_budget: 16 * max_batch.max(1),
            max_inflight: max_batch.max(1),
            failed: vec![],
        };
        let serve_result = replica.engine.serve(&mut batch, &mut ctl);
        let mut failed = std::mem::take(&mut ctl.failed);

        // claimed jobs the backend handed back are never dropped: an
        // engine-level Err burns an attempt (the session they were in
        // failed); an early Ok-abort (breaker opened on other rows'
        // failures) re-routes them without costing an attempt
        let mut stranded: Vec<RowJob> = vec![];
        match &serve_result {
            Ok(()) => {
                for job in batch.drain(..) {
                    replica.inflight.fetch_sub(1, Ordering::SeqCst);
                    stranded.push(job);
                }
            }
            Err(e) => {
                replica.failures.fetch_add(1, Ordering::SeqCst);
                let mut breaker = replica.breaker.lock().unwrap();
                let opened = breaker.record_failure(Instant::now());
                // same as WorkerCtl::fail — never trigger under the guard
                drop(breaker);
                if opened {
                    replica.quarantines.fetch_add(1, Ordering::SeqCst);
                    crate::log_warn!("service", "replica {} quarantined: {e:#}", replica.id);
                    if let Some(f) = &flight {
                        f.trigger(
                            Anomaly::BreakerOpen,
                            &format!("replica {} quarantined on engine failure", replica.id),
                        );
                    }
                }
                for job in batch.drain(..) {
                    replica.inflight.fetch_sub(1, Ordering::SeqCst);
                    failed.push((job, anyhow!("engine failure: {e:#}")));
                }
            }
        }

        // -- bounded retry with backoff ------------------------------
        // Deliberate pacing: the backoff parks THIS worker, so a
        // replica that just produced failures cools down briefly before
        // admitting its next session, while the retried jobs land on
        // peers (self only as a fallback).  Healthy traffic queued here
        // waits at most one backoff (default 10ms).
        if !failed.is_empty() {
            std::thread::sleep(cfg.retry_backoff);
        }
        for (mut job, err) in failed {
            job.attempts += 1;
            if job.attempts >= cfg.max_attempts {
                metrics.failed.fetch_add(1, Ordering::SeqCst);
                job.completer.complete(Err(err.context(format!(
                    "request failed after {} attempts",
                    job.attempts
                ))));
            } else {
                metrics.retried.fetch_add(1, Ordering::SeqCst);
                if let Some(o) = &obs {
                    o.mark(job.trace, SpanKind::Retry, replica.id as u32, job.attempts as u64);
                }
                // a fresh enqueue: queue-wait telemetry measures time
                // since the job last entered a queue, not since birth
                job.enqueued = Instant::now();
                route_job(&peers, job, Some(replica.id), &metrics, None);
            }
        }
        for mut job in stranded {
            metrics.rerouted.fetch_add(1, Ordering::SeqCst);
            if let Some(o) = &obs {
                o.mark(job.trace, SpanKind::Reroute, replica.id as u32, 0);
            }
            job.enqueued = Instant::now();
            route_job(&peers, job, Some(replica.id), &metrics, None);
        }
    }
}

/// While a replica is quarantined its worker parks in the breaker gate,
/// so its queue must be swept from there: overdue jobs expire on time,
/// the rest migrate to healthy peers — or stay queued when no peer is
/// ready (the all-quarantined fallback keeps them until a probe heals
/// someone or their deadline fires).
fn sweep_quarantined_queue(
    replica: &Arc<ReplicaState>,
    peers: &[Arc<ReplicaState>],
    metrics: &ServiceMetrics,
    obs: Option<&Arc<SpanRecorder>>,
    flight: Option<&Arc<FlightRecorder>>,
) {
    if replica.queue.is_empty() {
        return;
    }
    let peer_ready = peers.iter().any(|p| p.id != replica.id && p.ready());
    let now = Instant::now();
    for job in replica.queue.drain() {
        if job.expired(now) {
            expire_job(job, metrics, flight);
        } else if peer_ready {
            metrics.rerouted.fetch_add(1, Ordering::SeqCst);
            if let Some(o) = obs {
                o.mark(job.trace, SpanKind::Reroute, replica.id as u32, 0);
            }
            route_job(peers, job, Some(replica.id), metrics, None);
        } else if let Err(job) = replica.queue.push(job) {
            fail_now(job, "rollout service shut down", metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Promise;

    fn job(temp: f32, ttl: Duration) -> (RowJob, Promise<Result<GenOutput>>) {
        let (completer, promise) = Promise::pair();
        let now = Instant::now();
        let j = RowJob {
            prompt: vec![1, 2],
            args: SamplingArgs { temperature: temp, ..Default::default() },
            enqueued: now,
            deadline: now + ttl,
            attempts: 0,
            trace: 0,
            reused: 0,
            completer,
        };
        (j, promise)
    }

    #[test]
    fn queue_pops_fifo_and_matches_keys() {
        let q = RequestQueue::new();
        let (a, _pa) = job(1.0, Duration::from_secs(5));
        let (b, _pb) = job(0.5, Duration::from_secs(5));
        let (c, _pc) = job(1.0, Duration::from_secs(5));
        let key_hot = a.batch_key();
        q.push(a).map_err(|_| ()).unwrap();
        q.push(b).map_err(|_| ()).unwrap();
        q.push(c).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 3);
        // matching pop skips the non-matching middle job
        let train = RequestClass::TrainRollout;
        let first = q.try_pop_matching(&key_hot, train).unwrap();
        assert_eq!(first.batch_key(), key_hot);
        let second = q.try_pop_matching(&key_hot, train).unwrap();
        assert_eq!(second.batch_key(), key_hot);
        assert!(q.try_pop_matching(&key_hot, train).is_none());
        assert_eq!(q.len(), 1); // the 0.5-temperature job remains
    }

    #[test]
    fn pop_matching_waits_for_a_late_match() {
        let q = Arc::new(RequestQueue::new());
        let (probe, _p) = job(1.0, Duration::from_secs(5));
        let key = probe.batch_key();
        drop(probe);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let train = RequestClass::TrainRollout;
            q2.pop_matching_until(&key, train, Instant::now() + Duration::from_millis(500))
        });
        std::thread::sleep(Duration::from_millis(20));
        let (late, _pl) = job(1.0, Duration::from_secs(5));
        q.push(late).map_err(|_| ()).unwrap();
        assert!(h.join().unwrap().is_some());
    }

    #[test]
    fn close_hands_back_waiters_and_rejects_pushes() {
        let q = RequestQueue::new();
        let (a, _pa) = job(1.0, Duration::from_secs(5));
        q.push(a).map_err(|_| ()).unwrap();
        let left = q.close();
        assert_eq!(left.len(), 1);
        let (b, pb) = job(1.0, Duration::from_secs(5));
        assert!(q.push(b).is_err());
        drop(pb);
        assert!(q.pop_timeout(Duration::from_millis(5)).is_none());
    }

    fn classed_job(
        class: RequestClass,
        ttl: Duration,
    ) -> (RowJob, Promise<Result<GenOutput>>) {
        let (mut j, p) = job(1.0, ttl);
        j.args.class = class;
        (j, p)
    }

    #[test]
    fn qos_queue_interleaves_classes_by_weight() {
        let cfg = QosConfig { enabled: true, aging: Duration::ZERO, ..Default::default() };
        let q = RequestQueue::with_qos(&cfg);
        assert!(q.qos_enabled());
        let ttl = Duration::from_secs(5);
        let mut promises = vec![];
        for _ in 0..8 {
            let (j, p) = classed_job(RequestClass::TrainRollout, ttl);
            q.push(j).map_err(|_| ()).unwrap();
            promises.push(p);
        }
        for _ in 0..8 {
            let (j, p) = classed_job(RequestClass::Interactive, ttl);
            q.push(j).map_err(|_| ()).unwrap();
            promises.push(p);
        }
        assert_eq!(q.class_len(RequestClass::TrainRollout), 8);
        assert_eq!(q.class_len(RequestClass::Interactive), 8);
        // despite 8 train jobs enqueued first, interactive jobs appear
        // early in the dequeue order instead of waiting behind them all
        let mut first_interactive_at = None;
        for i in 0..16 {
            let j = q.pop_timeout(Duration::from_millis(50)).unwrap();
            if j.args.class == RequestClass::Interactive && first_interactive_at.is_none() {
                first_interactive_at = Some(i);
            }
        }
        let at = first_interactive_at.expect("interactive jobs dequeued");
        assert!(at < 8, "interactive head FIFO-blocked behind train backlog (index {at})");
    }

    #[test]
    fn qos_matching_pops_stay_within_the_leader_class() {
        let cfg = QosConfig { enabled: true, ..Default::default() };
        let q = RequestQueue::with_qos(&cfg);
        let ttl = Duration::from_secs(5);
        let (train, _pt) = classed_job(RequestClass::TrainRollout, ttl);
        let (eval, _pe) = classed_job(RequestClass::Eval, ttl);
        let key = train.batch_key();
        assert_eq!(eval.batch_key(), key, "same sampling key across classes");
        q.push(train).map_err(|_| ()).unwrap();
        q.push(eval).map_err(|_| ()).unwrap();
        // an eval-led session must not pull the train job as a refill
        let got = q.try_pop_matching(&key, RequestClass::Eval).unwrap();
        assert_eq!(got.args.class, RequestClass::Eval);
        assert!(q.try_pop_matching(&key, RequestClass::Eval).is_none());
        assert_eq!(q.class_len(RequestClass::TrainRollout), 1);
    }

    #[test]
    fn expired_jobs_complete_with_deadline_error() {
        let metrics = ServiceMetrics::new();
        let (j, p) = job(1.0, Duration::ZERO);
        std::thread::sleep(Duration::from_millis(2));
        assert!(j.expired(Instant::now()));
        expire_job(j, &metrics, None);
        assert_eq!(metrics.expired.load(Ordering::SeqCst), 1);
        let err = p.wait().unwrap().unwrap_err().to_string();
        assert!(err.contains("deadline exceeded"), "{err}");
    }
}
