//! The microbatcher: per-replica request queues, admission-window
//! coalescing, and the worker loop that turns independent `chat` calls
//! into shared engine sessions.
//!
//! Each replica owns one worker thread.  The worker pops the first
//! queued request, waits up to the admission window for co-travellers
//! with the same sampling parameters, then serves the batch as ONE
//! shared session — refilling freed slots from the queue mid-session
//! (continuous batching).  Deadlines are enforced at pop time, failures
//! feed the replica's circuit breaker, and a newly quarantined replica
//! drains its queue to healthy peers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cache::PrefixIndex;
use crate::exec::future::Completer;
use crate::explorer::generation::{GenOutput, SamplingArgs};
use crate::obs::{Span, SpanKind, SpanRecorder};

use super::replica::{ReplicaState, ServeCtl};
use super::telemetry::ServiceMetrics;
use super::ServiceConfig;

/// One row request: a single completion of one prompt.  `chat(n)` fans
/// out into n row jobs that may land on different replicas/sessions.
pub struct RowJob {
    pub prompt: Vec<i32>,
    pub args: SamplingArgs,
    pub enqueued: Instant,
    pub deadline: Instant,
    /// Failed attempts so far (bounded by `service.max_attempts`).
    pub attempts: usize,
    /// Episode trace id threaded from `SamplingArgs` (0 = untraced);
    /// every span this job produces carries it.
    pub trace: u64,
    /// Prefix tokens the router matched for this request (0 = cold) —
    /// how mock-path replicas tell a resume from a cold prefill.
    pub reused: u32,
    pub completer: Completer<Result<GenOutput>>,
}

impl RowJob {
    pub fn batch_key(&self) -> SampleKey {
        SampleKey::of(&self.args)
    }

    pub fn expired(&self, now: Instant) -> bool {
        now >= self.deadline
    }
}

/// Sampling parameters a shared session must agree on (per-row budgets
/// and seeds may differ; temperature/top-k/top-p may not).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleKey {
    temperature_bits: u32,
    top_k: usize,
    top_p_bits: u32,
}

impl SampleKey {
    pub fn of(args: &SamplingArgs) -> SampleKey {
        SampleKey {
            temperature_bits: args.temperature.to_bits(),
            top_k: args.top_k,
            top_p_bits: args.top_p.to_bits(),
        }
    }
}

// ---------------------------------------------------------------------------
// request queue

struct QueueState {
    jobs: VecDeque<RowJob>,
    closed: bool,
}

/// A replica's request queue (condvar-based, like `exec::channel` but
/// with key-matching pops for sampling-compatible admission).
pub struct RequestQueue {
    state: Mutex<QueueState>,
    cvar: Condvar,
}

impl Default for RequestQueue {
    fn default() -> Self {
        RequestQueue::new()
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            cvar: Condvar::new(),
        }
    }

    /// Enqueue; hands the job back if the queue is closed (shutdown).
    pub fn push(&self, job: RowJob) -> std::result::Result<(), RowJob> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(job);
        }
        st.jobs.push_back(job);
        drop(st);
        self.cvar.notify_all();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking pop of the front job (any key), bounded by `timeout`.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<RowJob> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cvar.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Non-blocking: remove the first job whose sampling key matches.
    pub fn try_pop_matching(&self, key: &SampleKey) -> Option<RowJob> {
        let mut st = self.state.lock().unwrap();
        let pos = st.jobs.iter().position(|j| j.batch_key() == *key)?;
        st.jobs.remove(pos)
    }

    /// Key-matching pop that waits until `deadline` for a match (the
    /// admission window).
    pub fn pop_matching_until(&self, key: &SampleKey, deadline: Instant) -> Option<RowJob> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(pos) = st.jobs.iter().position(|j| j.batch_key() == *key) {
                return st.jobs.remove(pos);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cvar.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Remove everything (quarantine drain / shutdown).
    pub fn drain(&self) -> Vec<RowJob> {
        let mut st = self.state.lock().unwrap();
        st.jobs.drain(..).collect()
    }

    /// Close the queue and hand back what was still waiting.
    pub fn close(&self) -> Vec<RowJob> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        let left = st.jobs.drain(..).collect();
        drop(st);
        self.cvar.notify_all();
        left
    }
}

// ---------------------------------------------------------------------------
// routing

/// Least-loaded routing over ready replicas, with an optional affinity
/// override: `preferred` (the replica holding the request's KV prefix,
/// pre-vetted by the affinity policy) wins while it is still ready.
/// When every replica is quarantined the job still lands somewhere: the
/// replica whose health probe is due soonest (requests are never
/// dropped by the router).
pub fn route_job(
    replicas: &[Arc<ReplicaState>],
    job: RowJob,
    exclude: Option<usize>,
    metrics: &ServiceMetrics,
    preferred: Option<usize>,
) {
    if let Some(p) = preferred {
        let holder = replicas.iter().find(|r| r.id == p && Some(r.id) != exclude && r.ready());
        if let Some(r) = holder {
            if let Err(job) = r.queue.push(job) {
                fail_now(job, "rollout service shut down", metrics);
            }
            return;
        }
        // the holder went unready between decision and push: fall
        // through to the normal cold path
    }
    let now = Instant::now();
    let pick = replicas
        .iter()
        .filter(|r| Some(r.id) != exclude && r.ready())
        .min_by_key(|r| (r.load(), r.id))
        .or_else(|| {
            // only the excluded replica is healthy — better it than none
            replicas.iter().filter(|r| r.ready()).min_by_key(|r| (r.load(), r.id))
        })
        .or_else(|| {
            replicas.iter().min_by_key(|r| (r.probe_eta_ms(now), r.load(), r.id))
        });
    match pick {
        Some(r) => {
            if let Err(job) = r.queue.push(job) {
                fail_now(job, "rollout service shut down", metrics);
            }
        }
        None => fail_now(job, "rollout service has no replicas", metrics),
    }
}

/// Complete a job with a terminal error.
fn fail_now(job: RowJob, why: &str, metrics: &ServiceMetrics) {
    metrics.failed.fetch_add(1, Ordering::SeqCst);
    job.completer.complete(Err(anyhow!("{why}")));
}

/// Complete a job whose deadline passed while it was queued.
pub(super) fn expire_job(job: RowJob, metrics: &ServiceMetrics) {
    metrics.expired.fetch_add(1, Ordering::SeqCst);
    let waited = job.enqueued.elapsed();
    job.completer
        .complete(Err(anyhow!("request deadline exceeded after {waited:?} in queue")));
}

/// Record one job's queued-to-claimed wait: always into the metrics
/// histogram, and as a QueueWait span on the claiming replica when
/// tracing is enabled.
fn note_claimed(
    job: &RowJob,
    now: Instant,
    replica_id: usize,
    metrics: &ServiceMetrics,
    obs: Option<&Arc<SpanRecorder>>,
) {
    let wait = now.saturating_duration_since(job.enqueued);
    metrics.note_queue_wait(wait);
    if let Some(o) = obs {
        o.record(Span {
            trace: job.trace,
            kind: SpanKind::QueueWait,
            replica: replica_id as u32,
            start_us: o.rel_us(job.enqueued),
            dur_us: wait.as_micros() as u64,
            detail: job.attempts as u64,
        });
    }
}

// ---------------------------------------------------------------------------
// the worker

pub struct WorkerSetup {
    pub replica: Arc<ReplicaState>,
    /// All replicas (for retry re-routing and quarantine drains).
    pub peers: Vec<Arc<ReplicaState>>,
    pub cfg: ServiceConfig,
    pub metrics: Arc<ServiceMetrics>,
    /// The service-wide prefix index, when the cache is enabled:
    /// completed session-tagged rows are admitted as reusable prefixes.
    pub cache: Option<Arc<PrefixIndex>>,
    /// Span recorder, when observability is enabled.
    pub obs: Option<Arc<SpanRecorder>>,
    pub shutdown: Arc<AtomicBool>,
}

/// Session-scoped [`ServeCtl`]: claims refills from the replica's queue,
/// completes finished rows, and collects failures for the post-session
/// retry pass.
struct WorkerCtl<'a> {
    replica: &'a ReplicaState,
    key: SampleKey,
    metrics: &'a ServiceMetrics,
    cache: Option<&'a Arc<PrefixIndex>>,
    obs: Option<&'a Arc<SpanRecorder>>,
    /// Refills left before the session must end.  Bounds session
    /// lifetime so a steady stream of same-key traffic cannot starve a
    /// queued request with a different sampling key (which can only be
    /// popped — and deadline-checked — between sessions).
    refill_budget: usize,
    /// Concurrent-row cap (`service.max_batch`): idle-slot filling must
    /// not grow a session past the configured occupancy.
    max_inflight: usize,
    failed: Vec<(RowJob, anyhow::Error)>,
}

impl ServeCtl for WorkerCtl<'_> {
    fn refill(&mut self) -> Option<RowJob> {
        loop {
            if self.refill_budget == 0
                || self.replica.inflight.load(Ordering::SeqCst) >= self.max_inflight
            {
                return None;
            }
            let job = self.replica.queue.try_pop_matching(&self.key)?;
            let now = Instant::now();
            if job.expired(now) {
                expire_job(job, self.metrics);
                continue;
            }
            note_claimed(&job, now, self.replica.id, self.metrics, self.obs);
            self.metrics.rows.fetch_add(1, Ordering::SeqCst);
            self.metrics.refills.fetch_add(1, Ordering::SeqCst);
            self.replica.inflight.fetch_add(1, Ordering::SeqCst);
            self.refill_budget -= 1;
            return Some(job);
        }
    }

    fn done(&mut self, job: RowJob, out: GenOutput) {
        self.replica.inflight.fetch_sub(1, Ordering::SeqCst);
        self.replica.rows_served.fetch_add(1, Ordering::SeqCst);
        self.replica.breaker.lock().unwrap().record_success();
        self.metrics.completed.fetch_add(1, Ordering::SeqCst);
        // a session-tagged transcript is a reusable prefix for the
        // episode's next turn: index it under this replica and the
        // exact weight version that served it
        if job.args.session.is_some() {
            if let Some(cache) = self.cache {
                cache.admit(&out.tokens, self.replica.id, out.version);
            }
        }
        job.completer.complete(Ok(out));
    }

    fn fail(&mut self, job: RowJob, err: anyhow::Error) -> bool {
        self.replica.inflight.fetch_sub(1, Ordering::SeqCst);
        self.replica.failures.fetch_add(1, Ordering::SeqCst);
        let mut breaker = self.replica.breaker.lock().unwrap();
        if breaker.record_failure(Instant::now()) {
            self.replica.quarantines.fetch_add(1, Ordering::SeqCst);
            crate::log_warn!(
                "service",
                "replica {} quarantined after consecutive failures: {err:#}",
                self.replica.id
            );
        }
        let open = breaker.is_open();
        drop(breaker);
        self.failed.push((job, err));
        !open
    }
}

/// The per-replica serving loop.  Runs until shutdown with an empty
/// queue; a quarantined replica parks here until its probe heals it.
pub fn run_worker(setup: WorkerSetup) {
    let WorkerSetup { replica, peers, cfg, metrics, cache, obs, shutdown } = setup;
    const PARK: Duration = Duration::from_millis(20);
    loop {
        // -- circuit breaker gate ------------------------------------
        let probe_wait = {
            let breaker = replica.breaker.lock().unwrap();
            breaker.time_to_probe(Instant::now())
        };
        if let Some(wait) = probe_wait {
            if shutdown.load(Ordering::SeqCst) {
                for job in replica.queue.drain() {
                    fail_now(job, "rollout service shut down", &metrics);
                }
                break;
            }
            // quarantined replicas still honor deadlines and hand their
            // queued traffic to healthy peers
            sweep_quarantined_queue(&replica, &peers, &metrics, obs.as_ref());
            if wait > Duration::ZERO {
                std::thread::sleep(wait.min(PARK));
                continue;
            }
            metrics.probes.fetch_add(1, Ordering::SeqCst);
            match replica.engine.probe() {
                Ok(()) => {
                    replica.breaker.lock().unwrap().close();
                    crate::log_info!("service", "replica {} recovered (probe ok)", replica.id);
                }
                Err(e) => {
                    replica.breaker.lock().unwrap().reopen(Instant::now());
                    crate::log_debug!("service", "replica {} probe failed: {e:#}", replica.id);
                }
            }
            continue;
        }

        // -- admission: first request opens the batching window ------
        let Some(first) = replica.queue.pop_timeout(PARK) else {
            if shutdown.load(Ordering::SeqCst) && replica.queue.is_empty() {
                break;
            }
            continue;
        };
        let now = Instant::now();
        if first.expired(now) {
            expire_job(first, &metrics);
            continue;
        }
        note_claimed(&first, now, replica.id, &metrics, obs.as_ref());
        let key = first.batch_key();
        let native = replica.engine.max_batch();
        let max_batch = if cfg.max_batch > 0 { cfg.max_batch.min(native) } else { native };
        let mut batch = vec![first];
        let admit_deadline = now + cfg.admission_window;
        while batch.len() < max_batch {
            match replica.queue.pop_matching_until(&key, admit_deadline) {
                Some(job) if job.expired(Instant::now()) => expire_job(job, &metrics),
                Some(job) => {
                    note_claimed(&job, Instant::now(), replica.id, &metrics, obs.as_ref());
                    batch.push(job);
                }
                None => break,
            }
        }

        // -- one shared session --------------------------------------
        let claimed = batch.len();
        replica.inflight.fetch_add(claimed, Ordering::SeqCst);
        metrics.sessions.fetch_add(1, Ordering::SeqCst);
        metrics.rows.fetch_add(claimed as u64, Ordering::SeqCst);
        let mut ctl = WorkerCtl {
            replica: &replica,
            key,
            metrics: &metrics,
            cache: cache.as_ref(),
            obs: obs.as_ref(),
            refill_budget: 16 * max_batch.max(1),
            max_inflight: max_batch.max(1),
            failed: vec![],
        };
        let serve_result = replica.engine.serve(&mut batch, &mut ctl);
        let mut failed = std::mem::take(&mut ctl.failed);

        // claimed jobs the backend handed back are never dropped: an
        // engine-level Err burns an attempt (the session they were in
        // failed); an early Ok-abort (breaker opened on other rows'
        // failures) re-routes them without costing an attempt
        let mut stranded: Vec<RowJob> = vec![];
        match &serve_result {
            Ok(()) => {
                for job in batch.drain(..) {
                    replica.inflight.fetch_sub(1, Ordering::SeqCst);
                    stranded.push(job);
                }
            }
            Err(e) => {
                replica.failures.fetch_add(1, Ordering::SeqCst);
                let mut breaker = replica.breaker.lock().unwrap();
                if breaker.record_failure(Instant::now()) {
                    replica.quarantines.fetch_add(1, Ordering::SeqCst);
                    crate::log_warn!("service", "replica {} quarantined: {e:#}", replica.id);
                }
                drop(breaker);
                for job in batch.drain(..) {
                    replica.inflight.fetch_sub(1, Ordering::SeqCst);
                    failed.push((job, anyhow!("engine failure: {e:#}")));
                }
            }
        }

        // -- bounded retry with backoff ------------------------------
        // Deliberate pacing: the backoff parks THIS worker, so a
        // replica that just produced failures cools down briefly before
        // admitting its next session, while the retried jobs land on
        // peers (self only as a fallback).  Healthy traffic queued here
        // waits at most one backoff (default 10ms).
        if !failed.is_empty() {
            std::thread::sleep(cfg.retry_backoff);
        }
        for (mut job, err) in failed {
            job.attempts += 1;
            if job.attempts >= cfg.max_attempts {
                metrics.failed.fetch_add(1, Ordering::SeqCst);
                job.completer.complete(Err(err.context(format!(
                    "request failed after {} attempts",
                    job.attempts
                ))));
            } else {
                metrics.retried.fetch_add(1, Ordering::SeqCst);
                if let Some(o) = &obs {
                    o.mark(job.trace, SpanKind::Retry, replica.id as u32, job.attempts as u64);
                }
                // a fresh enqueue: queue-wait telemetry measures time
                // since the job last entered a queue, not since birth
                job.enqueued = Instant::now();
                route_job(&peers, job, Some(replica.id), &metrics, None);
            }
        }
        for mut job in stranded {
            metrics.rerouted.fetch_add(1, Ordering::SeqCst);
            if let Some(o) = &obs {
                o.mark(job.trace, SpanKind::Reroute, replica.id as u32, 0);
            }
            job.enqueued = Instant::now();
            route_job(&peers, job, Some(replica.id), &metrics, None);
        }
    }
}

/// While a replica is quarantined its worker parks in the breaker gate,
/// so its queue must be swept from there: overdue jobs expire on time,
/// the rest migrate to healthy peers — or stay queued when no peer is
/// ready (the all-quarantined fallback keeps them until a probe heals
/// someone or their deadline fires).
fn sweep_quarantined_queue(
    replica: &Arc<ReplicaState>,
    peers: &[Arc<ReplicaState>],
    metrics: &ServiceMetrics,
    obs: Option<&Arc<SpanRecorder>>,
) {
    if replica.queue.is_empty() {
        return;
    }
    let peer_ready = peers.iter().any(|p| p.id != replica.id && p.ready());
    let now = Instant::now();
    for job in replica.queue.drain() {
        if job.expired(now) {
            expire_job(job, metrics);
        } else if peer_ready {
            metrics.rerouted.fetch_add(1, Ordering::SeqCst);
            if let Some(o) = obs {
                o.mark(job.trace, SpanKind::Reroute, replica.id as u32, 0);
            }
            route_job(peers, job, Some(replica.id), metrics, None);
        } else if let Err(job) = replica.queue.push(job) {
            fail_now(job, "rollout service shut down", metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Promise;

    fn job(temp: f32, ttl: Duration) -> (RowJob, Promise<Result<GenOutput>>) {
        let (completer, promise) = Promise::pair();
        let now = Instant::now();
        let j = RowJob {
            prompt: vec![1, 2],
            args: SamplingArgs { temperature: temp, ..Default::default() },
            enqueued: now,
            deadline: now + ttl,
            attempts: 0,
            trace: 0,
            reused: 0,
            completer,
        };
        (j, promise)
    }

    #[test]
    fn queue_pops_fifo_and_matches_keys() {
        let q = RequestQueue::new();
        let (a, _pa) = job(1.0, Duration::from_secs(5));
        let (b, _pb) = job(0.5, Duration::from_secs(5));
        let (c, _pc) = job(1.0, Duration::from_secs(5));
        let key_hot = a.batch_key();
        q.push(a).map_err(|_| ()).unwrap();
        q.push(b).map_err(|_| ()).unwrap();
        q.push(c).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 3);
        // matching pop skips the non-matching middle job
        let first = q.try_pop_matching(&key_hot).unwrap();
        assert_eq!(first.batch_key(), key_hot);
        let second = q.try_pop_matching(&key_hot).unwrap();
        assert_eq!(second.batch_key(), key_hot);
        assert!(q.try_pop_matching(&key_hot).is_none());
        assert_eq!(q.len(), 1); // the 0.5-temperature job remains
    }

    #[test]
    fn pop_matching_waits_for_a_late_match() {
        let q = Arc::new(RequestQueue::new());
        let (probe, _p) = job(1.0, Duration::from_secs(5));
        let key = probe.batch_key();
        drop(probe);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            q2.pop_matching_until(&key, Instant::now() + Duration::from_millis(500))
        });
        std::thread::sleep(Duration::from_millis(20));
        let (late, _pl) = job(1.0, Duration::from_secs(5));
        q.push(late).map_err(|_| ()).unwrap();
        assert!(h.join().unwrap().is_some());
    }

    #[test]
    fn close_hands_back_waiters_and_rejects_pushes() {
        let q = RequestQueue::new();
        let (a, _pa) = job(1.0, Duration::from_secs(5));
        q.push(a).map_err(|_| ()).unwrap();
        let left = q.close();
        assert_eq!(left.len(), 1);
        let (b, pb) = job(1.0, Duration::from_secs(5));
        assert!(q.push(b).is_err());
        drop(pb);
        assert!(q.pop_timeout(Duration::from_millis(5)).is_none());
    }

    #[test]
    fn expired_jobs_complete_with_deadline_error() {
        let metrics = ServiceMetrics::new();
        let (j, p) = job(1.0, Duration::ZERO);
        std::thread::sleep(Duration::from_millis(2));
        assert!(j.expired(Instant::now()));
        expire_job(j, &metrics);
        assert_eq!(metrics.expired.load(Ordering::SeqCst), 1);
        let err = p.wait().unwrap().unwrap_err().to_string();
        assert!(err.contains("deadline exceeded"), "{err}");
    }
}
