//! Service telemetry: lock-free counters and latency histograms updated
//! on the serving path, snapshotted into the coordinator's `Monitor` at
//! publish boundaries and attached to the final `ModeReport`.
//!
//! Latencies are full [`Histogram`]s (DESIGN.md §8), not means: queue
//! wait, end-to-end rollout latency, and per-turn prefill each report
//! p50/p95/p99, and snapshots merge across runs by addition.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::obs::{HistSnapshot, Histogram};

/// Fleet-wide counters (per-replica counters live on `ReplicaState`).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Row requests accepted by `chat` (a `chat(n)` submits n rows).
    pub submitted: AtomicU64,
    /// Rows completed successfully.
    pub completed: AtomicU64,
    /// Rows that exhausted their retry budget.
    pub failed: AtomicU64,
    /// Rows dropped at pop time because their deadline had passed.
    pub expired: AtomicU64,
    /// Failed attempts that were re-queued for another try.
    pub retried: AtomicU64,
    /// Rows migrated off a quarantined replica without burning an
    /// attempt (queued sweeps + session-abort strands).
    pub rerouted: AtomicU64,
    /// Shared engine sessions (the "engine calls" coalescing divides).
    pub sessions: AtomicU64,
    /// Rows claimed into sessions, including mid-session refills.
    pub rows: AtomicU64,
    /// Rows that entered a session through a continuous-batching refill.
    pub refills: AtomicU64,
    /// Health probes sent to quarantined replicas.
    pub probes: AtomicU64,
    /// Queued-to-claimed latency per row.
    pub queue_wait: Histogram,
    /// Submit-to-complete latency per `chat` call (all rows settled).
    pub rollout: Histogram,
    /// Cold per-turn prefill latency (engine replicas; resumes skip it).
    pub prefill: Histogram,
}

impl ServiceMetrics {
    pub fn new() -> ServiceMetrics {
        ServiceMetrics::default()
    }

    /// Record how long a row sat queued before being claimed.
    pub fn note_queue_wait(&self, wait: Duration) {
        self.queue_wait.observe_duration(wait);
    }

    /// Record one `chat` call's end-to-end latency.
    pub fn note_rollout(&self, elapsed: Duration) {
        self.rollout.observe_duration(elapsed);
    }

    /// Record one cold prefill.
    pub fn note_prefill(&self, elapsed: Duration) {
        self.prefill.observe_duration(elapsed);
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        self.queue_wait.snapshot().mean()
    }
}

/// Point-in-time view of one replica.
#[derive(Debug, Clone, Default)]
pub struct ReplicaSnapshot {
    pub id: usize,
    /// Rows this replica completed.
    pub rows: u64,
    pub failures: u64,
    /// Times the circuit breaker opened on this replica.
    pub quarantines: u64,
    /// Currently quarantined?
    pub quarantined: bool,
    pub weight_version: u64,
    pub queued: usize,
    pub inflight: usize,
    /// Parked KV sessions held for episode resumes.
    pub parked: usize,
}

/// Point-in-time view of the whole service (attached to `ModeReport`).
#[derive(Debug, Clone, Default)]
pub struct ServiceSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub expired: u64,
    pub retried: u64,
    pub rerouted: u64,
    pub sessions: u64,
    pub rows: u64,
    pub refills: u64,
    pub probes: u64,
    pub mean_queue_wait_s: f64,
    /// Queue-wait latency distribution (p50/p95/p99 via `percentile`).
    pub queue_wait: HistSnapshot,
    /// End-to-end rollout latency distribution per `chat` call.
    pub rollout: HistSnapshot,
    /// Cold per-turn prefill latency distribution (engine replicas).
    pub prefill: HistSnapshot,
    pub queued: usize,
    pub inflight: usize,
    pub replicas: Vec<ReplicaSnapshot>,
    /// Prefix-reuse cache telemetry (present when the cache is enabled).
    pub cache: Option<crate::cache::CacheSnapshot>,
}

impl ServiceSnapshot {
    /// Mean rows per shared engine session — the microbatcher's
    /// coalescing factor (> 1 means requests actually shared sessions).
    pub fn occupancy(&self) -> f64 {
        if self.sessions == 0 {
            0.0
        } else {
            self.rows as f64 / self.sessions as f64
        }
    }

    pub fn quarantined(&self) -> usize {
        self.replicas.iter().filter(|r| r.quarantined).count()
    }

    /// Replicas currently serving (the pool minus quarantined) — the
    /// live-capacity denominator the control plane steers against.
    pub fn healthy(&self) -> usize {
        self.replicas.len() - self.quarantined()
    }

    /// Uniform monitor field set (role "service").
    pub fn monitor_fields(&self) -> Vec<(String, f64)> {
        let mut fields = vec![
            ("occupancy".to_string(), self.occupancy()),
            ("queue_wait_s".to_string(), self.mean_queue_wait_s),
            ("queued".to_string(), self.queued as f64),
            ("inflight".to_string(), self.inflight as f64),
            ("sessions".to_string(), self.sessions as f64),
            ("completed".to_string(), self.completed as f64),
            ("failed".to_string(), self.failed as f64),
            ("expired".to_string(), self.expired as f64),
            ("retried".to_string(), self.retried as f64),
            ("quarantined".to_string(), self.quarantined() as f64),
        ];
        for (name, hist) in
            [("queue_wait", &self.queue_wait), ("rollout", &self.rollout), ("prefill", &self.prefill)]
        {
            let (p50, p95, p99) = hist.p50_p95_p99();
            fields.push((format!("{name}_p50_s"), p50));
            fields.push((format!("{name}_p95_s"), p95));
            fields.push((format!("{name}_p99_s"), p99));
        }
        for r in &self.replicas {
            fields.push((format!("replica{}_rows", r.id), r.rows as f64));
            fields.push((format!("replica{}_version", r.id), r.weight_version as f64));
        }
        if let Some(cache) = &self.cache {
            fields.extend(cache.monitor_fields());
        }
        fields
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_is_rows_per_session() {
        let mut s = ServiceSnapshot::default();
        assert_eq!(s.occupancy(), 0.0);
        s.sessions = 4;
        s.rows = 10;
        assert!((s.occupancy() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn healthy_counts_the_pool_minus_quarantined() {
        let mut s = ServiceSnapshot::default();
        assert_eq!(s.healthy(), 0);
        s.replicas = vec![
            ReplicaSnapshot { id: 0, ..Default::default() },
            ReplicaSnapshot { id: 1, quarantined: true, ..Default::default() },
            ReplicaSnapshot { id: 2, ..Default::default() },
        ];
        assert_eq!(s.quarantined(), 1);
        assert_eq!(s.healthy(), 2);
    }

    #[test]
    fn queue_wait_histogram_mean_and_percentiles() {
        let m = ServiceMetrics::new();
        assert_eq!(m.mean_queue_wait_s(), 0.0);
        m.note_queue_wait(Duration::from_millis(10));
        m.note_queue_wait(Duration::from_millis(30));
        // the histogram mean tracks the exact mean to within rounding
        assert!((m.mean_queue_wait_s() - 0.020).abs() < 1e-4, "{}", m.mean_queue_wait_s());
        let snap = m.queue_wait.snapshot();
        assert_eq!(snap.count, 2);
        assert!(snap.percentile(0.95) >= snap.percentile(0.50));
    }

    #[test]
    fn rollout_and_prefill_histograms_record() {
        let m = ServiceMetrics::new();
        m.note_rollout(Duration::from_millis(50));
        m.note_prefill(Duration::from_millis(5));
        assert_eq!(m.rollout.snapshot().count, 1);
        assert_eq!(m.prefill.snapshot().count, 1);
        assert!(m.rollout.snapshot().percentile(0.5) > 0.01);
    }

    #[test]
    fn monitor_fields_cover_replicas_and_percentiles() {
        let m = ServiceMetrics::new();
        m.note_queue_wait(Duration::from_millis(10));
        m.note_rollout(Duration::from_millis(80));
        let snap = ServiceSnapshot {
            queue_wait: m.queue_wait.snapshot(),
            rollout: m.rollout.snapshot(),
            replicas: vec![ReplicaSnapshot { id: 0, ..Default::default() }, ReplicaSnapshot { id: 1, ..Default::default() }],
            ..Default::default()
        };
        let fields = snap.monitor_fields();
        assert!(fields.iter().any(|(n, _)| n == "occupancy"));
        assert!(fields.iter().any(|(n, _)| n == "replica1_rows"));
        for key in ["queue_wait_p50_s", "queue_wait_p95_s", "queue_wait_p99_s", "rollout_p95_s", "prefill_p99_s"]
        {
            assert!(fields.iter().any(|(n, _)| n == key), "missing {key}");
        }
        let p95 = fields.iter().find(|(n, _)| n == "rollout_p95_s").unwrap().1;
        assert!(p95 > 0.01, "{p95}");
    }
}
