//! Service telemetry: lock-free counters and latency histograms updated
//! on the serving path, snapshotted into the coordinator's `Monitor` at
//! publish boundaries and attached to the final `ModeReport`.
//!
//! Latencies are full [`Histogram`]s (DESIGN.md §8), not means: queue
//! wait, end-to-end rollout latency, and per-turn prefill each report
//! p50/p95/p99, and snapshots merge across runs by addition.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::obs::{HistSnapshot, Histogram};
use crate::qos::{RequestClass, CLASS_COUNT};

/// Fleet-wide counters (per-replica counters live on `ReplicaState`).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Row requests accepted by `chat` (a `chat(n)` submits n rows).
    pub submitted: AtomicU64,
    /// Rows completed successfully.
    pub completed: AtomicU64,
    /// Rows that exhausted their retry budget.
    pub failed: AtomicU64,
    /// Rows dropped at pop time because their deadline had passed.
    pub expired: AtomicU64,
    /// Failed attempts that were re-queued for another try.
    pub retried: AtomicU64,
    /// Rows migrated off a quarantined replica without burning an
    /// attempt (queued sweeps + session-abort strands).
    pub rerouted: AtomicU64,
    /// Shared engine sessions (the "engine calls" coalescing divides).
    pub sessions: AtomicU64,
    /// Rows claimed into sessions, including mid-session refills.
    pub rows: AtomicU64,
    /// Rows that entered a session through a continuous-batching refill.
    pub refills: AtomicU64,
    /// Health probes sent to quarantined replicas.
    pub probes: AtomicU64,
    /// Queued-to-claimed latency per row.
    pub queue_wait: Histogram,
    /// Submit-to-complete latency per `chat` call (all rows settled).
    pub rollout: Histogram,
    /// Cold per-turn prefill latency (engine replicas; resumes skip it).
    pub prefill: Histogram,
    /// Prompt tokens submitted (pending-prefill estimation: divided by
    /// `submitted` it yields the fleet mean prompt length that
    /// `route_job`'s cost-aware tie-break multiplies by queue depth).
    pub prompt_tokens: AtomicU64,
    /// Per-class row counts, indexed by `RequestClass::index()`.
    pub class_submitted: [AtomicU64; CLASS_COUNT],
    pub class_completed: [AtomicU64; CLASS_COUNT],
    pub class_expired: [AtomicU64; CLASS_COUNT],
    /// Per-class queued-to-claimed latency.
    pub class_queue_wait: [Histogram; CLASS_COUNT],
    /// Per-class end-to-end rollout latency.
    pub class_rollout: [Histogram; CLASS_COUNT],
}

impl ServiceMetrics {
    pub fn new() -> ServiceMetrics {
        ServiceMetrics::default()
    }

    /// Record how long a row sat queued before being claimed, tagged
    /// with its class (the fleet histogram and the per-class one).
    pub fn note_queue_wait(&self, wait: Duration, class: RequestClass) {
        self.queue_wait.observe_duration(wait);
        self.class_queue_wait[class.index()].observe_duration(wait);
    }

    /// Record one `chat` call's end-to-end latency.
    pub fn note_rollout(&self, elapsed: Duration, class: RequestClass) {
        self.rollout.observe_duration(elapsed);
        self.class_rollout[class.index()].observe_duration(elapsed);
    }

    /// Record one cold prefill.
    pub fn note_prefill(&self, elapsed: Duration) {
        self.prefill.observe_duration(elapsed);
    }

    /// Account rows accepted by `chat`: count, class, prompt tokens.
    pub fn note_submitted(&self, rows: u64, prompt_tokens: u64, class: RequestClass) {
        self.submitted.fetch_add(rows, Ordering::Relaxed);
        self.prompt_tokens.fetch_add(prompt_tokens * rows, Ordering::Relaxed);
        self.class_submitted[class.index()].fetch_add(rows, Ordering::Relaxed);
    }

    pub fn note_completed(&self, class: RequestClass) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.class_completed[class.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_expired(&self, class: RequestClass) {
        self.expired.fetch_add(1, Ordering::Relaxed);
        self.class_expired[class.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        self.queue_wait.snapshot().mean()
    }

    /// Fleet mean prompt length in tokens (0 before the first submit) —
    /// the per-queued-row prefill estimate for cost-aware routing.
    pub fn mean_prompt_tokens(&self) -> u64 {
        let submitted = self.submitted.load(Ordering::Relaxed);
        if submitted == 0 {
            0
        } else {
            self.prompt_tokens.load(Ordering::Relaxed) / submitted
        }
    }
}

/// Point-in-time view of one replica.
#[derive(Debug, Clone, Default)]
pub struct ReplicaSnapshot {
    pub id: usize,
    /// Rows this replica completed.
    pub rows: u64,
    pub failures: u64,
    /// Times the circuit breaker opened on this replica.
    pub quarantines: u64,
    /// Currently quarantined?
    pub quarantined: bool,
    pub weight_version: u64,
    pub queued: usize,
    pub inflight: usize,
    /// Parked KV sessions held for episode resumes.
    pub parked: usize,
}

/// Point-in-time view of the whole service (attached to `ModeReport`).
#[derive(Debug, Clone, Default)]
pub struct ServiceSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub expired: u64,
    pub retried: u64,
    pub rerouted: u64,
    pub sessions: u64,
    pub rows: u64,
    pub refills: u64,
    pub probes: u64,
    pub mean_queue_wait_s: f64,
    /// Queue-wait latency distribution (p50/p95/p99 via `percentile`).
    pub queue_wait: HistSnapshot,
    /// End-to-end rollout latency distribution per `chat` call.
    pub rollout: HistSnapshot,
    /// Cold per-turn prefill latency distribution (engine replicas).
    pub prefill: HistSnapshot,
    pub queued: usize,
    pub inflight: usize,
    /// Per-class row counts, indexed by `RequestClass::index()`.
    pub class_submitted: [u64; CLASS_COUNT],
    pub class_completed: [u64; CLASS_COUNT],
    pub class_expired: [u64; CLASS_COUNT],
    /// Per-class queue-wait latency distributions.
    pub class_queue_wait: [HistSnapshot; CLASS_COUNT],
    /// Per-class end-to-end rollout latency distributions.
    pub class_rollout: [HistSnapshot; CLASS_COUNT],
    pub replicas: Vec<ReplicaSnapshot>,
    /// Prefix-reuse cache telemetry (present when the cache is enabled).
    pub cache: Option<crate::cache::CacheSnapshot>,
}

impl ServiceSnapshot {
    /// Mean rows per shared engine session — the microbatcher's
    /// coalescing factor (> 1 means requests actually shared sessions).
    pub fn occupancy(&self) -> f64 {
        if self.sessions == 0 {
            0.0
        } else {
            self.rows as f64 / self.sessions as f64
        }
    }

    pub fn quarantined(&self) -> usize {
        self.replicas.iter().filter(|r| r.quarantined).count()
    }

    /// Replicas currently serving (the pool minus quarantined) — the
    /// live-capacity denominator the control plane steers against.
    pub fn healthy(&self) -> usize {
        self.replicas.len() - self.quarantined()
    }

    /// Uniform monitor field set (role "service").
    pub fn monitor_fields(&self) -> Vec<(String, f64)> {
        let mut fields = vec![
            ("occupancy".to_string(), self.occupancy()),
            ("queue_wait_s".to_string(), self.mean_queue_wait_s),
            ("queued".to_string(), self.queued as f64),
            ("inflight".to_string(), self.inflight as f64),
            ("sessions".to_string(), self.sessions as f64),
            ("completed".to_string(), self.completed as f64),
            ("failed".to_string(), self.failed as f64),
            ("expired".to_string(), self.expired as f64),
            ("retried".to_string(), self.retried as f64),
            ("quarantined".to_string(), self.quarantined() as f64),
        ];
        for (name, hist) in
            [("queue_wait", &self.queue_wait), ("rollout", &self.rollout), ("prefill", &self.prefill)]
        {
            let (p50, p95, p99) = hist.p50_p95_p99();
            fields.push((format!("{name}_p50_s"), p50));
            fields.push((format!("{name}_p95_s"), p95));
            fields.push((format!("{name}_p99_s"), p99));
        }
        for class in RequestClass::ALL {
            let i = class.index();
            // only emit class rows that saw traffic, so class-unaware
            // runs keep their exact historical field set
            if self.class_submitted[i] == 0 {
                continue;
            }
            let name = class.as_str();
            fields.push((format!("class_{name}_submitted"), self.class_submitted[i] as f64));
            fields.push((format!("class_{name}_completed"), self.class_completed[i] as f64));
            fields.push((format!("class_{name}_expired"), self.class_expired[i] as f64));
            let (_, wait_p95, _) = self.class_queue_wait[i].p50_p95_p99();
            let (_, roll_p95, _) = self.class_rollout[i].p50_p95_p99();
            fields.push((format!("class_{name}_queue_wait_p95_s"), wait_p95));
            fields.push((format!("class_{name}_rollout_p95_s"), roll_p95));
        }
        for r in &self.replicas {
            fields.push((format!("replica{}_rows", r.id), r.rows as f64));
            fields.push((format!("replica{}_version", r.id), r.weight_version as f64));
        }
        if let Some(cache) = &self.cache {
            fields.extend(cache.monitor_fields());
        }
        fields
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_is_rows_per_session() {
        let mut s = ServiceSnapshot::default();
        assert_eq!(s.occupancy(), 0.0);
        s.sessions = 4;
        s.rows = 10;
        assert!((s.occupancy() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn healthy_counts_the_pool_minus_quarantined() {
        let mut s = ServiceSnapshot::default();
        assert_eq!(s.healthy(), 0);
        s.replicas = vec![
            ReplicaSnapshot { id: 0, ..Default::default() },
            ReplicaSnapshot { id: 1, quarantined: true, ..Default::default() },
            ReplicaSnapshot { id: 2, ..Default::default() },
        ];
        assert_eq!(s.quarantined(), 1);
        assert_eq!(s.healthy(), 2);
    }

    #[test]
    fn queue_wait_histogram_mean_and_percentiles() {
        let m = ServiceMetrics::new();
        assert_eq!(m.mean_queue_wait_s(), 0.0);
        m.note_queue_wait(Duration::from_millis(10), RequestClass::TrainRollout);
        m.note_queue_wait(Duration::from_millis(30), RequestClass::TrainRollout);
        // the histogram mean tracks the exact mean to within rounding
        assert!((m.mean_queue_wait_s() - 0.020).abs() < 1e-4, "{}", m.mean_queue_wait_s());
        let snap = m.queue_wait.snapshot();
        assert_eq!(snap.count, 2);
        assert!(snap.percentile(0.95) >= snap.percentile(0.50));
    }

    #[test]
    fn rollout_and_prefill_histograms_record() {
        let m = ServiceMetrics::new();
        m.note_rollout(Duration::from_millis(50), RequestClass::TrainRollout);
        m.note_prefill(Duration::from_millis(5));
        assert_eq!(m.rollout.snapshot().count, 1);
        assert_eq!(m.prefill.snapshot().count, 1);
        assert!(m.rollout.snapshot().percentile(0.5) > 0.01);
    }

    #[test]
    fn class_tagged_metrics_split_per_class() {
        let m = ServiceMetrics::new();
        m.note_submitted(2, 8, RequestClass::Interactive);
        m.note_submitted(4, 16, RequestClass::TrainRollout);
        m.note_queue_wait(Duration::from_millis(5), RequestClass::Interactive);
        m.note_queue_wait(Duration::from_millis(40), RequestClass::TrainRollout);
        m.note_rollout(Duration::from_millis(20), RequestClass::Interactive);
        m.note_completed(RequestClass::Interactive);
        m.note_expired(RequestClass::TrainRollout);
        let i = RequestClass::Interactive.index();
        let t = RequestClass::TrainRollout.index();
        assert_eq!(m.class_submitted[i].load(Ordering::Relaxed), 2);
        assert_eq!(m.class_submitted[t].load(Ordering::Relaxed), 4);
        assert_eq!(m.class_completed[i].load(Ordering::Relaxed), 1);
        assert_eq!(m.class_expired[t].load(Ordering::Relaxed), 1);
        assert_eq!(m.class_queue_wait[i].snapshot().count, 1);
        assert_eq!(m.class_rollout[i].snapshot().count, 1);
        // fleet aggregates still see everything
        assert_eq!(m.submitted.load(Ordering::Relaxed), 6);
        assert_eq!(m.queue_wait.snapshot().count, 2);
        // mean prompt: (2*8 + 4*16) / 6 = 13
        assert_eq!(m.mean_prompt_tokens(), 13);
        // snapshot fields surface only classes that saw traffic
        let snap = ServiceSnapshot {
            class_submitted: [4, 0, 2],
            class_queue_wait: [
                m.class_queue_wait[t].snapshot(),
                HistSnapshot::default(),
                m.class_queue_wait[i].snapshot(),
            ],
            ..Default::default()
        };
        let fields = snap.monitor_fields();
        assert!(fields.iter().any(|(n, _)| n == "class_interactive_queue_wait_p95_s"));
        assert!(fields.iter().any(|(n, _)| n == "class_train_submitted"));
        assert!(!fields.iter().any(|(n, _)| n.starts_with("class_eval")), "no eval traffic");
    }

    #[test]
    fn monitor_fields_cover_replicas_and_percentiles() {
        let m = ServiceMetrics::new();
        m.note_queue_wait(Duration::from_millis(10), RequestClass::TrainRollout);
        m.note_rollout(Duration::from_millis(80), RequestClass::TrainRollout);
        let snap = ServiceSnapshot {
            queue_wait: m.queue_wait.snapshot(),
            rollout: m.rollout.snapshot(),
            replicas: vec![ReplicaSnapshot { id: 0, ..Default::default() }, ReplicaSnapshot { id: 1, ..Default::default() }],
            ..Default::default()
        };
        let fields = snap.monitor_fields();
        assert!(fields.iter().any(|(n, _)| n == "occupancy"));
        assert!(fields.iter().any(|(n, _)| n == "replica1_rows"));
        for key in ["queue_wait_p50_s", "queue_wait_p95_s", "queue_wait_p99_s", "rollout_p95_s", "prefill_p99_s"]
        {
            assert!(fields.iter().any(|(n, _)| n == key), "missing {key}");
        }
        let p95 = fields.iter().find(|(n, _)| n == "rollout_p95_s").unwrap().1;
        assert!(p95 > 0.01, "{p95}");
    }
}
