//! The rollout service (paper §2.2): the in-process model-serving tier
//! between workflow runners and the generation engine.  Runners no
//! longer hold an engine — they hold a [`ServiceHandle`] and call it
//! like clients of a vLLM deployment, which buys three properties the
//! direct-handle wiring could not express:
//!
//! * **Microbatching** ([`batcher`]): concurrent `chat` requests are
//!   coalesced under an admission window into shared engine sessions,
//!   and a finished row's slot is refilled from the queue mid-session
//!   (continuous batching) instead of waiting for the whole batch.
//! * **Replica pool** ([`replica`], [`service`]): N engines behind
//!   least-loaded routing with per-replica weight-version tracking, so
//!   weight publishes roll across replicas without stopping traffic.
//! * **Robustness** : per-request deadlines, bounded retry with backoff,
//!   and a circuit breaker that quarantines a replica after K
//!   consecutive failures — quarantined replicas drain their queued
//!   traffic to healthy peers and are probed back to health.
//!
//! [`telemetry`] exposes queue wait, batch occupancy, in-flight depth
//! and per-replica throughput, flowing into the coordinator's
//! `Monitor`/`RunRecorder` (DESIGN.md §6).
//!
//! Session-tagged requests additionally flow through the prefix-reuse
//! cache (`crate::cache`, DESIGN.md §7): follow-up turns of a multi-turn
//! episode route to the replica holding their KV prefix and resume its
//! parked session instead of re-prefilling the transcript.

use std::time::Duration;

use anyhow::{ensure, Result};

pub mod batcher;
pub mod replica;
pub mod service;
pub mod telemetry;

pub use batcher::{RequestQueue, RowJob, SampleKey};
pub use replica::{
    Breaker, EngineReplica, ModelReplica, ReplicaEngine, ReplicaObs, ReplicaState, ServeCtl,
};
pub use service::{RolloutService, ServiceHandle};
pub use telemetry::{ReplicaSnapshot, ServiceMetrics, ServiceSnapshot};

/// Service tuning knobs (the typed `[service]` config section parses
/// into this; see `coordinator::config::ServiceSection`).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Max rows per shared session; 0 = the backend's native batch size.
    pub max_batch: usize,
    /// How long the first request of a batch waits for co-travellers.
    pub admission_window: Duration,
    /// Tokens sampled between continuous-batching refill checks.
    pub refill_chunk: usize,
    /// Per-request deadline: queued requests past it complete with an
    /// error instead of occupying a slot.
    pub request_timeout: Duration,
    /// Attempts per request across replicas (1 = no retry).
    pub max_attempts: usize,
    /// Backoff before a failed request is re-routed.
    pub retry_backoff: Duration,
    /// Consecutive failures that quarantine a replica.
    pub breaker_failures: u32,
    /// Quarantine cooldown before a health probe.
    pub quarantine: Duration,
    /// Prefix-reuse cache knobs (`service.cache_*` config keys).
    pub cache: crate::cache::CacheConfig,
    /// QoS serving-plane knobs (`[qos]` config section): request
    /// classes, fair scheduling, session migration (DESIGN.md §11).
    pub qos: crate::qos::QosConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 0,
            admission_window: Duration::from_millis(2),
            refill_chunk: 4,
            request_timeout: Duration::from_secs(120),
            max_attempts: 3,
            retry_backoff: Duration::from_millis(10),
            breaker_failures: 3,
            quarantine: Duration::from_millis(500),
            cache: crate::cache::CacheConfig::default(),
            qos: crate::qos::QosConfig::default(),
        }
    }
}

impl ServiceConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_attempts >= 1, "service.max_attempts must be >= 1");
        ensure!(self.refill_chunk >= 1, "service.refill_chunk must be >= 1");
        ensure!(self.breaker_failures >= 1, "service.breaker_failures must be >= 1");
        ensure!(self.request_timeout > Duration::ZERO, "service.timeout_s must be > 0");
        self.cache.validate()?;
        self.qos.validate()?;
        Ok(())
    }
}
