//! Replicas: the engines behind the rollout service, each wrapped with a
//! circuit breaker, load accounting, and its own request queue.
//!
//! A [`ReplicaEngine`] serves one *shared session* at a time: it claims
//! the initial rows, keeps pulling more through [`ServeCtl::refill`]
//! (continuous batching), and hands every claimed row back through
//! `done`/`fail`.  Two implementations:
//!
//! * [`EngineReplica`] — the real path over `GenerationEngine`: chunked
//!   sampling with mid-session slot restart through the decode path.
//! * [`ModelReplica`] — any `RolloutEndpoint` (notably `MockModel`), the
//!   stand-in for an external engine; used by tests and benches.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::cache::{ParkedSession, PrefixIndex, RowLease, SessionPark};
use crate::explorer::generation::{GenOutput, GenerationEngine, RolloutEndpoint, SamplingArgs};
use crate::explorer::Session;
use crate::model::{WeightSnapshot, WeightUpdate};
use crate::obs::{Span, SpanKind, SpanRecorder};
use crate::tokenizer::BOS;

use super::batcher::{RequestQueue, RowJob};
use super::telemetry::{ReplicaSnapshot, ServiceMetrics};

/// Tracing handle a replica stamps its spans with: the replica's id in
/// the trace's lane model, the shared span ring, and the fleet metrics
/// (for the cold-prefill histogram).  Absent when observability is off.
#[derive(Clone)]
pub struct ReplicaObs {
    pub id: u32,
    pub spans: Arc<SpanRecorder>,
    pub metrics: Arc<ServiceMetrics>,
}

// ---------------------------------------------------------------------------
// circuit breaker

/// Per-replica circuit breaker: `threshold` consecutive failures open it
/// for `quarantine`; a due probe either closes it or re-opens it.
#[derive(Debug)]
pub struct Breaker {
    threshold: u32,
    quarantine: Duration,
    consecutive: u32,
    open_until: Option<Instant>,
}

impl Breaker {
    pub fn new(threshold: u32, quarantine: Duration) -> Breaker {
        Breaker { threshold: threshold.max(1), quarantine, consecutive: 0, open_until: None }
    }

    pub fn is_open(&self) -> bool {
        self.open_until.is_some()
    }

    /// While open: time left before the next health probe (zero = due).
    pub fn time_to_probe(&self, now: Instant) -> Option<Duration> {
        self.open_until.map(|until| until.saturating_duration_since(now))
    }

    /// An in-flight row succeeded: reset the failure streak.  Does NOT
    /// close an open breaker — only a health probe ([`close`](Self::close))
    /// ends a quarantine, so intermittent failures can't flap the
    /// replica back into rotation.
    pub fn record_success(&mut self) {
        self.consecutive = 0;
    }

    /// A health probe succeeded: close the breaker.
    pub fn close(&mut self) {
        self.consecutive = 0;
        self.open_until = None;
    }

    /// Count one failure; returns true when this failure newly opened
    /// the breaker.
    pub fn record_failure(&mut self, now: Instant) -> bool {
        self.consecutive += 1;
        if self.open_until.is_none() && self.consecutive >= self.threshold {
            self.open_until = Some(now + self.quarantine);
            return true;
        }
        false
    }

    /// A probe failed: stay quarantined for another cooldown.
    pub fn reopen(&mut self, now: Instant) {
        self.open_until = Some(now + self.quarantine);
    }

    /// Quarantine for an explicit duration — the poisoned-worker path
    /// uses this to park a replica whose thread died.
    pub fn quarantine_for(&mut self, now: Instant, cooldown: Duration) {
        self.open_until = Some(now + cooldown);
    }
}

// ---------------------------------------------------------------------------
// the serving contract

/// Callbacks a [`ReplicaEngine`] uses while serving one shared session.
pub trait ServeCtl {
    /// Pull another queued request compatible with this session, if any
    /// (the continuous-batching refill source).
    fn refill(&mut self) -> Option<RowJob>;
    /// Deliver a finished row.
    fn done(&mut self, job: RowJob, out: GenOutput);
    /// Report a per-row failure.  Returns false when the session should
    /// abort (circuit breaker tripped): stop claiming work and return.
    fn fail(&mut self, job: RowJob, err: anyhow::Error) -> bool;
}

/// One engine behind the service.
pub trait ReplicaEngine: Send + Sync {
    /// Max rows a shared session can hold.
    fn max_batch(&self) -> usize;
    fn weight_version(&self) -> u64;
    /// Apply a published update the *service* fetched once for the whole
    /// pool (the rolling sync shares one `Arc<WeightSnapshot>` across
    /// every replica instead of N independent sync pulls).  Returns true
    /// when this replica moved to `update.version`.
    fn apply_update(&self, update: &WeightUpdate) -> Result<bool>;
    fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()>;
    /// Serve one shared session: the initial `rows` plus whatever
    /// [`ServeCtl::refill`] yields mid-session.  Every claimed row is
    /// handed back through `ctl`; on an engine-level error un-served
    /// jobs are put back into `rows` for the caller to retry.
    fn serve(&self, rows: &mut Vec<RowJob>, ctl: &mut dyn ServeCtl) -> Result<()>;
    /// Cheap health check used to close the circuit breaker.
    fn probe(&self) -> Result<()>;
    /// Parked KV sessions held for episode resumes (0 when uncached).
    fn parked(&self) -> usize {
        0
    }
    /// Live migration source (QoS plane, DESIGN.md §11): hand over the
    /// parked session holding episode `key`'s lease at exactly weight
    /// `version`, removing it from this replica.  `None` = not held
    /// here or unsupported (the caller falls back to a cold serve).
    fn extract_session(&self, key: u64, version: u64) -> Option<ParkedSession<Session>> {
        let _ = (key, version);
        None
    }
    /// Live migration sink: adopt a session extracted from a peer so
    /// the episode's next turn resumes here instead of re-prefilling.
    /// On rejection (no parking capacity / unsupported) the session is
    /// handed back untouched so the caller can restore it.
    fn adopt_session(
        &self,
        parked: ParkedSession<Session>,
    ) -> std::result::Result<(), ParkedSession<Session>> {
        Err(parked)
    }
}

// ---------------------------------------------------------------------------
// real engine replica (continuous batching over KV-cache sessions)

/// Continuous batching over one `GenerationEngine`.
///
/// Weight-consistency trade-off: sampling releases the replica's
/// ParamStore read lock between `refill_chunk`-token chunks, so a
/// rolling weight sync landing mid-session can switch a row's policy
/// version between chunks.  Lockstep policies are unaffected (explorers
/// are blocked in admission while the trainer publishes); free-running
/// policies already tolerate intra-batch staleness, and the service
/// reports the conservative *minimum* replica version per batch.  Raise
/// `service.refill_chunk` toward `max_new_tokens` to approach the
/// direct-handle behavior (one lock span per rollout) at the cost of
/// coarser slot refill.
pub struct EngineReplica {
    engine: Arc<GenerationEngine>,
    /// Tokens sampled between refill checks.
    refill_chunk: usize,
    /// Prefix-reuse wiring: the service-wide index (routing + telemetry)
    /// and this replica's parked KV sessions.  `None` = cache off.
    cache: Option<Arc<PrefixIndex>>,
    park: Mutex<SessionPark<Session>>,
    /// Span tracing, when observability is enabled.
    obs: Option<ReplicaObs>,
}

/// A session established for serving, warm or cold: the engine state,
/// the claimed jobs by row, per-row prompt lengths, and the session
/// tags each row starts with (pre-seeded by a warm resume with leases
/// that survived the claim untouched, so co-parked episodes re-park).
struct SessionSetup {
    session: Session,
    slots: Vec<Option<RowJob>>,
    plen: Vec<usize>,
    tags: Vec<Option<u64>>,
    /// Per-row decode-span start (recorder-relative µs; 0 = untraced).
    t0: Vec<u64>,
}

impl EngineReplica {
    pub fn new(engine: Arc<GenerationEngine>, refill_chunk: usize) -> EngineReplica {
        Self::with_cache(engine, refill_chunk, None)
    }

    /// A replica participating in the prefix-reuse cache: it parks live
    /// KV sessions between the turns of session-tagged episodes and
    /// resumes them by feeding only the new turn's delta tokens.
    pub fn with_cache(
        engine: Arc<GenerationEngine>,
        refill_chunk: usize,
        cache: Option<Arc<PrefixIndex>>,
    ) -> EngineReplica {
        let (capacity, ttl) = match &cache {
            Some(c) if c.config().enabled => (c.config().max_parked, c.config().park_ttl),
            _ => (0, Duration::from_secs(1)),
        };
        EngineReplica {
            engine,
            refill_chunk: refill_chunk.max(1),
            cache,
            park: Mutex::new(SessionPark::new(capacity, ttl)),
            obs: None,
        }
    }

    /// Attach span tracing (builder; observability enabled).
    pub fn with_obs(mut self, obs: ReplicaObs) -> EngineReplica {
        self.obs = Some(obs);
        self
    }

    /// Recorder-relative "now" for decode-span starts (0 when untraced).
    fn span_now(&self) -> u64 {
        self.obs.as_ref().map(|o| o.spans.now_us()).unwrap_or(0)
    }

    /// Parked sessions currently held (telemetry).
    pub fn parked_len(&self) -> usize {
        self.park.lock().unwrap().len()
    }

    /// Drop parked sessions whose weights predate the current version
    /// (invalidation-on-publish: a parked KV must be continued by
    /// exactly the weights that produced it).
    fn invalidate_parked(&self) {
        let version = self.engine.params_version();
        let dropped = self.park.lock().unwrap().invalidate_below(version);
        if let Some(cache) = &self.cache {
            cache.note_park_invalidated(dropped);
        }
    }

    /// Deliver row `r`'s output, then refill the freed slot from the
    /// queue (continuous batching).
    fn retire_row(
        &self,
        session: &mut Session,
        slots: &mut [Option<RowJob>],
        plen: &mut [usize],
        tags: &mut [Option<u64>],
        t0: &mut [u64],
        r: usize,
        finished: bool,
        cache: usize,
        aborted: &mut bool,
        ctl: &mut dyn ServeCtl,
    ) {
        let out = session.output(r, plen[r], finished);
        let job = slots[r].take().expect("retire_row on empty slot");
        if let Some(o) = &self.obs {
            let now = o.spans.now_us();
            o.spans.record(Span {
                trace: job.trace,
                kind: SpanKind::Decode,
                replica: o.id,
                start_us: t0[r],
                dur_us: now.saturating_sub(t0[r]),
                detail: session.tokens[r].len().saturating_sub(plen[r]) as u64,
            });
        }
        // the retired episode owns this row's KV until someone refills
        // the slot (see fill_slot, which clears the tag)
        tags[r] = job.args.session;
        ctl.done(job, out);
        self.fill_slot(session, slots, plen, tags, t0, r, cache, aborted, ctl);
    }

    /// Claim a queued request into the empty slot `r` (used both when a
    /// row retires and for idle padding rows, so bursty arrivals after
    /// session start don't wait for a retirement).  Sets `aborted` when
    /// a restart failure trips the breaker; no further fills happen
    /// after that, but rows already in flight keep serving.
    fn fill_slot(
        &self,
        session: &mut Session,
        slots: &mut [Option<RowJob>],
        plen: &mut [usize],
        tags: &mut [Option<u64>],
        t0: &mut [u64],
        r: usize,
        cache: usize,
        aborted: &mut bool,
        ctl: &mut dyn ServeCtl,
    ) {
        if *aborted {
            return;
        }
        if let Some(next) = ctl.refill() {
            // restarting the row clobbers whatever episode KV it held
            tags[r] = None;
            let max = cache.saturating_sub(2);
            let p: Vec<i32> = if next.prompt.len() > max {
                next.prompt[..max].to_vec()
            } else {
                next.prompt.clone()
            };
            let seed = next.args.seed;
            let t = Instant::now();
            match self.engine.restart_row(session, r, &p, seed) {
                Ok(()) => {
                    if let Some(o) = &self.obs {
                        o.metrics.note_prefill(t.elapsed());
                        o.spans.record(Span {
                            trace: next.trace,
                            kind: SpanKind::Prefill,
                            replica: o.id,
                            start_us: o.spans.rel_us(t),
                            dur_us: t.elapsed().as_micros() as u64,
                            detail: p.len() as u64,
                        });
                    }
                    plen[r] = p.len();
                    slots[r] = Some(next);
                    t0[r] = self.span_now();
                }
                Err(e) => {
                    if !ctl.fail(next, e) {
                        *aborted = true;
                    }
                }
            }
        }
    }

    /// Cold session establishment: prefill the batch heads, stream the
    /// tails through the decode path (the pre-cache serve() behavior).
    fn cold_start(
        &self,
        rows: &mut Vec<RowJob>,
        count: usize,
        tp: usize,
        cache: usize,
    ) -> Result<SessionSetup> {
        let t_prefill = Instant::now();
        let clamp = |p: &[i32]| -> Vec<i32> {
            let max = cache.saturating_sub(2);
            if p.len() > max {
                p[..max].to_vec()
            } else {
                p.to_vec()
            }
        };
        // prompts longer than the prefill bucket stream their tail
        // through the decode path, exactly like `generate()`
        let clamped: Vec<Vec<i32>> = rows.iter().take(count).map(|j| clamp(&j.prompt)).collect();
        let heads: Vec<Vec<i32>> = clamped.iter().map(|p| p[..p.len().min(tp)].to_vec()).collect();
        let base_seed = rows[0].args.seed;
        let mut session = self.engine.start_session(&heads, base_seed)?;
        let nrows = session.rows();
        let tails: Vec<Vec<i32>> = (0..nrows)
            .map(|r| {
                if r < clamped.len() && clamped[r].len() > tp {
                    clamped[r][tp..].to_vec()
                } else {
                    Vec::new()
                }
            })
            .collect();
        if tails.iter().any(|t| !t.is_empty()) {
            self.engine.feed(&mut session, &tails)?;
        }
        let prefill_took = t_prefill.elapsed();
        // session established: claim the jobs (every claimed job must be
        // handed back through ctl or returned via `rows` on error)
        let mut slots: Vec<Option<RowJob>> = rows.drain(..count).map(Some).collect();
        slots.resize_with(nrows, || None);
        let mut plen = vec![0usize; nrows];
        for (r, slot) in slots.iter().enumerate() {
            if let Some(job) = slot {
                plen[r] = clamped[r].len();
                session.seed_row(r, job.args.seed);
            }
        }
        if let Some(o) = &self.obs {
            // one shared prefill; each claimed episode gets its own span
            // so its timeline stays self-contained
            o.metrics.note_prefill(prefill_took);
            let start = o.spans.rel_us(t_prefill);
            for job in slots.iter().flatten() {
                o.spans.record(Span {
                    trace: job.trace,
                    kind: SpanKind::Prefill,
                    replica: o.id,
                    start_us: start,
                    dur_us: prefill_took.as_micros() as u64,
                    detail: job.prompt.len() as u64,
                });
            }
        }
        let tags = vec![None; nrows];
        let t0 = vec![self.span_now(); nrows];
        Ok(SessionSetup { session, slots, plen, tags, t0 })
    }

    /// Warm session establishment: claim a parked session one of the
    /// batch jobs continues (same weights, transcript a prefix of the
    /// prompt) and extend the matching rows with only their delta
    /// tokens; unmatched jobs stream into free rows through the decode
    /// path.  `None` = nothing reusable, take the cold path.  On an
    /// engine-level error every claimed job is handed back via `rows`
    /// (the serve() retry contract).
    fn try_resume(
        &self,
        rows: &mut Vec<RowJob>,
        count: usize,
        cache_len: usize,
        version: u64,
    ) -> Result<Option<SessionSetup>> {
        let Some(cache) = &self.cache else { return Ok(None) };
        if !cache.config().enabled {
            return Ok(None);
        }
        let claimed = {
            let mut park = self.park.lock().unwrap();
            cache.note_park_expired(park.sweep(Instant::now()));
            park.claim(|p| {
                p.version == version
                    && rows.iter().take(count).any(|job| {
                        job.args.session.is_some_and(|key| {
                            (0..p.rows.len())
                                .any(|r| p.row_resumes(r, key, &job.prompt, cache_len))
                        })
                    })
            })
        };
        let Some(parked) = claimed else { return Ok(None) };
        let ParkedSession { state: mut session, rows: leases, .. } = parked;
        let nrows = session.rows();
        let mut slots: Vec<Option<RowJob>> = std::iter::repeat_with(|| None).take(nrows).collect();
        let mut plen = vec![0usize; nrows];
        let mut used = vec![false; nrows];
        let mut batch: VecDeque<RowJob> = rows.drain(..count).collect();
        let mut pending: VecDeque<RowJob> = VecDeque::new();
        while let Some(job) = batch.pop_front() {
            let hit = job.args.session.and_then(|key| {
                (0..nrows).find(|&r| {
                    !used[r]
                        && leases[r]
                            .as_ref()
                            .is_some_and(|l| l.resumes(key, &job.prompt, cache_len))
                })
            });
            match hit {
                Some(r) => {
                    let reused = leases[r].as_ref().map(|l| l.transcript.len()).unwrap_or(0);
                    let delta = &job.prompt[reused..];
                    let t = Instant::now();
                    match self.engine.extend_row(&mut session, r, delta, job.args.seed) {
                        Ok(()) => {
                            cache.note_resumed(reused);
                            if let Some(o) = &self.obs {
                                o.spans.record(Span {
                                    trace: job.trace,
                                    kind: SpanKind::Resume,
                                    replica: o.id,
                                    start_us: o.spans.rel_us(t),
                                    dur_us: t.elapsed().as_micros() as u64,
                                    detail: reused as u64,
                                });
                            }
                            used[r] = true;
                            plen[r] = job.prompt.len();
                            slots[r] = Some(job);
                        }
                        Err(e) => {
                            rows.extend(slots.iter_mut().filter_map(Option::take));
                            rows.push(job);
                            rows.extend(pending);
                            rows.extend(batch);
                            return Err(e);
                        }
                    }
                }
                None => pending.push_back(job),
            }
        }
        // unmatched jobs stream into free rows through the decode path
        // (rows still holding unclaimed leases are clobbered last, so a
        // second episode parked in this session survives when there is
        // room)
        let mut free: Vec<usize> = (0..nrows).filter(|&r| !used[r]).collect();
        free.sort_by_key(|&r| leases[r].is_some());
        let mut free = free.into_iter();
        while let Some(job) = pending.pop_front() {
            let r = free.next().expect("batch jobs never exceed session rows");
            let max = cache_len.saturating_sub(2);
            let p: Vec<i32> = if job.prompt.len() > max {
                job.prompt[..max].to_vec()
            } else {
                job.prompt.clone()
            };
            let t = Instant::now();
            match self.engine.restart_row(&mut session, r, &p, job.args.seed) {
                Ok(()) => {
                    if let Some(o) = &self.obs {
                        o.metrics.note_prefill(t.elapsed());
                        o.spans.record(Span {
                            trace: job.trace,
                            kind: SpanKind::Prefill,
                            replica: o.id,
                            start_us: o.spans.rel_us(t),
                            dur_us: t.elapsed().as_micros() as u64,
                            detail: p.len() as u64,
                        });
                    }
                    plen[r] = p.len();
                    slots[r] = Some(job);
                }
                Err(e) => {
                    rows.extend(slots.iter_mut().filter_map(Option::take));
                    rows.push(job);
                    rows.extend(pending);
                    return Err(e);
                }
            }
        }
        // leases that survived the claim untouched (their episodes did
        // not turn this batch, and no job clobbered their row) carry
        // over, so park_after re-files them and a co-parked episode's
        // next turn still resumes
        let mut tags: Vec<Option<u64>> = vec![None; nrows];
        for (r, tag) in tags.iter_mut().enumerate() {
            if slots[r].is_none() {
                *tag = leases[r].as_ref().map(|l| l.key);
            }
        }
        let t0 = vec![self.span_now(); nrows];
        Ok(Some(SessionSetup { session, slots, plen, tags, t0 }))
    }

    /// Park the finished session for the episodes' next turns.  Skipped
    /// when no row served a session-tagged job, when parking is off, or
    /// when a rolling sync landed mid-session (mixed-version KV must
    /// never be resumed).
    fn park_after(&self, session: Session, tags: &[Option<u64>], version: u64) {
        let Some(cache) = &self.cache else { return };
        let cfg = cache.config();
        if !cfg.enabled || cfg.max_parked == 0 {
            return;
        }
        if self.engine.params_version() != version {
            return;
        }
        let leases: Vec<Option<RowLease>> = tags
            .iter()
            .enumerate()
            .map(|(r, tag)| {
                tag.and_then(|key| {
                    // per-row serving stamp (GenOutput::version source):
                    // the same stamp the trie invalidates off
                    (session.row_version(r) == version)
                        .then(|| RowLease { key, transcript: session.tokens[r].clone() })
                })
            })
            .collect();
        if leases.iter().all(Option::is_none) {
            return;
        }
        let now = Instant::now();
        let mut park = self.park.lock().unwrap();
        cache.note_park_expired(park.sweep(now));
        let evicted = park.park(session, version, leases, now);
        cache.note_parked(evicted);
    }
}

impl ReplicaEngine for EngineReplica {
    fn max_batch(&self) -> usize {
        self.engine.engine().gen_shape().0
    }

    fn weight_version(&self) -> u64 {
        self.engine.params_version()
    }

    fn apply_update(&self, update: &WeightUpdate) -> Result<bool> {
        let updated = self.engine.apply_update(update)?;
        if updated {
            // a new policy version invalidates every parked KV session
            self.invalidate_parked();
        }
        Ok(updated)
    }

    fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        self.engine.set_weights(snapshot, version)?;
        self.invalidate_parked();
        Ok(())
    }

    fn serve(&self, rows: &mut Vec<RowJob>, ctl: &mut dyn ServeCtl) -> Result<()> {
        let (b, tp, cache) = self.engine.engine().gen_shape();
        let count = rows.len().min(b);
        ensure!(count > 0, "empty service session");
        let version = self.engine.params_version();
        // establish the session: resume a parked one when a batch job
        // continues a leased transcript under the current weights, else
        // prefill a fresh one
        let setup = match self.try_resume(rows, count, cache, version)? {
            Some(parts) => parts,
            None => self.cold_start(rows, count, tp, cache)?,
        };
        // `tags`: which episode's KV each row holds once its job retires
        // — the leases park_after() files for the episodes' next turns
        let SessionSetup { mut session, mut slots, mut plen, mut tags, mut t0 } = setup;
        let nrows = session.rows();
        let template = slots.iter().flatten().next().map(|j| j.args.clone()).unwrap_or_default();
        let mut aborted = false;
        loop {
            // fill idle padding slots from the queue first: requests
            // arriving after session start join the running session
            // instead of waiting for a retirement (ctl enforces the
            // configured occupancy cap)
            for r in 0..nrows {
                if slots[r].is_none() {
                    self.fill_slot(
                        &mut session,
                        &mut slots,
                        &mut plen,
                        &mut tags,
                        &mut t0,
                        r,
                        cache,
                        &mut aborted,
                        ctl,
                    );
                }
            }
            // rows still wanting tokens, and the chunk that overshoots none
            let mut live = vec![false; nrows];
            let mut chunk = self.refill_chunk;
            for (r, slot) in slots.iter().enumerate() {
                if let Some(job) = slot {
                    let generated = session.tokens[r].len().saturating_sub(plen[r]);
                    let remaining = job.args.max_new_tokens.saturating_sub(generated);
                    if remaining > 0 && session.remaining_budget(r) > 0 {
                        live[r] = true;
                        chunk = chunk.min(remaining);
                    }
                }
            }
            // retire occupied slots that want no more tokens (zero
            // token budget, exhausted cache): every claimed job is
            // handed back through ctl, never dropped
            let mut retired = false;
            for r in 0..nrows {
                if slots[r].is_some() && !live[r] {
                    self.retire_row(
                        &mut session,
                        &mut slots,
                        &mut plen,
                        &mut tags,
                        &mut t0,
                        r,
                        false,
                        cache,
                        &mut aborted,
                        ctl,
                    );
                    retired = true;
                }
            }
            if retired {
                continue; // freshly refilled slots re-enter the scan
            }
            if !live.contains(&true) {
                break;
            }
            let step_args = SamplingArgs { max_new_tokens: chunk, ..template.clone() };
            let finished = match self.engine.sample(&mut session, &step_args, &live) {
                Ok(f) => f,
                Err(e) => {
                    // engine-level failure: hand in-flight jobs back for retry
                    rows.extend(slots.iter_mut().filter_map(Option::take));
                    return Err(e);
                }
            };
            for r in 0..nrows {
                if !live[r] {
                    continue;
                }
                let generated = session.tokens[r].len().saturating_sub(plen[r]);
                let row_done = {
                    let job = slots[r].as_ref().unwrap();
                    finished[r]
                        || generated >= job.args.max_new_tokens
                        || session.remaining_budget(r) == 0
                };
                if row_done {
                    // continuous batching: deliver + refill mid-session
                    self.retire_row(
                        &mut session,
                        &mut slots,
                        &mut plen,
                        &mut tags,
                        &mut t0,
                        r,
                        finished[r],
                        cache,
                        &mut aborted,
                        ctl,
                    );
                }
            }
        }
        // keep the KV alive for the episodes' next turns
        self.park_after(session, &tags, version);
        Ok(())
    }

    fn probe(&self) -> Result<()> {
        let args = SamplingArgs { max_new_tokens: 1, ..SamplingArgs::default() };
        self.engine.generate(&[vec![BOS]], &args).map(|_| ())
    }

    fn parked(&self) -> usize {
        self.parked_len()
    }

    fn extract_session(&self, key: u64, version: u64) -> Option<ParkedSession<Session>> {
        let mut park = self.park.lock().unwrap();
        if let Some(cache) = &self.cache {
            cache.note_park_expired(park.sweep(Instant::now()));
        }
        park.claim(|p| {
            p.version == version
                && p.rows.iter().any(|l| l.as_ref().is_some_and(|l| l.key == key))
        })
    }

    fn adopt_session(
        &self,
        parked: ParkedSession<Session>,
    ) -> std::result::Result<(), ParkedSession<Session>> {
        // adopted KV must be continued by exactly the weights that
        // produced it — reject on any version skew (the router checks
        // this too, but weights can roll between decision and adopt)
        if parked.version != self.engine.params_version() {
            return Err(parked);
        }
        let mut park = self.park.lock().unwrap();
        if park.capacity() == 0 {
            return Err(parked);
        }
        let evicted = park.adopt(parked);
        if let Some(cache) = &self.cache {
            cache.note_parked(evicted);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// endpoint-backed replica (mock / external engine stand-in)

pub struct ModelReplica {
    model: Arc<dyn RolloutEndpoint>,
    max_batch: usize,
    obs: Option<ReplicaObs>,
}

impl ModelReplica {
    pub fn new(model: Arc<dyn RolloutEndpoint>, max_batch: usize) -> ModelReplica {
        ModelReplica { model, max_batch: max_batch.max(1), obs: None }
    }

    /// Attach span tracing (builder; observability enabled).
    pub fn with_obs(mut self, obs: ReplicaObs) -> ModelReplica {
        self.obs = Some(obs);
        self
    }
}

impl ReplicaEngine for ModelReplica {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn weight_version(&self) -> u64 {
        self.model.weight_version()
    }

    fn apply_update(&self, update: &WeightUpdate) -> Result<bool> {
        if self.model.weight_version() >= update.version {
            return Ok(false);
        }
        self.model.set_weights(&update.snapshot, update.version)?;
        Ok(true)
    }

    fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        self.model.set_weights(snapshot, version)
    }

    fn serve(&self, rows: &mut Vec<RowJob>, ctl: &mut dyn ServeCtl) -> Result<()> {
        loop {
            let job = if rows.is_empty() {
                match ctl.refill() {
                    Some(j) => j,
                    None => break,
                }
            } else {
                rows.remove(0)
            };
            let t = Instant::now();
            match self.model.chat(&job.prompt, 1, &job.args) {
                Ok(mut outs) if !outs.is_empty() => {
                    if let Some(o) = &self.obs {
                        // the endpoint call is opaque, so the timeline
                        // marks resume-vs-cold at the call start (the
                        // router's prefix match decides which) and books
                        // the whole call as the decode span
                        let start = o.spans.rel_us(t);
                        let (kind, detail) = if job.reused > 0 {
                            (SpanKind::Resume, job.reused as u64)
                        } else {
                            (SpanKind::Prefill, job.prompt.len() as u64)
                        };
                        o.spans.record(Span {
                            trace: job.trace,
                            kind,
                            replica: o.id,
                            start_us: start,
                            dur_us: 0,
                            detail,
                        });
                        o.spans.record(Span {
                            trace: job.trace,
                            kind: SpanKind::Decode,
                            replica: o.id,
                            start_us: start,
                            dur_us: t.elapsed().as_micros() as u64,
                            detail: outs[0].tokens.len().saturating_sub(job.prompt.len()) as u64,
                        });
                    }
                    ctl.done(job, outs.remove(0))
                }
                Ok(_) => {
                    if !ctl.fail(job, anyhow!("backend returned no output")) {
                        break;
                    }
                }
                Err(e) => {
                    if !ctl.fail(job, e) {
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    fn probe(&self) -> Result<()> {
        let args = SamplingArgs { max_new_tokens: 1, ..SamplingArgs::default() };
        self.model.chat(&[BOS], 1, &args).map(|_| ())
    }
}

// ---------------------------------------------------------------------------
// replica state (engine + queue + breaker + accounting)

pub struct ReplicaState {
    pub id: usize,
    pub engine: Arc<dyn ReplicaEngine>,
    pub queue: RequestQueue,
    pub breaker: Mutex<Breaker>,
    /// Rows currently inside this replica's session.
    pub inflight: AtomicUsize,
    pub rows_served: AtomicU64,
    pub failures: AtomicU64,
    pub quarantines: AtomicU64,
}

impl ReplicaState {
    pub fn new(id: usize, engine: Arc<dyn ReplicaEngine>, breaker: Breaker) -> ReplicaState {
        Self::with_qos(id, engine, breaker, &crate::qos::QosConfig::default())
    }

    /// A replica whose queue honors the QoS plane (per-class DRR
    /// dequeue) when `qos.enabled`; identical to [`new`](Self::new)
    /// otherwise.
    pub fn with_qos(
        id: usize,
        engine: Arc<dyn ReplicaEngine>,
        breaker: Breaker,
        qos: &crate::qos::QosConfig,
    ) -> ReplicaState {
        ReplicaState {
            id,
            engine,
            queue: RequestQueue::with_qos(qos),
            breaker: Mutex::new(breaker),
            inflight: AtomicUsize::new(0),
            rows_served: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
        }
    }

    /// Routing load: queued + in-session rows.
    pub fn load(&self) -> usize {
        self.queue.len() + self.inflight.load(Ordering::SeqCst)
    }

    /// Accepting routed traffic (breaker closed)?
    pub fn ready(&self) -> bool {
        !self.breaker.lock().unwrap().is_open()
    }

    /// Milliseconds until this replica's next probe (0 if ready) — the
    /// all-quarantined routing fallback prefers the soonest recovery.
    pub fn probe_eta_ms(&self, now: Instant) -> u64 {
        self.breaker
            .lock()
            .unwrap()
            .time_to_probe(now)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0)
    }

    pub fn snapshot(&self) -> ReplicaSnapshot {
        ReplicaSnapshot {
            id: self.id,
            rows: self.rows_served.load(Ordering::SeqCst),
            failures: self.failures.load(Ordering::SeqCst),
            quarantines: self.quarantines.load(Ordering::SeqCst),
            quarantined: !self.ready(),
            weight_version: self.engine.weight_version(),
            queued: self.queue.len(),
            inflight: self.inflight.load(Ordering::SeqCst),
            parked: self.engine.parked(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_opens_after_threshold_and_probes_back() {
        let mut b = Breaker::new(3, Duration::from_millis(50));
        let t0 = Instant::now();
        assert!(!b.record_failure(t0));
        assert!(!b.record_failure(t0));
        assert!(!b.is_open());
        assert!(b.record_failure(t0), "third consecutive failure opens");
        assert!(b.is_open());
        // further failures while open do not re-report "newly opened"
        assert!(!b.record_failure(t0));
        // cooldown counts down to a due probe
        assert!(b.time_to_probe(t0).unwrap() > Duration::ZERO);
        assert_eq!(b.time_to_probe(t0 + Duration::from_millis(60)), Some(Duration::ZERO));
        // an in-flight success resets the streak but does NOT close an
        // open breaker (only a probe may, so quarantine can't flap)
        b.record_success();
        assert!(b.is_open());
        // failed probe re-opens, successful probe closes
        b.reopen(t0 + Duration::from_millis(60));
        assert!(b.time_to_probe(t0 + Duration::from_millis(61)).unwrap() > Duration::ZERO);
        b.close();
        assert!(!b.is_open());
    }

    #[test]
    fn breaker_success_resets_the_streak() {
        let mut b = Breaker::new(2, Duration::from_millis(10));
        let now = Instant::now();
        assert!(!b.record_failure(now));
        b.record_success();
        assert!(!b.record_failure(now), "streak was reset");
        assert!(b.record_failure(now));
    }

    #[test]
    fn replica_state_load_and_snapshot() {
        use crate::explorer::generation::MockModel;
        let model: Arc<dyn RolloutEndpoint> =
            Arc::new(MockModel::new(1, Duration::ZERO, 0.0));
        let engine: Arc<dyn ReplicaEngine> = Arc::new(ModelReplica::new(model, 4));
        let r = ReplicaState::new(7, engine, Breaker::new(2, Duration::from_millis(10)));
        assert_eq!(r.load(), 0);
        assert!(r.ready());
        r.inflight.fetch_add(3, Ordering::SeqCst);
        assert_eq!(r.load(), 3);
        let snap = r.snapshot();
        assert_eq!((snap.id, snap.inflight, snap.quarantined), (7, 3, false));
    }
}
