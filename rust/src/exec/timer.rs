//! Deadlines and retry-with-backoff — the primitives behind the paper's
//! timeout/retry/skip fault-tolerance for agent–environment interaction.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    pub fn after(d: Duration) -> Deadline {
        Deadline { at: Instant::now() + d }
    }
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }
}

#[derive(Debug, Clone)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    pub base_delay: Duration,
    pub backoff: f64,
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::from_millis(10),
            backoff: 2.0,
            max_delay: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    pub fn delay_for_attempt(&self, attempt: usize) -> Duration {
        let ms = self.base_delay.as_secs_f64() * self.backoff.powi(attempt as i32);
        Duration::from_secs_f64(ms).min(self.max_delay)
    }
}

/// Run `f` until it succeeds or attempts are exhausted.  Returns the last
/// error alongside the attempt count so the runner can log retry stats.
pub fn retry<T, E, F>(policy: &RetryPolicy, mut f: F) -> Result<(T, usize), (E, usize)>
where
    F: FnMut(usize) -> Result<T, E>,
{
    let mut last_err = None;
    for attempt in 0..policy.max_attempts {
        match f(attempt) {
            Ok(v) => return Ok((v, attempt + 1)),
            Err(e) => {
                last_err = Some(e);
                if attempt + 1 < policy.max_attempts {
                    std::thread::sleep(policy.delay_for_attempt(attempt));
                }
            }
        }
    }
    Err((last_err.unwrap(), policy.max_attempts))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(20));
        assert!(!d.expired());
        std::thread::sleep(Duration::from_millis(25));
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
    }

    #[test]
    fn retry_succeeds_after_failures() {
        let policy = RetryPolicy { base_delay: Duration::from_millis(1), ..Default::default() };
        let mut calls = 0;
        let result = retry(&policy, |_| {
            calls += 1;
            if calls < 3 {
                Err("fail")
            } else {
                Ok(42)
            }
        });
        assert_eq!(result.unwrap(), (42, 3));
    }

    #[test]
    fn retry_exhausts() {
        let policy = RetryPolicy {
            max_attempts: 2,
            base_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let result: Result<((), usize), _> = retry(&policy, |_| Err::<(), _>("nope"));
        assert_eq!(result.unwrap_err(), ("nope", 2));
    }

    #[test]
    fn backoff_grows_and_caps() {
        let policy = RetryPolicy {
            max_attempts: 10,
            base_delay: Duration::from_millis(100),
            backoff: 10.0,
            max_delay: Duration::from_secs(2),
        };
        assert_eq!(policy.delay_for_attempt(0), Duration::from_millis(100));
        assert_eq!(policy.delay_for_attempt(1), Duration::from_secs(1));
        assert_eq!(policy.delay_for_attempt(5), Duration::from_secs(2)); // capped
    }
}
