//! Promise / cancellation primitives for the thread-pool executor.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone, thiserror::Error, PartialEq)]
pub enum TaskError {
    #[error("task panicked: {0}")]
    Panicked(String),
    #[error("task timed out after {0:?}")]
    Timeout(Duration),
    #[error("task was cancelled")]
    Cancelled,
    #[error("executor shut down before task completed")]
    Disconnected,
    #[error("task failed: {0}")]
    Failed(String),
}

/// One-shot result handle for a submitted task.
pub struct Promise<T> {
    rx: mpsc::Receiver<Result<T, TaskError>>,
}

pub struct Completer<T> {
    tx: mpsc::Sender<Result<T, TaskError>>,
}

impl<T> Completer<T> {
    pub fn complete(self, value: T) {
        let _ = self.tx.send(Ok(value));
    }
    pub fn fail(self, err: TaskError) {
        let _ = self.tx.send(Err(err));
    }
}

impl<T> Promise<T> {
    pub fn pair() -> (Completer<T>, Promise<T>) {
        let (tx, rx) = mpsc::channel();
        (Completer { tx }, Promise { rx })
    }

    /// Create an already-resolved promise.
    pub fn ready(value: T) -> Promise<T> {
        let (c, p) = Self::pair();
        c.complete(value);
        p
    }

    /// Block until the task completes.
    pub fn wait(self) -> Result<T, TaskError> {
        self.rx.recv().unwrap_or(Err(TaskError::Disconnected))
    }

    /// Block up to `timeout`; the promise is consumed either way (the
    /// runner treats a timed-out task as abandoned, per the paper's
    /// timeout/skip fault tolerance).
    pub fn wait_timeout(self, timeout: Duration) -> Result<T, TaskError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(TaskError::Timeout(timeout)),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(TaskError::Disconnected),
        }
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<Result<T, TaskError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(TaskError::Disconnected)),
        }
    }
}

/// Cooperative cancellation shared across workers.
#[derive(Debug, Clone, Default)]
pub struct CancellationToken {
    flag: Arc<AtomicBool>,
}

impl CancellationToken {
    pub fn new() -> CancellationToken {
        Self::default()
    }
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
    /// Sleep in small increments so cancellation is observed promptly.
    pub fn sleep(&self, total: Duration) -> bool {
        let step = Duration::from_millis(5);
        let mut remaining = total;
        while remaining > Duration::ZERO {
            if self.is_cancelled() {
                return false;
            }
            let d = remaining.min(step);
            std::thread::sleep(d);
            remaining = remaining.saturating_sub(d);
        }
        !self.is_cancelled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promise_resolves() {
        let (c, p) = Promise::pair();
        std::thread::spawn(move || c.complete(42));
        assert_eq!(p.wait().unwrap(), 42);
    }

    #[test]
    fn promise_timeout() {
        let (_c, p) = Promise::<i32>::pair();
        let err = p.wait_timeout(Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, TaskError::Timeout(_)));
    }

    #[test]
    fn promise_disconnected() {
        let (c, p) = Promise::<i32>::pair();
        drop(c);
        assert_eq!(p.wait().unwrap_err(), TaskError::Disconnected);
    }

    #[test]
    fn try_take_polls() {
        let (c, p) = Promise::pair();
        assert!(p.try_take().is_none());
        c.complete(7);
        assert_eq!(p.try_take().unwrap().unwrap(), 7);
    }

    #[test]
    fn cancellation() {
        let tok = CancellationToken::new();
        let t2 = tok.clone();
        let h = std::thread::spawn(move || t2.sleep(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        tok.cancel();
        assert!(!h.join().unwrap()); // sleep interrupted
        assert!(tok.is_cancelled());
    }
}
