//! Fixed-size thread pool with panic containment and busy-fraction
//! accounting.
//!
//! Busy-fraction is the CPU-era stand-in for the paper's GPU-utilization
//! metric (Tables 1–2): the fraction of wall-time the pool's workers spent
//! executing tasks.  Explorer and trainer each own a pool, mirroring the
//! paper's explorer/trainer GPU partition.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::future::{Promise, TaskError};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    busy_nanos: AtomicU64,
    in_flight: AtomicUsize,
    started_at: Mutex<Instant>,
}

pub struct ThreadPool {
    name: String,
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    size: usize,
}

impl ThreadPool {
    pub fn new(name: &str, size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            busy_nanos: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            started_at: Mutex::new(Instant::now()),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let thread_name = format!("{name}-{i}");
            workers.push(
                std::thread::Builder::new()
                    .name(thread_name)
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                shared.in_flight.fetch_add(1, Ordering::SeqCst);
                                let start = Instant::now();
                                // Panics are contained per-job: a failing
                                // workflow must not take down the runner.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                shared
                                    .busy_nanos
                                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                                shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { name: name.to_string(), tx: Some(tx), workers, shared, size }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job; the promise resolves with its return value, or with
    /// `TaskError::Panicked` if it panicked.
    pub fn submit<T, F>(&self, f: F) -> Promise<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (completer, promise) = Promise::pair();
        let job: Job = Box::new(move || {
            match catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => completer.complete(v),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "<non-string panic>".to_string());
                    completer.fail(TaskError::Panicked(msg));
                }
            }
        });
        if let Some(tx) = &self.tx {
            let _ = tx.send(job);
        }
        promise
    }

    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// Reset the busy-fraction accounting window.
    pub fn reset_utilization(&self) {
        self.shared.busy_nanos.store(0, Ordering::SeqCst);
        *self.shared.started_at.lock().unwrap() = Instant::now();
    }

    /// Busy fraction over the current window, normalized per worker, in
    /// percent (the "GPU utilization" analog).
    pub fn utilization_percent(&self) -> f64 {
        let wall = self.shared.started_at.lock().unwrap().elapsed().as_nanos() as f64;
        if wall <= 0.0 {
            return 0.0;
        }
        let busy = self.shared.busy_nanos.load(Ordering::Relaxed) as f64;
        100.0 * busy / (wall * self.size as f64)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn runs_jobs_concurrently() {
        let pool = ThreadPool::new("t", 4);
        let start = Instant::now();
        let promises: Vec<_> = (0..4)
            .map(|i| {
                pool.submit(move || {
                    std::thread::sleep(Duration::from_millis(50));
                    i * 2
                })
            })
            .collect();
        let results: Vec<i32> = promises.into_iter().map(|p| p.wait().unwrap()).collect();
        assert_eq!(results, vec![0, 2, 4, 6]);
        assert!(start.elapsed() < Duration::from_millis(160), "not parallel");
    }

    #[test]
    fn contains_panics() {
        let pool = ThreadPool::new("t", 1);
        let p1 = pool.submit(|| panic!("boom"));
        assert!(matches!(p1.wait().unwrap_err(), TaskError::Panicked(m) if m.contains("boom")));
        // pool still alive after a panic
        let p2 = pool.submit(|| 1);
        assert_eq!(p2.wait().unwrap(), 1);
    }

    #[test]
    fn utilization_accounting() {
        let pool = ThreadPool::new("t", 2);
        pool.reset_utilization();
        let ps: Vec<_> =
            (0..2).map(|_| pool.submit(|| std::thread::sleep(Duration::from_millis(60)))).collect();
        for p in ps {
            p.wait().unwrap();
        }
        let util = pool.utilization_percent();
        assert!(util > 40.0 && util <= 101.0, "util {util}");
    }

    #[test]
    fn shutdown_joins_workers() {
        let pool = ThreadPool::new("t", 2);
        let p = pool.submit(|| 5);
        drop(pool);
        assert_eq!(p.wait().unwrap(), 5);
    }
}
