//! Bounded MPMC channel with blocking backpressure (condvar-based).
//!
//! This is the plumbing between explorer and buffer, and between data
//! pipeline stages: multiple workflow-runner threads `send` experiences,
//! multiple consumers `recv`, and a full channel blocks producers — the
//! backpressure the paper's Controller module applies against resource
//! exhaustion.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SendError {
    #[error("channel closed")]
    Closed,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum RecvError {
    #[error("channel closed and drained")]
    Closed,
    #[error("recv timed out")]
    Timeout,
    #[error("channel empty")]
    Empty,
}

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    senders: usize,
}

pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0);
    let inner = Arc::new(Inner {
        queue: Mutex::new(State { items: VecDeque::new(), closed: false, senders: 1 }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
    });
    (Sender { inner: Arc::clone(&inner) }, Receiver { inner })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.queue.lock().unwrap().senders += 1;
        Sender { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            self.inner.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Sender<T> {
    /// Blocking send with backpressure.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError::Closed);
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(item);
                drop(st);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.closed || st.items.len() >= self.inner.capacity {
            return Err(item);
        }
        st.items.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; returns `Closed` only after the queue drains.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(RecvError::Closed);
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(RecvError::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, res) = self.inner.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Err(RecvError::Closed);
                }
                return Err(RecvError::Timeout);
            }
        }
    }

    pub fn try_recv(&self) -> Result<T, RecvError> {
        let mut st = self.inner.queue.lock().unwrap();
        if let Some(item) = st.items.pop_front() {
            drop(st);
            self.inner.not_full.notify_one();
            return Ok(item);
        }
        if st.closed {
            Err(RecvError::Closed)
        } else {
            Err(RecvError::Empty)
        }
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(10);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let got: Vec<i32> = (0..5).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn backpressure_blocks_producer() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err());
        let t = std::thread::spawn(move || {
            let start = Instant::now();
            tx.send(3).unwrap(); // blocks until consumer drains
            start.elapsed()
        });
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.recv().unwrap(), 1);
        let blocked_for = t.join().unwrap();
        assert!(blocked_for >= Duration::from_millis(40), "{blocked_for:?}");
    }

    #[test]
    fn close_drains_then_errors() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap_err(), RecvError::Closed);
        assert_eq!(tx.send(3).unwrap_err(), SendError::Closed);
    }

    #[test]
    fn drop_all_senders_closes() {
        let (tx, rx) = bounded::<i32>(4);
        let tx2 = tx.clone();
        tx.send(7).unwrap();
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv().unwrap_err(), RecvError::Closed);
    }

    #[test]
    fn recv_timeout() {
        let (_tx, rx) = bounded::<i32>(1);
        let start = Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(30)).unwrap_err(), RecvError::Timeout);
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(8);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || {
                    let mut got = vec![];
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut expected: Vec<i32> =
            (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        expected.sort_unstable();
        assert_eq!(all, expected);
    }
}
