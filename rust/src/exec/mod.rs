//! Threaded async-lite executor (the offline registry has no tokio).
//!
//! The explorer's workflow runners, the trainer loop and the coordinator
//! scheduler are built on these primitives: a panic-containing thread
//! pool, promises with timed waits, cancellation tokens, bounded MPMC
//! channels with backpressure, watchable state cells, and retry/deadline
//! helpers.

pub mod channel;
pub mod future;
pub mod pool;
pub mod timer;
pub mod watch;

pub use channel::{bounded, Receiver, RecvError, SendError, Sender};
pub use future::{CancellationToken, Promise, TaskError};
pub use pool::ThreadPool;
pub use watch::WatchCell;
