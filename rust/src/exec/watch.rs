//! `WatchCell`: a shared state cell with predicate waiting — the
//! executor's replacement for hand-rolled `Mutex` + `Condvar` pairs.
//!
//! The coordinator's scheduler keeps its run progress (train steps,
//! published weight windows, explored batches) in one `WatchCell`;
//! explorer drivers block in [`WatchCell::wait_until`] until their sync
//! policy admits the next batch, and every state mutation through
//! [`WatchCell::update`] wakes all waiters to re-evaluate.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

pub struct WatchCell<T> {
    state: Mutex<T>,
    cvar: Condvar,
}

impl<T> WatchCell<T> {
    pub fn new(initial: T) -> WatchCell<T> {
        WatchCell { state: Mutex::new(initial), cvar: Condvar::new() }
    }

    /// Mutate the state and wake every waiter to re-check its predicate.
    pub fn update<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        let mut guard = self.state.lock().unwrap();
        let out = f(&mut guard);
        drop(guard);
        self.cvar.notify_all();
        out
    }

    /// Observe the state without mutating it.
    pub fn read<R>(&self, f: impl FnOnce(&T) -> R) -> R {
        f(&self.state.lock().unwrap())
    }

    /// Wake all waiters without a state change (e.g. after flipping an
    /// external cancellation token the predicates consult).
    pub fn notify_all(&self) {
        self.cvar.notify_all();
    }

    /// Block until `pred` returns `Some(decision)`, re-evaluating after
    /// every [`update`](Self::update) / [`notify_all`](Self::notify_all).
    pub fn wait_until<R>(&self, mut pred: impl FnMut(&T) -> Option<R>) -> R {
        let mut guard = self.state.lock().unwrap();
        loop {
            if let Some(out) = pred(&guard) {
                return out;
            }
            guard = self.cvar.wait(guard).unwrap();
        }
    }

    /// [`wait_until`](Self::wait_until) with a deadline; `None` on timeout.
    pub fn wait_until_timeout<R>(
        &self,
        timeout: Duration,
        mut pred: impl FnMut(&T) -> Option<R>,
    ) -> Option<R> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.state.lock().unwrap();
        loop {
            if let Some(out) = pred(&guard) {
                return Some(out);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self.cvar.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
            if res.timed_out() {
                return pred(&guard);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn update_wakes_waiter() {
        let cell = Arc::new(WatchCell::new(0u64));
        let c2 = Arc::clone(&cell);
        let h = std::thread::spawn(move || c2.wait_until(|v| (*v >= 3).then_some(*v)));
        for i in 1..=3 {
            std::thread::sleep(Duration::from_millis(10));
            cell.update(|v| *v = i);
        }
        assert_eq!(h.join().unwrap(), 3);
    }

    #[test]
    fn wait_returns_decision_value() {
        let cell = WatchCell::new(vec![1, 2, 3]);
        let sum: i32 = cell.wait_until(|v| Some(v.iter().sum()));
        assert_eq!(sum, 6);
    }

    #[test]
    fn timeout_expires_without_update() {
        let cell = WatchCell::new(false);
        let start = Instant::now();
        let out = cell.wait_until_timeout(Duration::from_millis(30), |v| v.then_some(()));
        assert!(out.is_none());
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn notify_all_reevaluates_external_condition() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let cell = Arc::new(WatchCell::new(()));
        let flag = Arc::new(AtomicBool::new(false));
        let (c2, f2) = (Arc::clone(&cell), Arc::clone(&flag));
        let h = std::thread::spawn(move || {
            c2.wait_until(|_| f2.load(Ordering::SeqCst).then_some(()));
        });
        std::thread::sleep(Duration::from_millis(10));
        flag.store(true, Ordering::SeqCst);
        cell.notify_all();
        h.join().unwrap();
    }

    #[test]
    fn concurrent_updates_all_observed() {
        let cell = Arc::new(WatchCell::new(0u64));
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&cell);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        c.update(|v| *v += 1);
                    }
                })
            })
            .collect();
        let reader = {
            let c = Arc::clone(&cell);
            std::thread::spawn(move || c.wait_until(|v| (*v == 400).then_some(*v)))
        };
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(reader.join().unwrap(), 400);
        assert_eq!(cell.read(|v| *v), 400);
    }
}
