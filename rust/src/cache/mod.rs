//! Prefix-reuse cache (paper §2.2's avoid-recomputation optimization,
//! service-side): the layer between the rollout service and the
//! generation engines that stops multi-turn workflows from re-prefilling
//! their whole growing transcript on every turn.
//!
//! Three parts (DESIGN.md §7):
//!
//! * [`trie`] — a token-level radix prefix trie indexing which replica
//!   holds a live KV prefix for which served transcript, with
//!   ref-counted nodes, LRU eviction under a token budget, and
//!   weight-version tagging (entries are invalidated when a new policy
//!   version is published).
//! * [`sessions`] — the parked-session store: live engine sessions kept
//!   alive between the turns of one workflow episode under TTL leases
//!   and capacity bounds; a follow-up turn claims its parked row and the
//!   engine extends it with only the delta tokens through the masked
//!   decode path.
//! * [`affinity`] — the routing decision: a follow-up turn goes to the
//!   replica holding its prefix unless that replica is quarantined,
//!   stale, or overloaded, in which case the request falls back cleanly
//!   to least-loaded routing and a cold prefill.
//!
//! Workflows opt in by threading an episode session key through
//! `SamplingArgs` (`WorkflowCtx::chat_turn`); untagged requests bypass
//! every cache path.  [`PrefixIndex`] is the service-wide handle tying
//! the three parts together and owning the telemetry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{ensure, Result};

pub mod affinity;
pub mod sessions;
pub mod trie;

pub use affinity::{AffinityPolicy, Fallback, ReplicaView, Route};
pub use sessions::{ParkedSession, RowLease, SessionPark};
pub use trie::{PrefixMatch, PrefixTrie};

/// Prefix-reuse tuning knobs (the `service.cache_*` config keys parse
/// into this; see `coordinator::config::ServiceSection`).
#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub enabled: bool,
    /// Parked engine sessions kept alive per replica (each pins one
    /// batch worth of KV memory); 0 disables parking but keeps the
    /// prefix index and affinity routing.
    pub max_parked: usize,
    /// Lease TTL on parked sessions.
    pub park_ttl: Duration,
    /// Token budget of the prefix trie (0 = unbounded).
    pub trie_tokens: usize,
    /// Minimum matched prefix before affinity beats least-loaded.
    pub min_prefix: usize,
    /// Load margin within which affinity wins (see [`AffinityPolicy`]).
    pub overload_margin: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: true,
            max_parked: 2,
            park_ttl: Duration::from_secs(120),
            trie_tokens: 1 << 16,
            min_prefix: 4,
            overload_margin: 8,
        }
    }
}

impl CacheConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        ensure!(self.min_prefix >= 1, "service.cache_min_prefix must be >= 1");
        ensure!(self.park_ttl > Duration::ZERO, "service.cache_ttl_s must be > 0");
        Ok(())
    }
}

/// Lock-free cache counters, snapshotted into service telemetry.
#[derive(Debug, Default)]
pub struct CacheMetrics {
    /// Session-tagged prompts that consulted the prefix index.
    pub lookups: AtomicU64,
    /// Lookups that matched a reusable prefix AND routed with affinity.
    pub hits: AtomicU64,
    /// Lookups with no usable prefix (none stored, too short, stale).
    pub misses: AtomicU64,
    /// Prefix tokens the index matched on hits (routing-level reuse).
    pub reused_tokens: AtomicU64,
    /// Prompt tokens that skipped re-prefill through an actual parked-
    /// session resume (engine-level; subset of `reused_tokens`).
    pub saved_prefill_tokens: AtomicU64,
    /// Parked-session resumes performed by engine replicas.
    pub resumed: AtomicU64,
    /// Sessions parked for a future turn.
    pub parked: AtomicU64,
    /// Parked sessions evicted by the capacity bound.
    pub park_evicted: AtomicU64,
    /// Parked sessions dropped by TTL expiry.
    pub park_expired: AtomicU64,
    /// Trie entries evicted by the token budget.
    pub trie_evictions: AtomicU64,
    /// Entries/sessions dropped because a newer weight version published.
    pub invalidations: AtomicU64,
    /// Matched prefixes that fell back cold (quarantined / overloaded
    /// holder); the request is still served, just without reuse.
    pub affinity_fallbacks: AtomicU64,
    /// Parked sessions moved to a healthy replica instead of falling
    /// back cold (QoS live migration, DESIGN.md §11).
    pub migrations: AtomicU64,
    /// Prefill tokens the migrations above kept reusable (the matched
    /// prefix that would otherwise have been re-prefilled cold).
    pub migration_saved_tokens: AtomicU64,
}

/// Point-in-time cache telemetry (rides on `ServiceSnapshot`).
#[derive(Debug, Clone, Default)]
pub struct CacheSnapshot {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub reused_tokens: u64,
    pub saved_prefill_tokens: u64,
    pub resumed: u64,
    pub parked: u64,
    pub park_evicted: u64,
    pub park_expired: u64,
    pub trie_evictions: u64,
    pub invalidations: u64,
    pub affinity_fallbacks: u64,
    pub migrations: u64,
    pub migration_saved_tokens: u64,
    pub trie_entries: usize,
    pub trie_tokens: usize,
}

impl CacheSnapshot {
    /// Fraction of session-tagged lookups that reused a prefix.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Uniform monitor field set (merged into the "service" role).
    pub fn monitor_fields(&self) -> Vec<(String, f64)> {
        vec![
            ("cache_hit_rate".to_string(), self.hit_rate()),
            ("cache_reused_tokens".to_string(), self.reused_tokens as f64),
            ("cache_saved_prefill_tokens".to_string(), self.saved_prefill_tokens as f64),
            ("cache_resumed".to_string(), self.resumed as f64),
            ("cache_parked".to_string(), self.parked as f64),
            ("cache_evictions".to_string(), (self.trie_evictions + self.park_evicted) as f64),
            ("cache_invalidations".to_string(), self.invalidations as f64),
            ("cache_fallbacks".to_string(), self.affinity_fallbacks as f64),
            ("cache_migrations".to_string(), self.migrations as f64),
            ("cache_migration_saved_tokens".to_string(), self.migration_saved_tokens as f64),
            ("cache_entries".to_string(), self.trie_entries as f64),
        ]
    }
}

/// Full routing decision for a session-tagged prompt.  QoS migration
/// needs more than hit/miss: *who* holds the prefix and *why* it was
/// rejected decide whether the parked session can be moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDecision {
    /// No usable prefix: serve cold on the least-loaded path.
    Miss,
    /// Pin to the prefix holder, reusing `matched` tokens.
    Affinity { replica: usize, matched: usize },
    /// A prefix of `matched` tokens exists on `holder` (produced under
    /// `version`) but the holder was rejected for `reason`; see
    /// `qos::migratable` for which reasons allow moving the session.
    Cold { holder: usize, matched: usize, version: u64, reason: Fallback },
}

/// The service-wide prefix index: trie + affinity policy + telemetry.
/// Shared between the router (`RolloutService::chat`), the per-replica
/// workers (entry admission on completion, parked-session accounting)
/// and the weight-sync path (invalidation-on-publish).
pub struct PrefixIndex {
    cfg: CacheConfig,
    trie: Mutex<PrefixTrie>,
    policy: AffinityPolicy,
    pub metrics: CacheMetrics,
}

impl PrefixIndex {
    pub fn new(cfg: CacheConfig) -> PrefixIndex {
        let policy =
            AffinityPolicy { min_prefix: cfg.min_prefix, overload_margin: cfg.overload_margin };
        let trie = Mutex::new(PrefixTrie::new(cfg.trie_tokens));
        PrefixIndex { cfg, trie, policy, metrics: CacheMetrics::default() }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Routing decision for a session-tagged prompt: `Some(replica)`
    /// pins the request to its prefix holder, `None` means the normal
    /// least-loaded path (miss or clean fallback).
    pub fn route(&self, prompt: &[i32], replicas: &[ReplicaView]) -> Option<usize> {
        self.route_scored(prompt, replicas).0
    }

    /// [`route`](Self::route) plus the matched prefix length: how many
    /// transcript tokens the affinity hit reuses (0 on any cold route).
    /// The service stamps the score onto the row job so replicas can
    /// emit resume-vs-cold-prefill spans.
    pub fn route_scored(
        &self,
        prompt: &[i32],
        replicas: &[ReplicaView],
    ) -> (Option<usize>, usize) {
        match self.route_decision(prompt, replicas) {
            RouteDecision::Affinity { replica, matched } => (Some(replica), matched),
            _ => (None, 0),
        }
    }

    /// The full routing decision for a session-tagged prompt.  Same
    /// counters as [`route_scored`](Self::route_scored) (which wraps
    /// this), but a `Cold` fallback keeps the holder / matched length /
    /// version visible so the QoS plane can migrate the parked session
    /// instead of re-prefilling (DESIGN.md §11).
    pub fn route_decision(&self, prompt: &[i32], replicas: &[ReplicaView]) -> RouteDecision {
        self.metrics.lookups.fetch_add(1, Ordering::Relaxed);
        let mut trie = self.trie.lock().unwrap();
        let Some(m) = trie.lookup(prompt) else {
            self.metrics.misses.fetch_add(1, Ordering::Relaxed);
            return RouteDecision::Miss;
        };
        match self.policy.decide(m.len, m.version, m.replica, replicas) {
            Route::Affinity(id) => {
                self.metrics.hits.fetch_add(1, Ordering::Relaxed);
                self.metrics.reused_tokens.fetch_add(m.len as u64, Ordering::Relaxed);
                RouteDecision::Affinity { replica: id, matched: m.len }
            }
            Route::Cold(Fallback::ShortPrefix) => {
                self.metrics.misses.fetch_add(1, Ordering::Relaxed);
                RouteDecision::Miss
            }
            Route::Cold(Fallback::Stale) | Route::Cold(Fallback::Unknown) => {
                // the stored prefix can never be reused: drop it now
                trie.remove(&prompt[..m.len]);
                self.metrics.invalidations.fetch_add(1, Ordering::Relaxed);
                self.metrics.misses.fetch_add(1, Ordering::Relaxed);
                RouteDecision::Miss
            }
            Route::Cold(reason) => {
                // quarantined / overloaded holder: the prefix stays (the
                // replica may heal), the request goes cold — unless the
                // QoS plane migrates the session
                self.metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
                self.metrics.misses.fetch_add(1, Ordering::Relaxed);
                RouteDecision::Cold {
                    holder: m.replica,
                    matched: m.len,
                    version: m.version,
                    reason,
                }
            }
        }
    }

    /// Record a served transcript as a reusable prefix on `replica`.
    pub fn admit(&self, tokens: &[i32], replica: usize, version: u64) {
        let mut trie = self.trie.lock().unwrap();
        trie.insert(tokens, replica, version);
        let evicted = trie.enforce_budget();
        if evicted > 0 {
            self.metrics.trie_evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        }
    }

    /// Invalidation-on-publish: drop every prefix produced under a
    /// weight version older than `version`.
    pub fn invalidate_below(&self, version: u64) {
        let n = self.trie.lock().unwrap().invalidate_below(version);
        if n > 0 {
            self.metrics.invalidations.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    // -- parked-session accounting (engine replicas report here) ------

    pub fn note_resumed(&self, saved_tokens: usize) {
        self.metrics.resumed.fetch_add(1, Ordering::Relaxed);
        self.metrics.saved_prefill_tokens.fetch_add(saved_tokens as u64, Ordering::Relaxed);
    }

    pub fn note_parked(&self, evicted: usize) {
        self.metrics.parked.fetch_add(1, Ordering::Relaxed);
        if evicted > 0 {
            self.metrics.park_evicted.fetch_add(evicted as u64, Ordering::Relaxed);
        }
    }

    pub fn note_park_expired(&self, expired: usize) {
        if expired > 0 {
            self.metrics.park_expired.fetch_add(expired as u64, Ordering::Relaxed);
        }
    }

    pub fn note_park_invalidated(&self, dropped: usize) {
        if dropped > 0 {
            self.metrics.invalidations.fetch_add(dropped as u64, Ordering::Relaxed);
        }
    }

    /// Account a live session migration and rebind the stored prefix to
    /// its new holder, so subsequent turns route straight to the
    /// destination (`insert` on an existing path refreshes the entry in
    /// place; no tokens are re-stored).
    pub fn note_migrated(&self, prefix: &[i32], dest: usize, version: u64, saved_tokens: usize) {
        self.metrics.migrations.fetch_add(1, Ordering::Relaxed);
        self.metrics.migration_saved_tokens.fetch_add(saved_tokens as u64, Ordering::Relaxed);
        self.trie.lock().unwrap().insert(prefix, dest, version);
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        let (trie_entries, trie_tokens) = {
            let trie = self.trie.lock().unwrap();
            (trie.entries(), trie.stored_tokens())
        };
        let m = &self.metrics;
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        CacheSnapshot {
            lookups: load(&m.lookups),
            hits: load(&m.hits),
            misses: load(&m.misses),
            reused_tokens: load(&m.reused_tokens),
            saved_prefill_tokens: load(&m.saved_prefill_tokens),
            resumed: load(&m.resumed),
            parked: load(&m.parked),
            park_evicted: load(&m.park_evicted),
            park_expired: load(&m.park_expired),
            trie_evictions: load(&m.trie_evictions),
            invalidations: load(&m.invalidations),
            affinity_fallbacks: load(&m.affinity_fallbacks),
            migrations: load(&m.migrations),
            migration_saved_tokens: load(&m.migration_saved_tokens),
            trie_entries,
            trie_tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(n: usize) -> Vec<ReplicaView> {
        (0..n).map(|id| ReplicaView { id, load: 0, ready: true, version: 0 }).collect()
    }

    #[test]
    fn route_miss_then_admit_then_hit() {
        let idx = PrefixIndex::new(CacheConfig { min_prefix: 2, ..Default::default() });
        let prompt = vec![1, 2, 3, 4];
        assert_eq!(idx.route(&prompt, &views(2)), None);
        idx.admit(&prompt, 1, 0);
        let mut next = prompt.clone();
        next.extend([5, 6]);
        assert_eq!(idx.route(&next, &views(2)), Some(1));
        let snap = idx.snapshot();
        assert_eq!((snap.lookups, snap.hits, snap.misses), (2, 1, 1));
        assert_eq!(snap.reused_tokens, 4);
        assert!(snap.hit_rate() > 0.49 && snap.hit_rate() < 0.51);
    }

    #[test]
    fn stale_entries_are_dropped_at_lookup() {
        let idx = PrefixIndex::new(CacheConfig { min_prefix: 2, ..Default::default() });
        idx.admit(&[1, 2, 3], 0, 0);
        // replica now serves version 5: the stored prefix is stale
        let replicas = vec![ReplicaView { id: 0, load: 0, ready: true, version: 5 }];
        assert_eq!(idx.route(&[1, 2, 3, 4], &replicas), None);
        let snap = idx.snapshot();
        assert_eq!(snap.invalidations, 1);
        assert_eq!(snap.trie_entries, 0, "stale entry removed");
    }

    #[test]
    fn quarantined_holder_falls_back_but_keeps_entry() {
        let idx = PrefixIndex::new(CacheConfig { min_prefix: 2, ..Default::default() });
        idx.admit(&[1, 2, 3], 0, 0);
        let mut replicas = views(2);
        replicas[0].ready = false;
        assert_eq!(idx.route(&[1, 2, 3, 4], &replicas), None);
        let snap = idx.snapshot();
        assert_eq!(snap.affinity_fallbacks, 1);
        assert_eq!(snap.trie_entries, 1, "entry kept for when the holder heals");
        // holder heals: affinity resumes
        assert_eq!(idx.route(&[1, 2, 3, 4], &views(2)), Some(0));
    }

    #[test]
    fn invalidate_below_clears_published_over_versions() {
        let idx = PrefixIndex::new(CacheConfig::default());
        idx.admit(&[1, 2, 3, 4], 0, 1);
        idx.admit(&[5, 6, 7, 8], 0, 2);
        idx.invalidate_below(2);
        let snap = idx.snapshot();
        assert_eq!(snap.trie_entries, 1);
        assert_eq!(snap.invalidations, 1);
    }

    #[test]
    fn budget_evictions_surface_in_metrics() {
        let idx = PrefixIndex::new(CacheConfig { trie_tokens: 4, ..Default::default() });
        idx.admit(&[1, 2, 3, 4], 0, 0);
        idx.admit(&[5, 6, 7, 8], 0, 0);
        let snap = idx.snapshot();
        assert!(snap.trie_evictions >= 1, "{snap:?}");
        assert!(snap.trie_tokens <= 4);
    }

    #[test]
    fn route_decision_surfaces_holder_on_cold_fallback() {
        let idx = PrefixIndex::new(CacheConfig { min_prefix: 2, ..Default::default() });
        idx.admit(&[1, 2, 3, 4], 0, 0);
        let mut replicas = views(2);
        replicas[0].ready = false;
        let d = idx.route_decision(&[1, 2, 3, 4, 5], &replicas);
        assert_eq!(
            d,
            RouteDecision::Cold {
                holder: 0,
                matched: 4,
                version: 0,
                reason: Fallback::Quarantined
            }
        );
        // the wrapper maps the same decision to the legacy shape
        assert_eq!(idx.route_scored(&[1, 2, 3, 4, 5], &replicas), (None, 0));
        assert_eq!(idx.snapshot().affinity_fallbacks, 2);
    }

    #[test]
    fn note_migrated_rebinds_the_prefix_holder() {
        let idx = PrefixIndex::new(CacheConfig { min_prefix: 2, ..Default::default() });
        idx.admit(&[1, 2, 3, 4], 0, 0);
        idx.note_migrated(&[1, 2, 3, 4], 1, 0, 4);
        // subsequent turns route straight to the destination
        assert_eq!(idx.route(&[1, 2, 3, 4, 5], &views(2)), Some(1));
        let snap = idx.snapshot();
        assert_eq!(snap.migrations, 1);
        assert_eq!(snap.migration_saved_tokens, 4);
        assert_eq!(snap.trie_entries, 1, "rebind does not duplicate the entry");
        assert!(snap.monitor_fields().iter().any(|(n, _)| n == "cache_migrations"));
    }

    #[test]
    fn monitor_fields_cover_the_headline_counters() {
        let idx = PrefixIndex::new(CacheConfig::default());
        let fields = idx.snapshot().monitor_fields();
        for key in ["cache_hit_rate", "cache_saved_prefill_tokens", "cache_parked"] {
            assert!(fields.iter().any(|(n, _)| n == key), "missing {key}");
        }
    }
}
