//! Parked-session store: keeps live engine sessions (KV caches + row
//! cursors) alive between the turns of multi-turn workflow episodes.
//!
//! A replica parks the whole batch session at the end of a serve, with a
//! [`RowLease`] per row naming the episode key and the transcript whose
//! KV the row holds.  A follow-up turn whose prompt extends a leased
//! transcript *claims* the session and resumes it by feeding only the
//! delta tokens through the masked decode path, skipping the re-prefill
//! of the shared prefix.  Leases expire after a TTL, the store is
//! capacity-bounded (a parked session pins real KV memory), and parked
//! state is invalidated when a newer weight version is published — a
//! resumed KV must have been produced by exactly the weights that will
//! continue it.
//!
//! The store is generic over the session payload so the lease/TTL/
//! capacity machinery is unit-testable without a runtime.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One row's parked episode: the session key the workflow threads
/// through its turns and the transcript whose KV the row holds.
#[derive(Debug, Clone)]
pub struct RowLease {
    pub key: u64,
    pub transcript: Vec<i32>,
}

impl RowLease {
    /// Does `prompt` continue this lease's transcript (and leave room
    /// to sample at least one token within `cache_len`)?  THE resume
    /// predicate: claim-time and placement-time checks both call it.
    pub fn resumes(&self, key: u64, prompt: &[i32], cache_len: usize) -> bool {
        self.key == key
            && prompt.len() + 1 < cache_len
            && prompt.len() >= self.transcript.len()
            && prompt[..self.transcript.len()] == self.transcript[..]
    }
}

/// A parked engine session: payload + per-row leases + lease expiry.
pub struct ParkedSession<S> {
    pub state: S,
    /// Weight version every byte of this session's KV was produced
    /// under (sessions spanning a mid-run sync are never parked).
    pub version: u64,
    pub rows: Vec<Option<RowLease>>,
    pub expires: Instant,
}

impl<S> ParkedSession<S> {
    /// Does `prompt` continue row `r`'s leased transcript?  Delegates
    /// to [`RowLease::resumes`].
    pub fn row_resumes(&self, r: usize, key: u64, prompt: &[i32], cache_len: usize) -> bool {
        self.rows[r].as_ref().is_some_and(|l| l.resumes(key, prompt, cache_len))
    }
}

/// Capacity-bounded, TTL-leased MRU store of parked sessions.
pub struct SessionPark<S> {
    capacity: usize,
    ttl: Duration,
    parked: VecDeque<ParkedSession<S>>,
}

impl<S> SessionPark<S> {
    pub fn new(capacity: usize, ttl: Duration) -> SessionPark<S> {
        SessionPark { capacity, ttl, parked: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.parked.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parked.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drop sessions whose lease expired; returns how many.
    pub fn sweep(&mut self, now: Instant) -> usize {
        let before = self.parked.len();
        self.parked.retain(|p| p.expires > now);
        before - self.parked.len()
    }

    /// Park a session under a fresh lease.  Returns how many sessions
    /// were evicted to respect the capacity bound (including this one,
    /// immediately, when capacity is 0).
    pub fn park(
        &mut self,
        state: S,
        version: u64,
        rows: Vec<Option<RowLease>>,
        now: Instant,
    ) -> usize {
        self.parked.push_front(ParkedSession { state, version, rows, expires: now + self.ttl });
        let mut evicted = 0;
        while self.parked.len() > self.capacity {
            self.parked.pop_back();
            evicted += 1;
        }
        evicted
    }

    /// Remove and return the most recently parked session satisfying
    /// `pred` (a claimed session is owned by the caller; park it again
    /// after the turn).
    pub fn claim(&mut self, pred: impl Fn(&ParkedSession<S>) -> bool) -> Option<ParkedSession<S>> {
        let pos = self.parked.iter().position(pred)?;
        self.parked.remove(pos)
    }

    /// Adopt a session extracted from another replica's park (QoS
    /// migration, DESIGN.md §11): the session keeps its leases, weight
    /// version and remaining TTL — only the holder changes.  Returns
    /// how many sessions were evicted to respect the capacity bound
    /// (including this one, immediately, when capacity is 0).
    pub fn adopt(&mut self, parked: ParkedSession<S>) -> usize {
        self.parked.push_front(parked);
        let mut evicted = 0;
        while self.parked.len() > self.capacity {
            self.parked.pop_back();
            evicted += 1;
        }
        evicted
    }

    /// Drop parked sessions whose weights are older than `version`
    /// (invalidation-on-publish); returns how many.
    pub fn invalidate_below(&mut self, version: u64) -> usize {
        let before = self.parked.len();
        self.parked.retain(|p| p.version >= version);
        before - self.parked.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lease(key: u64, transcript: &[i32]) -> Option<RowLease> {
        Some(RowLease { key, transcript: transcript.to_vec() })
    }

    #[test]
    fn park_claim_roundtrip_and_prefix_check() {
        let mut park: SessionPark<u32> = SessionPark::new(2, Duration::from_secs(60));
        let now = Instant::now();
        assert_eq!(park.park(7, 1, vec![lease(42, &[1, 2, 3]), None], now), 0);
        let claimed = park
            .claim(|p| p.version == 1 && p.row_resumes(0, 42, &[1, 2, 3, 4], 64))
            .expect("claimable");
        assert_eq!(claimed.state, 7);
        assert!(park.is_empty(), "claim removes the session");
        // wrong key / diverging prompt / short prompt never resume
        assert!(!claimed.row_resumes(0, 43, &[1, 2, 3, 4], 64));
        assert!(!claimed.row_resumes(0, 42, &[1, 9, 3, 4], 64));
        assert!(!claimed.row_resumes(0, 42, &[1, 2], 64));
        assert!(!claimed.row_resumes(1, 42, &[1, 2, 3, 4], 64), "unleased row");
        // a prompt that cannot fit the cache falls back cold
        assert!(!claimed.row_resumes(0, 42, &[1, 2, 3, 4], 4));
        // exact-transcript prompt (turn retry) resumes with empty delta
        assert!(claimed.row_resumes(0, 42, &[1, 2, 3], 64));
    }

    #[test]
    fn capacity_evicts_oldest_first() {
        let mut park: SessionPark<u32> = SessionPark::new(2, Duration::from_secs(60));
        let now = Instant::now();
        assert_eq!(park.park(1, 1, vec![lease(1, &[1])], now), 0);
        assert_eq!(park.park(2, 1, vec![lease(2, &[2])], now), 0);
        assert_eq!(park.park(3, 1, vec![lease(3, &[3])], now), 1);
        assert_eq!(park.len(), 2);
        assert!(park.claim(|p| p.row_resumes(0, 1, &[1, 9], 64)).is_none(), "oldest evicted");
        assert!(park.claim(|p| p.row_resumes(0, 3, &[3, 9], 64)).is_some());
    }

    #[test]
    fn zero_capacity_never_parks() {
        let mut park: SessionPark<u32> = SessionPark::new(0, Duration::from_secs(60));
        assert_eq!(park.park(1, 1, vec![], Instant::now()), 1);
        assert!(park.is_empty());
    }

    #[test]
    fn leases_expire_on_sweep() {
        let mut park: SessionPark<u32> = SessionPark::new(4, Duration::from_millis(5));
        let now = Instant::now();
        park.park(1, 1, vec![lease(1, &[1])], now);
        assert_eq!(park.sweep(now), 0, "fresh lease survives");
        assert_eq!(park.sweep(now + Duration::from_millis(10)), 1);
        assert!(park.is_empty());
    }

    #[test]
    fn invalidate_below_drops_stale_weights() {
        let mut park: SessionPark<u32> = SessionPark::new(4, Duration::from_secs(60));
        let now = Instant::now();
        park.park(1, 1, vec![], now);
        park.park(2, 2, vec![], now);
        park.park(3, 3, vec![], now);
        assert_eq!(park.invalidate_below(3), 2);
        assert_eq!(park.len(), 1);
        assert!(park.claim(|p| p.version == 3).is_some());
    }

    #[test]
    fn adopt_preserves_leases_and_respects_capacity() {
        let now = Instant::now();
        let mut src: SessionPark<u32> = SessionPark::new(2, Duration::from_secs(60));
        src.park(7, 3, vec![lease(42, &[1, 2, 3])], now);
        let moved = src.claim(|p| p.row_resumes(0, 42, &[1, 2, 3, 4], 64)).unwrap();
        let mut dst: SessionPark<u32> = SessionPark::new(1, Duration::from_secs(60));
        assert_eq!(dst.adopt(moved), 0);
        // the adopted session resumes on the destination exactly as it
        // would have on the source: same lease, same version
        let got = dst.claim(|p| p.version == 3 && p.row_resumes(0, 42, &[1, 2, 3, 4], 64));
        assert_eq!(got.map(|p| p.state), Some(7));
        // capacity still binds on adopt
        dst.park(1, 3, vec![lease(1, &[1])], now);
        let extra = ParkedSession {
            state: 2,
            version: 3,
            rows: vec![lease(2, &[2])],
            expires: now + Duration::from_secs(60),
        };
        assert_eq!(dst.adopt(extra), 1);
        assert_eq!(dst.len(), 1);
    }

    #[test]
    fn claim_prefers_most_recent() {
        let mut park: SessionPark<u32> = SessionPark::new(4, Duration::from_secs(60));
        let now = Instant::now();
        park.park(1, 1, vec![lease(9, &[1])], now);
        park.park(2, 1, vec![lease(9, &[1])], now);
        let got = park.claim(|p| p.row_resumes(0, 9, &[1, 2], 64)).unwrap();
        assert_eq!(got.state, 2, "MRU order");
    }
}
