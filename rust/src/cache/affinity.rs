//! Affinity routing: send a follow-up turn to the replica that holds
//! its KV prefix — unless that replica is quarantined, serving stale
//! weights, or meaningfully more loaded than its peers, in which case
//! the request falls back cleanly to the normal least-loaded path (a
//! cold prefill is always correct; affinity is only ever a speedup).

/// A routing-time view of one replica (decoupled from service types so
/// the decision is unit-testable).
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    pub id: usize,
    /// Queued + in-session rows (the least-loaded routing metric).
    pub load: usize,
    /// Circuit breaker closed?
    pub ready: bool,
    /// Current weight version of the replica.
    pub version: u64,
}

/// Why an affinity candidate was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fallback {
    /// Matched prefix shorter than `min_prefix`: not worth pinning.
    ShortPrefix,
    /// The prefix-holding replica is quarantined.
    Quarantined,
    /// The prefix was produced under different weights than the replica
    /// now serves; resuming it would be incorrect.
    Stale,
    /// The replica is too far above the least-loaded peer.
    Overloaded,
    /// The replica is no longer in the pool.
    Unknown,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Pin the request to this replica.
    Affinity(usize),
    /// Use the normal least-loaded path.
    Cold(Fallback),
}

/// The affinity-vs-least-loaded tradeoff knobs.
#[derive(Debug, Clone, Copy)]
pub struct AffinityPolicy {
    /// Minimum matched prefix tokens before affinity beats least-loaded.
    pub min_prefix: usize,
    /// Affinity wins while the preferred replica's load is within this
    /// margin of the least-loaded ready peer.
    pub overload_margin: usize,
}

impl AffinityPolicy {
    /// Decide where a request whose prompt matched `matched` prefix
    /// tokens (held by `preferred`, produced under `version`) should go.
    pub fn decide(
        &self,
        matched: usize,
        version: u64,
        preferred: usize,
        replicas: &[ReplicaView],
    ) -> Route {
        if matched < self.min_prefix.max(1) {
            return Route::Cold(Fallback::ShortPrefix);
        }
        let Some(p) = replicas.iter().find(|r| r.id == preferred) else {
            return Route::Cold(Fallback::Unknown);
        };
        if !p.ready {
            return Route::Cold(Fallback::Quarantined);
        }
        if p.version != version {
            return Route::Cold(Fallback::Stale);
        }
        let min_ready = replicas.iter().filter(|r| r.ready).map(|r| r.load).min().unwrap_or(0);
        if p.load > min_ready + self.overload_margin {
            return Route::Cold(Fallback::Overloaded);
        }
        Route::Affinity(p.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(loads: &[(usize, bool)]) -> Vec<ReplicaView> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &(load, ready))| ReplicaView { id, load, ready, version: 1 })
            .collect()
    }

    const POLICY: AffinityPolicy = AffinityPolicy { min_prefix: 4, overload_margin: 8 };

    #[test]
    fn affinity_wins_within_margin() {
        let replicas = pool(&[(10, true), (4, true)]);
        assert_eq!(POLICY.decide(16, 1, 0, &replicas), Route::Affinity(0));
    }

    #[test]
    fn short_prefixes_stay_least_loaded() {
        let replicas = pool(&[(0, true), (0, true)]);
        assert_eq!(POLICY.decide(3, 1, 0, &replicas), Route::Cold(Fallback::ShortPrefix));
        assert_eq!(POLICY.decide(4, 1, 0, &replicas), Route::Affinity(0));
    }

    #[test]
    fn quarantined_replica_falls_back() {
        let replicas = pool(&[(0, false), (5, true)]);
        assert_eq!(POLICY.decide(16, 1, 0, &replicas), Route::Cold(Fallback::Quarantined));
    }

    #[test]
    fn overload_beyond_margin_falls_back() {
        let replicas = pool(&[(13, true), (4, true)]);
        assert_eq!(POLICY.decide(16, 1, 0, &replicas), Route::Cold(Fallback::Overloaded));
        // exactly at the margin still pins
        let replicas = pool(&[(12, true), (4, true)]);
        assert_eq!(POLICY.decide(16, 1, 0, &replicas), Route::Affinity(0));
    }

    #[test]
    fn stale_prefix_falls_back() {
        let mut replicas = pool(&[(0, true)]);
        replicas[0].version = 2;
        assert_eq!(POLICY.decide(16, 1, 0, &replicas), Route::Cold(Fallback::Stale));
    }

    #[test]
    fn unknown_replica_falls_back() {
        let replicas = pool(&[(0, true)]);
        assert_eq!(POLICY.decide(16, 1, 9, &replicas), Route::Cold(Fallback::Unknown));
    }
}
