//! Token-level radix prefix trie: the service-wide index of which
//! replica holds a live KV prefix for which token sequence.
//!
//! Entries are full served transcripts (prompt + generated tokens),
//! inserted when a session-tagged row completes and looked up by the
//! next turn's prompt: the longest stored sequence that is a *prefix*
//! of the prompt names the replica whose parked session can be resumed
//! by feeding only the delta tokens.  Edges are path-compressed, nodes
//! are ref-counted (shared prefixes survive until every sequence using
//! them is gone), entries are tagged with the weight version that
//! produced their KV (stale versions are invalidated on publish), and
//! a token budget is enforced by least-recently-touched eviction.

use std::collections::HashMap;

/// Result of a longest-prefix lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixMatch {
    /// Matched prefix length in tokens (a full stored sequence).
    pub len: usize,
    /// Replica whose parked session holds this prefix.
    pub replica: usize,
    /// Weight version the prefix KV was produced under.
    pub version: u64,
}

#[derive(Debug)]
struct Entry {
    replica: usize,
    version: u64,
    /// Logical-clock timestamp of the last insert/lookup touch (LRU).
    touched: u64,
}

#[derive(Debug)]
struct Node {
    /// Compressed edge label from the parent (empty at the root).
    edge: Vec<i32>,
    parent: usize,
    /// Children keyed by the first token of their edge.
    children: HashMap<i32, usize>,
    entry: Option<Entry>,
    /// Entries at or below this node; a node is pruned at zero.
    refs: usize,
}

pub struct PrefixTrie {
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// Max stored tokens (sum of edge labels); 0 = unbounded.
    budget: usize,
    stored_tokens: usize,
    entries: usize,
    clock: u64,
}

const ROOT: usize = 0;

impl PrefixTrie {
    pub fn new(budget: usize) -> PrefixTrie {
        PrefixTrie {
            nodes: vec![Node {
                edge: Vec::new(),
                parent: ROOT,
                children: HashMap::new(),
                entry: None,
                refs: 0,
            }],
            free: Vec::new(),
            budget,
            stored_tokens: 0,
            entries: 0,
            clock: 0,
        }
    }

    pub fn entries(&self) -> usize {
        self.entries
    }

    pub fn stored_tokens(&self) -> usize {
        self.stored_tokens
    }

    fn alloc(&mut self, node: Node) -> usize {
        match self.free.pop() {
            Some(id) => {
                self.nodes[id] = node;
                id
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Insert (or refresh) `tokens` as a stored sequence held by
    /// `replica` under weight `version`.  Returns the number of tokens
    /// newly stored (0 when the path already existed).
    pub fn insert(&mut self, tokens: &[i32], replica: usize, version: u64) -> usize {
        if tokens.is_empty() {
            return 0;
        }
        let now = self.tick();
        let mut node = ROOT;
        let mut i = 0usize;
        let mut added = 0usize;
        while i < tokens.len() {
            let first = tokens[i];
            match self.nodes[node].children.get(&first).copied() {
                None => {
                    // no child on this token: hang the whole remainder here
                    let rest = tokens[i..].to_vec();
                    added += rest.len();
                    self.stored_tokens += rest.len();
                    let child = self.alloc(Node {
                        edge: rest,
                        parent: node,
                        children: HashMap::new(),
                        entry: None,
                        refs: 0,
                    });
                    self.nodes[node].children.insert(first, child);
                    node = child;
                    i = tokens.len();
                }
                Some(child) => {
                    let common = {
                        let edge = &self.nodes[child].edge;
                        let max = edge.len().min(tokens.len() - i);
                        let mut k = 0;
                        while k < max && edge[k] == tokens[i + k] {
                            k += 1;
                        }
                        k
                    };
                    if common == self.nodes[child].edge.len() {
                        // full edge matched: descend
                        node = child;
                        i += common;
                    } else {
                        // split the edge at `common`: mid takes the head,
                        // the old child keeps the tail
                        let tail = self.nodes[child].edge.split_off(common);
                        let head = std::mem::take(&mut self.nodes[child].edge);
                        let child_refs = self.nodes[child].refs;
                        let mid = self.alloc(Node {
                            edge: head,
                            parent: node,
                            children: HashMap::new(),
                            entry: None,
                            refs: child_refs,
                        });
                        self.nodes[child].edge = tail;
                        self.nodes[child].parent = mid;
                        let tail_first = self.nodes[child].edge[0];
                        self.nodes[mid].children.insert(tail_first, child);
                        self.nodes[node].children.insert(first, mid);
                        node = mid;
                        i += common;
                        // the loop continues: either i == tokens.len()
                        // (entry lands on mid) or a fresh branch hangs
                        // off mid on the next iteration
                    }
                }
            }
        }
        // place / refresh the entry at `node`
        if let Some(e) = &mut self.nodes[node].entry {
            e.replica = replica;
            e.version = version;
            e.touched = now;
        } else {
            self.nodes[node].entry = Some(Entry { replica, version, touched: now });
            self.entries += 1;
            // new entry: bump refs on the whole path (node up to root)
            let mut n = node;
            loop {
                self.nodes[n].refs += 1;
                if n == ROOT {
                    break;
                }
                n = self.nodes[n].parent;
            }
        }
        added
    }

    /// Longest stored sequence that is a prefix of `tokens`; touches the
    /// match for LRU purposes.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<PrefixMatch> {
        let mut node = ROOT;
        let mut i = 0usize;
        let mut best: Option<(usize, usize)> = None; // (node, len)
        if self.nodes[ROOT].entry.is_some() {
            best = Some((ROOT, 0));
        }
        while i < tokens.len() {
            let Some(&child) = self.nodes[node].children.get(&tokens[i]) else {
                break;
            };
            let edge = &self.nodes[child].edge;
            if tokens.len() - i < edge.len() || edge[..] != tokens[i..i + edge.len()] {
                // query ends inside the edge or diverges: the stored
                // sequences below are longer than / different from the
                // query, so they cannot be resumed as its prefix
                break;
            }
            i += edge.len();
            node = child;
            if self.nodes[node].entry.is_some() {
                best = Some((node, i));
            }
        }
        let (node, len) = best?;
        let now = self.tick();
        let e = self.nodes[node].entry.as_mut().expect("best carries an entry");
        e.touched = now;
        Some(PrefixMatch { len, replica: e.replica, version: e.version })
    }

    /// Locate the node holding an entry for exactly `tokens`.
    fn find_exact(&self, tokens: &[i32]) -> Option<usize> {
        let mut node = ROOT;
        let mut i = 0usize;
        while i < tokens.len() {
            let &child = self.nodes[node].children.get(&tokens[i])?;
            let edge = &self.nodes[child].edge;
            if tokens.len() - i < edge.len() || edge[..] != tokens[i..i + edge.len()] {
                return None;
            }
            i += edge.len();
            node = child;
        }
        self.nodes[node].entry.as_ref().map(|_| node)
    }

    /// Remove the entry stored for exactly `tokens` (prefix entries of
    /// other sequences survive through their ref counts).
    pub fn remove(&mut self, tokens: &[i32]) -> bool {
        match self.find_exact(tokens) {
            Some(node) => {
                self.remove_entry_at(node);
                true
            }
            None => false,
        }
    }

    /// Drop the entry at `node`, release refs along its path, and prune
    /// nodes that no longer back any entry.
    fn remove_entry_at(&mut self, node: usize) {
        if self.nodes[node].entry.take().is_none() {
            return;
        }
        self.entries -= 1;
        let mut n = node;
        loop {
            self.nodes[n].refs -= 1;
            if n == ROOT {
                break;
            }
            n = self.nodes[n].parent;
        }
        // prune upward from the entry's node: zero-ref nodes back no
        // entries below, so they have no children left either (the
        // children check is defensive)
        let mut n = node;
        while n != ROOT && self.nodes[n].refs == 0 && self.nodes[n].children.is_empty() {
            let parent = self.nodes[n].parent;
            let first = self.nodes[n].edge[0];
            self.nodes[parent].children.remove(&first);
            self.stored_tokens -= self.nodes[n].edge.len();
            self.nodes[n].edge = Vec::new();
            self.nodes[n].children = HashMap::new();
            self.free.push(n);
            n = parent;
        }
    }

    /// Evict the least-recently-touched entry.  Returns false when empty.
    pub fn evict_lru(&mut self) -> bool {
        let mut victim: Option<(usize, u64)> = None;
        for (id, node) in self.nodes.iter().enumerate() {
            if let Some(e) = &node.entry {
                let older = match victim {
                    Some((_, t)) => e.touched < t,
                    None => true,
                };
                if older {
                    victim = Some((id, e.touched));
                }
            }
        }
        match victim {
            Some((id, _)) => {
                self.remove_entry_at(id);
                true
            }
            None => false,
        }
    }

    /// Evict LRU entries until the stored-token budget is respected;
    /// returns how many entries were evicted.
    pub fn enforce_budget(&mut self) -> usize {
        if self.budget == 0 {
            return 0;
        }
        let mut evicted = 0;
        while self.stored_tokens > self.budget && self.evict_lru() {
            evicted += 1;
        }
        evicted
    }

    /// Drop every entry produced under a weight version older than
    /// `version` (invalidation-on-publish); returns how many.
    pub fn invalidate_below(&mut self, version: u64) -> usize {
        let stale: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(id, n)| match &n.entry {
                Some(e) if e.version < version => Some(id),
                _ => None,
            })
            .collect();
        let count = stale.len();
        for id in stale {
            self.remove_entry_at(id);
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_longest_prefix_lookup() {
        let mut t = PrefixTrie::new(0);
        assert_eq!(t.insert(&[1, 2, 3], 0, 1), 3);
        assert_eq!(t.insert(&[1, 2, 3, 4, 5], 1, 1), 2);
        assert_eq!(t.entries(), 2);
        assert_eq!(t.stored_tokens(), 5);
        // query extending the longest entry matches the whole sequence
        let m = t.lookup(&[1, 2, 3, 4, 5, 6, 7]).unwrap();
        assert_eq!((m.len, m.replica), (5, 1));
        // query ending between entries matches the shorter one
        let m = t.lookup(&[1, 2, 3, 4]).unwrap();
        assert_eq!((m.len, m.replica), (3, 0));
        // diverging query still reuses the stored prefix entry
        let m = t.lookup(&[1, 2, 3, 9]).unwrap();
        assert_eq!(m.len, 3);
        // no entry is a prefix of this
        assert!(t.lookup(&[2, 2, 2]).is_none());
        assert!(t.lookup(&[1, 2]).is_none(), "mid-edge is not a stored sequence");
    }

    #[test]
    fn edge_split_preserves_both_sequences() {
        let mut t = PrefixTrie::new(0);
        t.insert(&[1, 2, 3, 4], 0, 1);
        // shares [1, 2] then diverges: splits the compressed edge
        t.insert(&[1, 2, 9], 1, 1);
        assert_eq!(t.stored_tokens(), 5, "shared prefix stored once");
        assert_eq!(t.lookup(&[1, 2, 3, 4, 5]).unwrap().len, 4);
        assert_eq!(t.lookup(&[1, 2, 9, 9]).unwrap().replica, 1);
        // an entry exactly at the split point
        t.insert(&[1, 2], 2, 1);
        assert_eq!(t.lookup(&[1, 2, 8]).unwrap().replica, 2);
        assert_eq!(t.stored_tokens(), 5);
        assert_eq!(t.entries(), 3);
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut t = PrefixTrie::new(0);
        t.insert(&[1, 2, 3], 0, 1);
        assert_eq!(t.insert(&[1, 2, 3], 4, 2), 0);
        assert_eq!(t.entries(), 1);
        let m = t.lookup(&[1, 2, 3]).unwrap();
        assert_eq!((m.replica, m.version), (4, 2));
    }

    #[test]
    fn remove_prunes_but_keeps_shared_prefixes() {
        let mut t = PrefixTrie::new(0);
        t.insert(&[1, 2, 3], 0, 1);
        t.insert(&[1, 2, 3, 4, 5], 0, 1);
        assert!(t.remove(&[1, 2, 3, 4, 5]));
        assert!(!t.remove(&[1, 2, 3, 4, 5]), "already gone");
        assert_eq!(t.entries(), 1);
        assert_eq!(t.stored_tokens(), 3, "suffix pruned, shared prefix kept");
        assert_eq!(t.lookup(&[1, 2, 3, 4, 5]).unwrap().len, 3);
        assert!(t.remove(&[1, 2, 3]));
        assert_eq!((t.entries(), t.stored_tokens()), (0, 0));
    }

    #[test]
    fn lru_eviction_respects_lookup_touches() {
        let mut t = PrefixTrie::new(0);
        t.insert(&[1, 1], 0, 1);
        t.insert(&[2, 2], 0, 1);
        t.insert(&[3, 3], 0, 1);
        // touch the oldest so it becomes the newest
        assert!(t.lookup(&[1, 1]).is_some());
        assert!(t.evict_lru());
        assert!(t.lookup(&[2, 2]).is_none(), "second-oldest evicted first");
        assert!(t.lookup(&[1, 1]).is_some());
        assert!(t.lookup(&[3, 3]).is_some());
    }

    #[test]
    fn budget_enforcement_evicts_to_fit() {
        let mut t = PrefixTrie::new(4);
        t.insert(&[1, 1], 0, 1);
        t.insert(&[2, 2], 0, 1);
        assert_eq!(t.enforce_budget(), 0);
        t.insert(&[3, 3], 0, 1);
        let evicted = t.enforce_budget();
        assert!(evicted >= 1, "over budget must evict");
        assert!(t.stored_tokens() <= 4);
        assert!(t.lookup(&[1, 1]).is_none(), "LRU entry evicted first");
    }

    #[test]
    fn invalidate_below_drops_stale_versions() {
        let mut t = PrefixTrie::new(0);
        t.insert(&[1, 1], 0, 1);
        t.insert(&[2, 2], 0, 2);
        t.insert(&[3, 3], 0, 3);
        assert_eq!(t.invalidate_below(3), 2);
        assert!(t.lookup(&[1, 1]).is_none());
        assert!(t.lookup(&[2, 2]).is_none());
        assert!(t.lookup(&[3, 3]).is_some());
        assert_eq!(t.entries(), 1);
    }

    #[test]
    fn freed_nodes_are_recycled() {
        let mut t = PrefixTrie::new(0);
        for round in 0..5 {
            t.insert(&[round, 1, 2, 3], 0, 1);
            assert!(t.remove(&[round, 1, 2, 3]));
        }
        // one root + at most one recycled chain survives
        assert!(t.nodes.len() <= 3, "arena grew without reuse: {}", t.nodes.len());
        assert_eq!(t.stored_tokens(), 0);
    }
}
