//! Fixed-bucket latency histograms (DESIGN.md §8): lock-free to
//! observe, mergeable to aggregate, and cheap to snapshot.
//!
//! Buckets are log-spaced powers of two starting at 1µs: bucket `i`
//! holds observations in `(2^(i-1)µs, 2^i µs]` (bucket 0 covers
//! everything at or below 1µs, the last bucket is open-ended at ~36
//! minutes).  Fixed log-spaced buckets keep `observe` to one atomic add,
//! make snapshots mergeable across replicas/runs by plain addition, and
//! bound the percentile error to the ×2 bucket width — the standard
//! trade for serving telemetry, replacing the mean-only accounting that
//! hid tail latency.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: 32 buckets × powers of two from 1µs ≈ 36-minute ceiling.
/// (Also the largest array length with a derived `Default`.)
pub const BUCKETS: usize = 32;

const US_PER_SEC: f64 = 1e6;

/// Upper bound of bucket `i`, in seconds.
fn bucket_upper_s(i: usize) -> f64 {
    (1u64 << i) as f64 / US_PER_SEC
}

fn bucket_of(secs: f64) -> usize {
    if secs.is_nan() || secs <= 0.0 {
        return 0;
    }
    let us = (secs * US_PER_SEC).ceil() as u64;
    (64 - us.max(1).leading_zeros() as usize - 1 + if us.is_power_of_two() { 0 } else { 1 })
        .min(BUCKETS - 1)
}

/// Live histogram: atomic bucket counters + count + sum.
#[derive(Debug, Default)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation in seconds (negatives clamp to bucket 0).
    pub fn observe(&self, secs: f64) {
        self.counts[bucket_of(secs)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((secs.max(0.0) * US_PER_SEC) as u64, Ordering::Relaxed);
    }

    pub fn observe_duration(&self, d: std::time::Duration) {
        self.observe(d.as_secs_f64());
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (out, c) in counts.iter_mut().zip(self.counts.iter()) {
            *out = c.load(Ordering::Relaxed);
        }
        HistSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum_s: self.sum_us.load(Ordering::Relaxed) as f64 / US_PER_SEC,
        }
    }
}

/// Immutable histogram state: mergeable by addition, queryable for
/// percentiles.  Rides inside `ServiceSnapshot` and `ModeReport`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub count: u64,
    pub sum_s: f64,
}

impl HistSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// The `q`-quantile (`0.0..=1.0`) in seconds, linearly interpolated
    /// within the containing bucket; 0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).max(1.0);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen as f64 + c as f64 >= rank {
                let lower = if i == 0 { 0.0 } else { bucket_upper_s(i - 1) };
                let frac = (rank - seen as f64) / c as f64;
                return lower + frac * (bucket_upper_s(i) - lower);
            }
            seen += c;
        }
        bucket_upper_s(BUCKETS - 1)
    }

    /// Fraction of observations strictly above `secs` (0 when empty).
    ///
    /// Bucket granularity applies: a bucket counts as "over" only when
    /// its *entire* range lies above `secs`, so the result is a lower
    /// bound within one ×2 bucket width — the conservative direction for
    /// an SLO violation ratio (never alarms on data that might comply).
    pub fn fraction_over(&self, secs: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let over: u64 = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(i, _)| i == 0 || bucket_upper_s(i - 1) >= secs)
            .map(|(_, &c)| c)
            .sum();
        // bucket 0 has lower bound 0: it is "over" only when secs < 0
        let over = if secs >= 0.0 { over - self.counts[0] } else { over };
        over as f64 / self.count as f64
    }

    /// Accumulate another snapshot (replica/run aggregation).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
    }

    /// The (p50, p95, p99) triple every report line prints.
    pub fn p50_p95_p99(&self) -> (f64, f64, f64) {
        (self.percentile(0.50), self.percentile(0.95), self.percentile(0.99))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log_spaced_and_clamped() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-1.0), 0);
        assert_eq!(bucket_of(1e-6), 0); // exactly 1µs
        assert_eq!(bucket_of(1.5e-6), 1);
        assert_eq!(bucket_of(2e-6), 1);
        assert_eq!(bucket_of(3e-6), 2);
        assert_eq!(bucket_of(1e9), BUCKETS - 1); // open-ended top
        // monotone in the observation
        let mut last = 0;
        for exp in 0..40 {
            let b = bucket_of(1e-6 * 2f64.powi(exp));
            assert!(b >= last);
            last = b;
        }
    }

    #[test]
    fn observe_count_sum_mean() {
        let h = Histogram::new();
        h.observe(0.010);
        h.observe(0.030);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert!((s.mean() - 0.020).abs() < 1e-6, "{}", s.mean());
        assert!(!s.is_empty());
        assert!(HistSnapshot::default().is_empty());
        assert_eq!(HistSnapshot::default().percentile(0.5), 0.0);
    }

    #[test]
    fn percentiles_bracket_the_data() {
        let h = Histogram::new();
        for _ in 0..95 {
            h.observe(0.001);
        }
        for _ in 0..5 {
            h.observe(0.500);
        }
        let s = h.snapshot();
        let p50 = s.percentile(0.50);
        let p99 = s.percentile(0.99);
        // p50 lands in the ~1ms bucket, p99 in the ~500ms bucket
        assert!(p50 > 0.0004 && p50 < 0.002, "p50={p50}");
        assert!(p99 > 0.25 && p99 <= 0.55, "p99={p99}");
        assert!(s.percentile(0.0) <= p50 && p50 <= p99);
        assert!(p99 <= s.percentile(1.0));
    }

    #[test]
    fn merge_is_addition() {
        let a = Histogram::new();
        let b = Histogram::new();
        for _ in 0..10 {
            a.observe(0.002);
            b.observe(0.200);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 20);
        assert!((m.sum_s - (10.0 * 0.002 + 10.0 * 0.200)).abs() < 1e-3);
        // the merged p95 reflects the slow half
        assert!(m.percentile(0.95) > 0.1, "{}", m.percentile(0.95));
        let (p50, p95, p99) = m.p50_p95_p99();
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn empty_snapshot_percentiles_are_zero() {
        let s = HistSnapshot::default();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(s.percentile(q), 0.0, "q={q}");
        }
        assert_eq!(s.p50_p95_p99(), (0.0, 0.0, 0.0));
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.fraction_over(0.0), 0.0);
    }

    #[test]
    fn single_sample_every_percentile_lands_in_its_bucket() {
        let h = Histogram::new();
        h.observe(0.003); // -> the (2ms, 4ms] bucket
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            let p = s.percentile(q);
            assert!(p > 0.002 && p <= 0.004096, "q={q} p={p}");
        }
        assert!((s.mean() - 0.003).abs() < 1e-6);
        assert_eq!(s.fraction_over(0.001), 1.0);
        assert_eq!(s.fraction_over(1.0), 0.0);
    }

    #[test]
    fn overflow_bucket_saturates_not_wraps() {
        let h = Histogram::new();
        // hours and days land in the open-ended top bucket
        for secs in [3.0e3, 9.0e4, 1.0e12] {
            h.observe(secs);
        }
        let s = h.snapshot();
        assert_eq!(s.counts[BUCKETS - 1], 3, "{:?}", s.counts);
        assert_eq!(s.count, 3);
        // percentiles stay inside the top bucket instead of wrapping
        let top = bucket_upper_s(BUCKETS - 1);
        let floor = bucket_upper_s(BUCKETS - 2);
        assert!(s.percentile(0.99) > floor && s.percentile(0.99) <= top);
        assert_eq!(s.percentile(1.0), top);
        assert_eq!(s.fraction_over(1.0), 1.0);
    }

    #[test]
    fn merge_of_disjoint_snapshots_preserves_both_populations() {
        let fast = Histogram::new();
        let slow = Histogram::new();
        for _ in 0..8 {
            fast.observe(1e-5);
        }
        for _ in 0..8 {
            slow.observe(2.0);
        }
        let (a, b) = (fast.snapshot(), slow.snapshot());
        // the two populations occupy disjoint bucket sets
        assert!((0..BUCKETS).all(|i| a.counts[i] == 0 || b.counts[i] == 0));
        let mut m = a;
        m.merge(&b);
        assert_eq!(m.count, 16);
        for i in 0..BUCKETS {
            assert_eq!(m.counts[i], a.counts[i] + b.counts[i]);
        }
        assert!((m.sum_s - (8.0 * 1e-5 + 8.0 * 2.0)).abs() < 1e-3);
        // exactly half the mass sits above any point between the modes
        assert!((m.fraction_over(0.1) - 0.5).abs() < 1e-12);
        assert!(m.percentile(0.25) < 1e-4 && m.percentile(0.75) > 1.0);
    }

    #[test]
    fn fraction_over_is_a_conservative_violation_ratio() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.observe(0.001);
        }
        for _ in 0..10 {
            h.observe(0.512);
        }
        let s = h.snapshot();
        // threshold above the fast mode, below the slow mode
        let f = s.fraction_over(0.01);
        assert!((f - 0.10).abs() < 1e-12, "f={f}");
        // threshold inside the slow mode's bucket: conservative (the
        // bucket straddles it, so it does not count as violating)
        assert!(s.fraction_over(0.6) <= 0.10);
        // everything is over a negative threshold, nothing over the top
        assert_eq!(s.fraction_over(-1.0), 1.0);
        assert_eq!(s.fraction_over(f64::INFINITY), 0.0);
    }

    #[test]
    fn concurrent_observes_all_land() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let h = std::sync::Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.observe(1e-5 * (i % 7 + 1) as f64);
                }
            }));
        }
        for hd in handles {
            hd.join().unwrap();
        }
        assert_eq!(h.snapshot().count, 4000);
    }
}
