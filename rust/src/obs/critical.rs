//! Critical-path attribution (DESIGN.md §12): where did each episode's
//! wall time actually go?
//!
//! The span ring records *what happened*; this module answers *what
//! dominated*.  Spans sharing a trace id are grouped into an episode,
//! and the episode's wall-clock interval is swept once: every
//! elementary sub-interval is attributed to the most specific span
//! covering it, so the segments **partition** the wall time — they sum
//! to it exactly, with uncovered time (workflow thinking, env steps,
//! scheduling gaps) landing in `other`.
//!
//! Specificity resolves overlap: a `decode` span covers serve-to-done
//! and contains the cold `prefill` (or cache `resume`) that started it,
//! so the serve marker wins inside its interval and only the remainder
//! counts as decode.  A queue wait whose claim took more than one
//! attempt (`detail` ≥ 2) is re-queue time caused by a retry and is
//! attributed to `retry`, not `queue`.  Trainer weight publishes
//! (`SyncStall`, trace 0) are global: their overlap with an episode is
//! attributed to `sync` wherever nothing episode-local was running.

use crate::qos::RequestClass;

use super::span::{Span, SpanKind};

/// Names of the attribution segments, in [`EpisodeBreakdown::segments`]
/// order.
pub const SEGMENT_NAMES: [&str; 8] =
    ["queue", "prefill", "resume", "decode", "sync", "retry", "migrate", "other"];

/// One episode's wall time, partitioned.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpisodeBreakdown {
    /// Episode trace id.
    pub trace: u64,
    /// Request class (from the episode's `ClassWait` mirror spans;
    /// defaults to [`RequestClass::TrainRollout`]).
    pub class: RequestClass,
    /// Episode start, µs relative to the recorder origin.
    pub start_us: u64,
    /// First span start to last span end.
    pub wall_us: u64,
    /// First-attempt queue waits.
    pub queue_us: u64,
    /// Cold prompt prefill.
    pub prefill_us: u64,
    /// Cache-hit resume (delta prefill only).
    pub resume_us: u64,
    /// Token generation (serve time not inside a prefill/resume).
    pub decode_us: u64,
    /// Overlap with trainer weight publishes, where otherwise idle.
    pub sync_us: u64,
    /// Re-queue waits after failed attempts.
    pub retry_us: u64,
    /// Live session migration.
    pub migrate_us: u64,
    /// Residual: wall time no span explains.
    pub other_us: u64,
    /// Retry markers observed.
    pub retries: u64,
    /// True when the episode's session was live-migrated.
    pub migrated: bool,
}

impl EpisodeBreakdown {
    /// `(name, µs)` per segment, in [`SEGMENT_NAMES`] order.  The values
    /// sum to `wall_us` exactly.
    pub fn segments(&self) -> [(&'static str, u64); 8] {
        [
            ("queue", self.queue_us),
            ("prefill", self.prefill_us),
            ("resume", self.resume_us),
            ("decode", self.decode_us),
            ("sync", self.sync_us),
            ("retry", self.retry_us),
            ("migrate", self.migrate_us),
            ("other", self.other_us),
        ]
    }

    /// The dominant segment: `(name, µs)` of the largest share.
    pub fn dominant(&self) -> (&'static str, u64) {
        self.segments().into_iter().max_by_key(|&(_, us)| us).unwrap_or(("other", 0))
    }
}

/// An interval contributing to the sweep: `[start, end)` attributed to
/// segment `seg` with precedence `priority` (higher wins on overlap).
struct Cover {
    start: u64,
    end: u64,
    seg: usize,
    priority: u8,
}

fn segment_of(span: &Span) -> Option<(usize, u8)> {
    // (segment index, priority); higher priority = more specific
    match span.kind {
        SpanKind::QueueWait if span.detail >= 2 => Some((5, 3)), // retry re-queue
        SpanKind::QueueWait => Some((0, 3)),
        SpanKind::Prefill => Some((1, 5)),
        SpanKind::Resume => Some((2, 5)),
        SpanKind::Migrate => Some((6, 5)),
        SpanKind::Decode => Some((3, 4)),
        SpanKind::SyncStall => Some((4, 1)),
        _ => None,
    }
}

/// Attribute one episode's spans (plus the run's global sync stalls).
fn breakdown(trace: u64, episode: &[&Span], syncs: &[&Span]) -> EpisodeBreakdown {
    let start = episode.iter().map(|s| s.start_us).min().unwrap_or(0);
    let end = episode.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(start);

    let mut out = EpisodeBreakdown {
        trace,
        start_us: start,
        wall_us: end - start,
        ..Default::default()
    };
    let mut covers: Vec<Cover> = Vec::with_capacity(episode.len() + syncs.len());
    let mut cuts: Vec<u64> = Vec::with_capacity(2 * (episode.len() + syncs.len()) + 2);
    cuts.push(start);
    cuts.push(end);
    for s in episode {
        match s.kind {
            SpanKind::Retry => out.retries += 1,
            SpanKind::Migrate => out.migrated = true,
            SpanKind::ClassWait => {
                // class mirror: label only, never swept (it duplicates
                // the queue-wait interval)
                if let Some(c) = RequestClass::from_index(s.detail as usize) {
                    out.class = c;
                }
                continue;
            }
            _ => {}
        }
        let Some((seg, priority)) = segment_of(s) else { continue };
        if s.dur_us == 0 {
            continue;
        }
        covers.push(Cover { start: s.start_us, end: s.start_us + s.dur_us, seg, priority });
        cuts.push(s.start_us);
        cuts.push(s.start_us + s.dur_us);
    }
    // global weight publishes, clipped to the episode's interval
    for s in syncs {
        let (a, b) = (s.start_us.max(start), (s.start_us + s.dur_us).min(end));
        if a >= b {
            continue;
        }
        let Some((seg, priority)) = segment_of(s) else { continue };
        covers.push(Cover { start: a, end: b, seg, priority });
        cuts.push(a);
        cuts.push(b);
    }

    cuts.sort_unstable();
    cuts.dedup();
    let mut segs = [0u64; 8];
    for w in cuts.windows(2) {
        let (a, b) = (w[0], w[1]);
        if a < start || b > end {
            continue;
        }
        let win = covers
            .iter()
            .filter(|c| c.start <= a && c.end >= b)
            .max_by_key(|c| c.priority)
            .map(|c| c.seg)
            .unwrap_or(7); // uncovered -> other
        segs[win] += b - a;
    }
    [
        &mut out.queue_us,
        &mut out.prefill_us,
        &mut out.resume_us,
        &mut out.decode_us,
        &mut out.sync_us,
        &mut out.retry_us,
        &mut out.migrate_us,
        &mut out.other_us,
    ]
    .into_iter()
    .zip(segs)
    .for_each(|(slot, v)| *slot = v);
    out
}

/// Group `spans` by trace id and attribute each episode, sorted by wall
/// time descending (the slowest episode first).  Trace 0 spans are run
/// plumbing, not an episode; its `SyncStall` spans contribute to every
/// episode they overlap.
pub fn attribute(spans: &[Span]) -> Vec<EpisodeBreakdown> {
    let syncs: Vec<&Span> =
        spans.iter().filter(|s| s.trace == 0 && s.kind == SpanKind::SyncStall).collect();
    let mut traces: Vec<u64> = spans.iter().map(|s| s.trace).filter(|&t| t != 0).collect();
    traces.sort_unstable();
    traces.dedup();
    let mut out: Vec<EpisodeBreakdown> = traces
        .into_iter()
        .map(|t| {
            let episode: Vec<&Span> = spans.iter().filter(|s| s.trace == t).collect();
            breakdown(t, &episode, &syncs)
        })
        .collect();
    out.sort_by(|a, b| b.wall_us.cmp(&a.wall_us).then(a.trace.cmp(&b.trace)));
    out
}

/// The `k` slowest episodes (attribution order is already slowest-first).
pub fn top_k(breakdowns: &[EpisodeBreakdown], k: usize) -> &[EpisodeBreakdown] {
    &breakdowns[..k.min(breakdowns.len())]
}

/// Per-class aggregate: `(class, episodes, total wall µs, summed
/// segments)` for every class with at least one episode — the body of
/// `trinity doctor`'s dominant-bottleneck table.
pub fn class_summary(
    breakdowns: &[EpisodeBreakdown],
) -> Vec<(RequestClass, usize, u64, [(&'static str, u64); 8])> {
    RequestClass::ALL
        .into_iter()
        .filter_map(|class| {
            let eps: Vec<&EpisodeBreakdown> =
                breakdowns.iter().filter(|b| b.class == class).collect();
            if eps.is_empty() {
                return None;
            }
            let mut segs = [("", 0u64); 8];
            for (i, name) in SEGMENT_NAMES.iter().enumerate() {
                segs[i] = (*name, eps.iter().map(|b| b.segments()[i].1).sum());
            }
            let wall = eps.iter().map(|b| b.wall_us).sum();
            Some((class, eps.len(), wall, segs))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::NO_REPLICA;

    fn span(trace: u64, kind: SpanKind, start_us: u64, dur_us: u64, detail: u64) -> Span {
        Span { trace, kind, replica: 0, start_us, dur_us, detail }
    }

    #[test]
    fn segments_partition_the_wall_time_exactly() {
        // a two-turn episode: queue -> cold prefill inside decode,
        // a gap, then queue -> cache resume inside decode
        let spans = vec![
            span(1, SpanKind::QueueWait, 0, 100, 1),
            span(1, SpanKind::Prefill, 100, 300, 64),
            span(1, SpanKind::Decode, 100, 500, 8), // contains the prefill
            span(1, SpanKind::QueueWait, 800, 50, 1),
            span(1, SpanKind::Resume, 850, 40, 48),
            span(1, SpanKind::Decode, 850, 150, 8), // contains the resume
        ];
        let b = &attribute(&spans)[0];
        assert_eq!(b.trace, 1);
        assert_eq!(b.wall_us, 1000);
        assert_eq!(b.queue_us, 150);
        assert_eq!(b.prefill_us, 300, "serve marker wins inside decode");
        assert_eq!(b.resume_us, 40, "cache-hit turn is resume, not prefill");
        assert_eq!(b.decode_us, 200 + 110, "decode keeps only its remainder");
        assert_eq!(b.other_us, 200, "the inter-turn gap");
        let total: u64 = b.segments().iter().map(|&(_, us)| us).sum();
        assert_eq!(total, b.wall_us, "segments must partition the wall");
        assert_eq!(b.dominant(), ("decode", 310));
        assert_eq!(b.class, RequestClass::TrainRollout);
    }

    #[test]
    fn retry_requeues_sync_overlap_and_class_label() {
        let spans = vec![
            span(2, SpanKind::QueueWait, 0, 100, 1),
            span(2, SpanKind::Retry, 100, 0, 2),
            span(2, SpanKind::QueueWait, 100, 200, 2), // second attempt
            span(2, SpanKind::ClassWait, 300, 0, RequestClass::Interactive.index() as u64),
            span(2, SpanKind::Decode, 300, 100, 4),
            // trace-0 sync stall covering the idle tail of the episode
            span(0, SpanKind::SyncStall, 400, 400, 0),
            span(2, SpanKind::Migrate, 700, 0, 0),
        ];
        let b = &attribute(&spans)[0];
        assert_eq!(b.class, RequestClass::Interactive);
        assert_eq!(b.wall_us, 700);
        assert_eq!(b.queue_us, 100, "first attempt is queue");
        assert_eq!(b.retry_us, 200, "re-queue after a retry is retry time");
        assert_eq!(b.retries, 1);
        assert_eq!(b.decode_us, 100);
        assert_eq!(b.sync_us, 300, "publish overlap clipped to the episode");
        assert_eq!(b.other_us, 0);
        assert!(b.migrated);
        let total: u64 = b.segments().iter().map(|&(_, us)| us).sum();
        assert_eq!(total, b.wall_us);
    }

    #[test]
    fn attribution_sorts_slowest_first_and_aggregates_by_class() {
        let spans = vec![
            span(1, SpanKind::QueueWait, 0, 50, 1),
            span(1, SpanKind::Decode, 50, 100, 2),
            span(2, SpanKind::QueueWait, 0, 400, 1),
            span(2, SpanKind::Decode, 400, 100, 2),
            span(0, SpanKind::DeviceTrain, 0, 999, 0), // plumbing, ignored
        ];
        let all = attribute(&spans);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].trace, 2, "slowest first");
        assert_eq!(top_k(&all, 1).len(), 1);
        assert_eq!(top_k(&all, 10).len(), 2);
        let per_class = class_summary(&all);
        assert_eq!(per_class.len(), 1);
        let (class, count, wall, segs) = per_class[0];
        assert_eq!(class, RequestClass::TrainRollout);
        assert_eq!(count, 2);
        assert_eq!(wall, 150 + 500);
        let queue = segs.iter().find(|&&(n, _)| n == "queue").unwrap().1;
        assert_eq!(queue, 450);
        assert!(attribute(&[]).is_empty());
    }
}
