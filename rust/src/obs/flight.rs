//! Flight recorder (DESIGN.md §12): when something goes wrong, snapshot
//! the evidence *before it is gone*.
//!
//! Gauges are point-in-time and the span ring overwrites itself — by the
//! time a human looks at a failed run, the window around the failure has
//! been recycled.  The [`FlightRecorder`] watches for anomalies
//! (deadline-expiry bursts, circuit-breaker opens, failed migrations,
//! SLO burn past threshold) and on trigger dumps a **self-contained**
//! `flight-<seq>.json` bundle to the monitor dir: the span-ring tail as
//! Chrome trace events (so `trinity doctor` and `chrome://tracing` both
//! open it), the gauge history, the `[control]` decision ring, per-class
//! queue state, and a config digest identifying the run.
//!
//! Dumps are rate-limited (one per `min_interval`) and bounded in count
//! (`max_dumps`), so a failure storm costs a handful of files, not a
//! disk.  Triggers are counted even when suppressed — the run report can
//! say "47 anomalies, 8 dumped".
//!
//! Wiring is acyclic by construction: the recorder holds `Arc`s *into*
//! the system (span ring, hub, sources wrapping the control plane and
//! replica queues); nothing the recorder reads holds the recorder.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::qos::RequestClass;
use crate::util::json::Value;

use super::export::chrome_trace;
use super::hub::TelemetryHub;
use super::span::SpanRecorder;

/// What tripped the recorder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Anomaly {
    /// `expiry_burst` deadline expiries inside one `expiry_window`.
    DeadlineBurst,
    /// A replica's circuit breaker opened (quarantine).
    BreakerOpen,
    /// A live session migration failed to land.
    MigrationFailure,
    /// A class's SLO burn rate crossed `burn_threshold`.
    SloBurn,
}

impl Anomaly {
    pub fn as_str(&self) -> &'static str {
        match self {
            Anomaly::DeadlineBurst => "deadline_burst",
            Anomaly::BreakerOpen => "breaker_open",
            Anomaly::MigrationFailure => "migration_failure",
            Anomaly::SloBurn => "slo_burn",
        }
    }
}

/// Flight-recorder knobs (a slice of `ObsConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct FlightConfig {
    /// Where dumps land; `None` = count triggers but never write.
    pub dir: Option<PathBuf>,
    /// Dumps written over the recorder's lifetime (0 disables dumping).
    pub max_dumps: u64,
    /// Minimum spacing between dumps.
    pub min_interval: Duration,
    /// Deadline expiries within `expiry_window` that count as a burst
    /// (0 disables the deadline trigger).
    pub expiry_burst: u32,
    /// Window for the expiry-burst counter.
    pub expiry_window: Duration,
    /// Newest spans embedded per dump.
    pub span_tail: usize,
    /// SLO burn rate at which the scheduler triggers [`Anomaly::SloBurn`]
    /// (0 disables; read by the scheduler, carried here so one struct
    /// describes the whole recorder).
    pub burn_threshold: f64,
}

impl Default for FlightConfig {
    fn default() -> Self {
        FlightConfig {
            dir: None,
            max_dumps: 8,
            min_interval: Duration::from_secs(30),
            expiry_burst: 8,
            expiry_window: Duration::from_secs(5),
            span_tail: 512,
            burn_threshold: 2.0,
        }
    }
}

impl FlightConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if !self.burn_threshold.is_finite() || self.burn_threshold < 0.0 {
            anyhow::bail!("flight burn_threshold must be finite and >= 0");
        }
        Ok(())
    }
}

/// A pluggable evidence source: each contributes one named section to
/// every dump.  Implemented by the control plane (decision ring) and the
/// rollout service (per-class queue state); anything else can attach.
pub trait FlightSource: Send + Sync {
    fn name(&self) -> &'static str;
    fn collect(&self) -> Value;
}

#[derive(Default)]
struct ExpiryWindow {
    /// Origin-relative µs of the window start.
    start_us: u64,
    count: u32,
}

pub struct FlightRecorder {
    cfg: FlightConfig,
    origin: Instant,
    /// Dumps written (also the next dump's sequence number).
    dumps: AtomicU64,
    /// Anomaly triggers observed, dumped or not.
    triggers: AtomicU64,
    /// Triggers that produced no dump (rate limit, dump cap, or a
    /// failed write) — `triggers == dumps + suppressed` always holds.
    suppressed: AtomicU64,
    /// Origin-relative µs of the last dump; `u64::MAX` = never.
    last_dump_us: AtomicU64,
    expiries: Mutex<ExpiryWindow>,
    spans: Mutex<Option<Arc<SpanRecorder>>>,
    hub: Mutex<Option<Arc<TelemetryHub>>>,
    sources: Mutex<Vec<Arc<dyn FlightSource>>>,
    config_digest: Mutex<String>,
}

impl FlightRecorder {
    pub fn new(cfg: FlightConfig) -> FlightRecorder {
        FlightRecorder {
            cfg,
            origin: Instant::now(),
            dumps: AtomicU64::new(0),
            triggers: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
            last_dump_us: AtomicU64::new(u64::MAX),
            expiries: Mutex::new(ExpiryWindow::default()),
            spans: Mutex::new(None),
            hub: Mutex::new(None),
            sources: Mutex::new(Vec::new()),
            config_digest: Mutex::new(String::new()),
        }
    }

    pub fn config(&self) -> &FlightConfig {
        &self.cfg
    }

    /// Attach the span ring whose tail each dump embeds.
    pub fn connect_spans(&self, spans: Arc<SpanRecorder>) {
        *self.spans.lock().unwrap() = Some(spans);
    }

    /// Attach the telemetry hub whose gauges + history each dump embeds.
    pub fn connect_hub(&self, hub: Arc<TelemetryHub>) {
        *self.hub.lock().unwrap() = Some(hub);
    }

    /// Attach an evidence source (control decisions, class queues, ...).
    pub fn attach(&self, source: Arc<dyn FlightSource>) {
        self.sources.lock().unwrap().push(source);
    }

    /// Stamp the config digest identifying the run the dumps belong to.
    pub fn set_config_digest(&self, digest: impl Into<String>) {
        *self.config_digest.lock().unwrap() = digest.into();
    }

    /// Anomaly triggers observed (dumped or suppressed).
    pub fn triggers(&self) -> u64 {
        self.triggers.load(Ordering::Relaxed)
    }

    /// Dumps actually written.
    pub fn dumps(&self) -> u64 {
        self.dumps.load(Ordering::Relaxed)
    }

    /// Triggers that produced no dump (rate limit, cap, failed write).
    pub fn suppressed(&self) -> u64 {
        self.suppressed.load(Ordering::Relaxed)
    }

    /// Note one deadline expiry of `class`; trips
    /// [`Anomaly::DeadlineBurst`] when `expiry_burst` land inside one
    /// `expiry_window`.
    pub fn note_expiry(&self, class: RequestClass) {
        if self.cfg.expiry_burst == 0 {
            return;
        }
        let now_us = self.origin.elapsed().as_micros() as u64;
        let window_us = self.cfg.expiry_window.as_micros() as u64;
        let burst = {
            let mut w = self.expiries.lock().unwrap();
            if now_us.saturating_sub(w.start_us) > window_us || w.count == 0 {
                w.start_us = now_us;
                w.count = 1;
                false
            } else {
                w.count += 1;
                let hit = w.count >= self.cfg.expiry_burst;
                if hit {
                    w.count = 0; // re-arm
                }
                hit
            }
        };
        if burst {
            self.trigger(
                Anomaly::DeadlineBurst,
                &format!(
                    "{} expiries within {:.1}s (last: class {})",
                    self.cfg.expiry_burst,
                    self.cfg.expiry_window.as_secs_f64(),
                    class.as_str()
                ),
            );
        }
    }

    /// Fire an anomaly: rate-limited and count-bounded; returns the dump
    /// path when one was written.
    pub fn trigger(&self, anomaly: Anomaly, detail: &str) -> Option<PathBuf> {
        self.triggers.fetch_add(1, Ordering::Relaxed);
        // cap check first: a capped recorder never consumes the
        // rate-limit window it will no longer use
        if self.cfg.max_dumps == 0
            || self.cfg.dir.is_none()
            || self.dumps.load(Ordering::Relaxed) >= self.cfg.max_dumps
        {
            self.suppressed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        // rate limit: one winner per min_interval (CAS, any thread)
        let now_us = self.origin.elapsed().as_micros() as u64;
        let interval_us = self.cfg.min_interval.as_micros() as u64;
        let prev_dump_us = loop {
            let last = self.last_dump_us.load(Ordering::Relaxed);
            if last != u64::MAX && now_us < last.saturating_add(interval_us) {
                self.suppressed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            if self
                .last_dump_us
                .compare_exchange(last, now_us, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                break last;
            }
        };
        // count bound (re-checked: the early load races with other winners)
        let seq = self.dumps.fetch_add(1, Ordering::Relaxed);
        if seq >= self.cfg.max_dumps {
            self.dumps.fetch_sub(1, Ordering::Relaxed);
            self.suppressed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let doc = self.bundle(anomaly, detail, seq, now_us);
        let dir = self.cfg.dir.clone().expect("checked above");
        let path = dir.join(format!("flight-{seq}.json"));
        let written = std::fs::create_dir_all(&dir)
            .and_then(|_| std::fs::write(&path, doc.to_string_pretty()));
        if let Err(e) = written {
            // a failed write is not a dump: roll the counter back so
            // dumps() stays exact, and release the rate-limit window so
            // the next anomaly may still produce evidence
            self.dumps.fetch_sub(1, Ordering::Relaxed);
            let _ = self.last_dump_us.compare_exchange(
                now_us,
                prev_dump_us,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            self.suppressed.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!("flight", "failed to write {path:?}: {e}");
            return None;
        }
        crate::log_warn!(
            "flight",
            "anomaly {}: dumped {path:?} ({detail})",
            anomaly.as_str()
        );
        Some(path)
    }

    /// Assemble the self-contained dump document.
    fn bundle(&self, anomaly: Anomaly, detail: &str, seq: u64, now_us: u64) -> Value {
        let gauges_obj = |g: &super::hub::Gauges| {
            Value::Object(g.fields().into_iter().map(|(k, v)| (k.to_string(), Value::num(v))).collect())
        };
        let mut doc = Value::obj(vec![
            ("flight", Value::int(seq as i64)),
            ("anomaly", Value::str(anomaly.as_str())),
            ("detail", Value::str(detail)),
            ("at_s", Value::num(now_us as f64 / 1e6)),
            ("config_digest", Value::str(self.config_digest.lock().unwrap().clone())),
        ]);
        if let Some(hub) = self.hub.lock().unwrap().as_ref() {
            doc.set("gauges", gauges_obj(&hub.gauges()));
            doc.set(
                "gauge_history",
                Value::arr(hub.history().iter().map(gauges_obj).collect()),
            );
        }
        let sections: Vec<(String, Value)> = self
            .sources
            .lock()
            .unwrap()
            .iter()
            .map(|s| (s.name().to_string(), s.collect()))
            .collect();
        doc.set("sections", Value::Object(sections));
        if let Some(spans) = self.spans.lock().unwrap().as_ref() {
            let all = spans.drain();
            let tail = &all[all.len().saturating_sub(self.cfg.span_tail)..];
            // embed as traceEvents so doctor/chrome open dumps directly
            if let Some(events) = chrome_trace(tail).get("traceEvents") {
                doc.set("traceEvents", events.clone());
            }
        }
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hub::Gauges;
    use crate::obs::span::{Span, SpanKind};

    struct StaticSource;
    impl FlightSource for StaticSource {
        fn name(&self) -> &'static str {
            "static"
        }
        fn collect(&self) -> Value {
            Value::obj(vec![("answer", Value::int(42))])
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("trft_flight_{tag}_{}", std::process::id()))
    }

    #[test]
    fn dump_is_self_contained_and_rate_limited() {
        let dir = temp_dir("bundle");
        let _ = std::fs::remove_dir_all(&dir);
        let recorder = FlightRecorder::new(FlightConfig {
            dir: Some(dir.clone()),
            min_interval: Duration::from_secs(3600),
            ..Default::default()
        });
        let spans = Arc::new(SpanRecorder::new(64));
        spans.record(Span {
            trace: 5,
            kind: SpanKind::Decode,
            replica: 0,
            start_us: 10,
            dur_us: 20,
            detail: 4,
        });
        let hub = Arc::new(TelemetryHub::new(Duration::from_millis(1)));
        hub.publish(Gauges { queued: 3.0, ..Default::default() });
        hub.publish(Gauges { queued: 9.0, ..Default::default() });
        recorder.connect_spans(Arc::clone(&spans));
        recorder.connect_hub(Arc::clone(&hub));
        recorder.attach(Arc::new(StaticSource));
        recorder.set_config_digest("deadbeef");

        let path = recorder.trigger(Anomaly::BreakerOpen, "replica 0 quarantined").unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "flight-0.json");
        let doc = Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("anomaly").and_then(Value::as_str), Some("breaker_open"));
        assert_eq!(doc.get("config_digest").and_then(Value::as_str), Some("deadbeef"));
        assert_eq!(doc.path("gauges.queued").and_then(Value::as_f64), Some(9.0));
        let history = doc.get("gauge_history").and_then(Value::as_array).unwrap();
        assert_eq!(history.len(), 2, "history reconstructs the window");
        assert_eq!(history[0].get("queued").and_then(Value::as_f64), Some(3.0));
        assert_eq!(doc.path("sections.static.answer").and_then(Value::as_i64), Some(42));
        let events = doc.get("traceEvents").and_then(Value::as_array).unwrap();
        assert!(events.iter().any(|e| e.get("name").and_then(Value::as_str) == Some("decode")));

        // second trigger inside the interval: counted, not dumped
        assert!(recorder.trigger(Anomaly::MigrationFailure, "again").is_none());
        assert_eq!(recorder.triggers(), 2);
        assert_eq!(recorder.dumps(), 1);
        assert_eq!(recorder.suppressed(), 1);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dump_count_is_bounded() {
        let dir = temp_dir("cap");
        let _ = std::fs::remove_dir_all(&dir);
        let recorder = FlightRecorder::new(FlightConfig {
            dir: Some(dir.clone()),
            max_dumps: 2,
            min_interval: Duration::ZERO,
            ..Default::default()
        });
        for i in 0..5 {
            recorder.trigger(Anomaly::SloBurn, &format!("t{i}"));
        }
        assert_eq!(recorder.dumps(), 2);
        assert_eq!(recorder.triggers(), 5);
        assert_eq!(recorder.suppressed(), 3);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 2);
        std::fs::remove_dir_all(&dir).unwrap();

        let disabled = FlightRecorder::new(FlightConfig { max_dumps: 0, ..Default::default() });
        assert!(disabled.trigger(Anomaly::BreakerOpen, "x").is_none());
        assert_eq!((disabled.triggers(), disabled.dumps()), (1, 0));
    }

    #[test]
    fn expiry_burst_trips_only_inside_the_window() {
        let dir = temp_dir("burst");
        let _ = std::fs::remove_dir_all(&dir);
        let recorder = FlightRecorder::new(FlightConfig {
            dir: Some(dir.clone()),
            expiry_burst: 3,
            expiry_window: Duration::from_secs(60),
            min_interval: Duration::ZERO,
            ..Default::default()
        });
        recorder.note_expiry(RequestClass::Interactive);
        recorder.note_expiry(RequestClass::Interactive);
        assert_eq!(recorder.triggers(), 0, "below the burst threshold");
        recorder.note_expiry(RequestClass::Interactive);
        assert_eq!(recorder.triggers(), 1, "third expiry trips the burst");
        assert_eq!(recorder.dumps(), 1);
        let dump = std::fs::read_to_string(dir.join("flight-0.json")).unwrap();
        assert!(dump.contains("deadline_burst"), "{dump}");
        assert!(dump.contains("interactive"), "{dump}");
        std::fs::remove_dir_all(&dir).unwrap();

        let off = FlightRecorder::new(FlightConfig { expiry_burst: 0, ..Default::default() });
        for _ in 0..100 {
            off.note_expiry(RequestClass::Eval);
        }
        assert_eq!(off.triggers(), 0, "trigger disabled by expiry_burst=0");
    }

    #[test]
    fn failed_write_rolls_back_accounting() {
        // a FILE at the dump-dir path makes create_dir_all fail
        let blocker = temp_dir("blocked");
        let _ = std::fs::remove_dir_all(&blocker);
        let _ = std::fs::remove_file(&blocker);
        std::fs::write(&blocker, b"not a dir").unwrap();
        let recorder = FlightRecorder::new(FlightConfig {
            dir: Some(blocker.clone()),
            min_interval: Duration::from_secs(3600),
            ..Default::default()
        });
        assert!(recorder.trigger(Anomaly::BreakerOpen, "x").is_none());
        assert_eq!(
            (recorder.triggers(), recorder.dumps(), recorder.suppressed()),
            (1, 0, 1),
            "a failed write is suppressed, not counted as a dump"
        );
        // the failure released the rate-limit window and its sequence
        // number: the next trigger dumps as soon as the path is writable
        std::fs::remove_file(&blocker).unwrap();
        let path = recorder.trigger(Anomaly::BreakerOpen, "y").unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "flight-0.json");
        assert_eq!((recorder.triggers(), recorder.dumps(), recorder.suppressed()), (2, 1, 1));
        std::fs::remove_dir_all(&blocker).unwrap();
    }

    #[test]
    fn no_dir_counts_but_never_writes() {
        let recorder = FlightRecorder::new(FlightConfig {
            dir: None,
            min_interval: Duration::ZERO,
            ..Default::default()
        });
        assert!(recorder.trigger(Anomaly::BreakerOpen, "x").is_none());
        assert_eq!(recorder.triggers(), 1);
    }
}
