//! Trace export (DESIGN.md §8): Chrome trace-event JSON plus the text
//! summary behind `trinity trace`.
//!
//! The export maps the span model onto the trace-event format that
//! `chrome://tracing` and Perfetto load directly:
//!
//! * **pid** is the lane — 0 = coordinator, `1 + replica` = a serving
//!   replica, [`DEVICE_LANE`] = the PJRT device;
//! * **tid** is the episode trace id, so one episode reads as one row
//!   per lane: queue wait → prefill/resume → decode per turn;
//! * complete events (`ph: "X"`) carry `ts`/`dur` in microseconds and
//!   the span's kind-specific `detail` in `args`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Value;

use super::span::{MigrateDetail, Span, SpanKind, NO_REPLICA};

/// The pid under which device-lane spans render.
pub const DEVICE_LANE: u64 = 999;

fn lane(span: &Span) -> u64 {
    match span.kind {
        SpanKind::DevicePrefill | SpanKind::DeviceDecode | SpanKind::DeviceTrain => DEVICE_LANE,
        _ if span.replica == NO_REPLICA => 0,
        _ => 1 + span.replica as u64,
    }
}

fn lane_name(pid: u64) -> String {
    match pid {
        0 => "coordinator".to_string(),
        DEVICE_LANE => "device".to_string(),
        n => format!("replica-{}", n - 1),
    }
}

fn category(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::QueueWait | SpanKind::Retry | SpanKind::Reroute => "service",
        SpanKind::Prefill | SpanKind::Resume | SpanKind::Decode => "replica",
        SpanKind::SyncStall => "sync",
        SpanKind::DevicePrefill | SpanKind::DeviceDecode | SpanKind::DeviceTrain => "device",
        SpanKind::ControlDecision => "control",
        SpanKind::Migrate | SpanKind::ClassWait => "qos",
    }
}

/// Render spans as a Chrome trace-event JSON document.
pub fn chrome_trace(spans: &[Span]) -> Value {
    let mut events = Vec::with_capacity(spans.len() + 4);
    let mut lanes: Vec<u64> = spans.iter().map(lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for pid in lanes {
        events.push(Value::obj(vec![
            ("ph", Value::str("M")),
            ("name", Value::str("process_name")),
            ("pid", Value::int(pid as i64)),
            ("args", Value::obj(vec![("name", Value::str(lane_name(pid)))])),
        ]));
    }
    for s in spans {
        // migrate spans unpack their detail word into readable args —
        // raw `dest<<32|saved` is useless in a trace viewer
        let args = if s.kind == SpanKind::Migrate {
            let m = MigrateDetail::unpack(s.detail);
            Value::obj(vec![
                ("dest_replica", Value::int(m.dest_replica as i64)),
                ("saved_tokens", Value::int(m.saved_tokens as i64)),
                ("replica", Value::int(s.replica as i64)),
            ])
        } else {
            Value::obj(vec![
                ("detail", Value::int(s.detail as i64)),
                ("replica", Value::int(s.replica as i64)),
            ])
        };
        events.push(Value::obj(vec![
            ("name", Value::str(s.kind.as_str())),
            ("cat", Value::str(category(s.kind))),
            ("ph", Value::str("X")),
            ("ts", Value::int(s.start_us as i64)),
            ("dur", Value::int(s.dur_us as i64)),
            ("pid", Value::int(lane(s) as i64)),
            ("tid", Value::int(s.trace as i64)),
            ("args", args),
        ]));
    }
    Value::obj(vec![("traceEvents", Value::arr(events))])
}

/// Rebuild the span list from a trace document — the inverse of
/// [`chrome_trace`], so `trinity doctor` and the flight-dump analyzer
/// run the same attribution code on a file as on a live ring.  Metadata
/// events and unknown span names are skipped (forward compatibility);
/// `Migrate` args are re-packed through [`MigrateDetail`].
pub fn spans_from_trace(doc: &Value) -> Result<Vec<Span>> {
    let events = doc
        .get("traceEvents")
        .and_then(Value::as_array)
        .context("not a trace: missing traceEvents")?;
    let mut spans = Vec::with_capacity(events.len());
    for e in events {
        if e.get("ph").and_then(Value::as_str) != Some("X") {
            continue;
        }
        let Some(kind) = e.get("name").and_then(Value::as_str).and_then(SpanKind::parse) else {
            continue;
        };
        let int = |key: &str| e.get(key).and_then(Value::as_i64).unwrap_or(0).max(0) as u64;
        let arg = |key: &str| {
            e.get("args").and_then(|a| a.get(key)).and_then(Value::as_i64).unwrap_or(0).max(0)
                as u64
        };
        let detail = if kind == SpanKind::Migrate {
            MigrateDetail {
                dest_replica: arg("dest_replica") as u32,
                saved_tokens: arg("saved_tokens") as u32,
            }
            .pack()
        } else {
            arg("detail")
        };
        spans.push(Span {
            trace: int("tid"),
            kind,
            replica: arg("replica") as u32,
            start_us: int("ts"),
            dur_us: int("dur"),
            detail,
        });
    }
    spans.sort_by_key(|s| (s.start_us, s.trace));
    Ok(spans)
}

/// Write `trace.json` for chrome://tracing / Perfetto.
pub fn write_trace(path: &Path, spans: &[Span]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        }
    }
    std::fs::write(path, chrome_trace(spans).to_string_pretty())
        .with_context(|| format!("writing trace to {path:?}"))
}

/// Load a trace file previously written by [`write_trace`].
pub fn load_trace(path: &Path) -> Result<Value> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
    Value::parse(&text).with_context(|| format!("parsing trace {path:?}"))
}

/// Summarize a trace document: per-kind counts and total/mean duration,
/// plus the episode count — the body of `trinity trace`.
pub fn summarize_trace(doc: &Value) -> Result<String> {
    let events = doc
        .get("traceEvents")
        .and_then(Value::as_array)
        .context("not a trace: missing traceEvents")?;
    // name -> (count, total_us, max_us)
    let mut kinds: Vec<(String, u64, u64, u64)> = vec![];
    let mut episodes: Vec<i64> = vec![];
    let mut span_events = 0u64;
    for e in events {
        if e.get("ph").and_then(Value::as_str) != Some("X") {
            continue;
        }
        span_events += 1;
        let name = e.get("name").and_then(Value::as_str).unwrap_or("?").to_string();
        let dur = e.get("dur").and_then(Value::as_i64).unwrap_or(0).max(0) as u64;
        let tid = e.get("tid").and_then(Value::as_i64).unwrap_or(0);
        if tid != 0 {
            episodes.push(tid);
        }
        match kinds.iter_mut().find(|(n, ..)| *n == name) {
            Some((_, c, total, max)) => {
                *c += 1;
                *total += dur;
                *max = (*max).max(dur);
            }
            None => kinds.push((name, 1, dur, dur)),
        }
    }
    episodes.sort_unstable();
    episodes.dedup();
    kinds.sort_by(|a, b| b.2.cmp(&a.2));
    let mut out = format!(
        "{span_events} spans across {} episode(s)\n\n{:<16} {:>8} {:>12} {:>10} {:>10}\n",
        episodes.len(),
        "kind",
        "count",
        "total (ms)",
        "mean (ms)",
        "max (ms)"
    );
    for (name, count, total, max) in &kinds {
        out.push_str(&format!(
            "{:<16} {:>8} {:>12.3} {:>10.3} {:>10.3}\n",
            name,
            count,
            *total as f64 / 1e3,
            *total as f64 / 1e3 / *count as f64,
            *max as f64 / 1e3,
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans() -> Vec<Span> {
        vec![
            Span { trace: 7, kind: SpanKind::QueueWait, replica: 0, start_us: 0, dur_us: 50, detail: 0 },
            Span { trace: 7, kind: SpanKind::Prefill, replica: 0, start_us: 50, dur_us: 200, detail: 12 },
            Span { trace: 7, kind: SpanKind::Decode, replica: 0, start_us: 250, dur_us: 400, detail: 8 },
            Span { trace: 9, kind: SpanKind::Resume, replica: 1, start_us: 300, dur_us: 20, detail: 30 },
            Span { trace: 0, kind: SpanKind::SyncStall, replica: NO_REPLICA, start_us: 100, dur_us: 90, detail: 0 },
            Span { trace: 0, kind: SpanKind::DeviceDecode, replica: NO_REPLICA, start_us: 260, dur_us: 10, detail: 0 },
        ]
    }

    #[test]
    fn chrome_trace_shape_and_lanes() {
        let doc = chrome_trace(&spans());
        let events = doc.get("traceEvents").and_then(Value::as_array).unwrap();
        let metas: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("M"))
            .collect();
        // coordinator + replica-0 + replica-1 + device lanes
        assert_eq!(metas.len(), 4);
        let xs: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 6);
        for e in &xs {
            assert!(e.get("ts").and_then(Value::as_i64).is_some());
            assert!(e.get("dur").and_then(Value::as_i64).is_some());
            assert!(e.get("pid").and_then(Value::as_i64).is_some());
            assert!(e.get("tid").and_then(Value::as_i64).is_some());
        }
        // lanes: sync stall on the coordinator, decode on replica-0,
        // resume on replica-1, device decode on the device lane
        let pid_of = |name: &str| {
            xs.iter()
                .find(|e| e.get("name").and_then(Value::as_str) == Some(name))
                .and_then(|e| e.get("pid"))
                .and_then(Value::as_i64)
                .unwrap()
        };
        assert_eq!(pid_of("weight_sync"), 0);
        assert_eq!(pid_of("decode"), 1);
        assert_eq!(pid_of("resume"), 2);
        assert_eq!(pid_of("device_decode"), DEVICE_LANE as i64);
    }

    #[test]
    fn write_load_summarize_roundtrip() {
        let dir = std::env::temp_dir().join(format!("trft_trace_{}", std::process::id()));
        let path = dir.join("trace.json");
        write_trace(&path, &spans()).unwrap();
        let doc = load_trace(&path).unwrap();
        let summary = summarize_trace(&doc).unwrap();
        assert!(summary.contains("6 spans across 2 episode(s)"), "{summary}");
        assert!(summary.contains("decode"), "{summary}");
        assert!(summary.contains("queue_wait"), "{summary}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summarize_rejects_non_traces() {
        assert!(summarize_trace(&Value::obj(vec![("x", Value::int(1))])).is_err());
        assert!(spans_from_trace(&Value::obj(vec![("x", Value::int(1))])).is_err());
    }

    #[test]
    fn migrate_args_are_readable_not_packed() {
        let detail = MigrateDetail { dest_replica: 2, saved_tokens: 345 }.pack();
        let s = Span { trace: 4, kind: SpanKind::Migrate, replica: 1, start_us: 10, dur_us: 0, detail };
        let doc = chrome_trace(&[s]);
        let events = doc.get("traceEvents").and_then(Value::as_array).unwrap();
        let e = events
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("migrate"))
            .unwrap();
        let args = e.get("args").unwrap();
        assert_eq!(args.get("dest_replica").and_then(Value::as_i64), Some(2));
        assert_eq!(args.get("saved_tokens").and_then(Value::as_i64), Some(345));
        assert!(args.get("detail").is_none(), "raw packed word must not leak: {args}");
    }

    #[test]
    fn spans_roundtrip_through_the_trace_document() {
        let mut original = spans();
        original.push(Span {
            trace: 9,
            kind: SpanKind::Migrate,
            replica: 0,
            start_us: 500,
            dur_us: 0,
            detail: MigrateDetail { dest_replica: 1, saved_tokens: 30 }.pack(),
        });
        let rebuilt = spans_from_trace(&chrome_trace(&original)).unwrap();
        assert_eq!(rebuilt.len(), original.len());
        let mut expected = original.clone();
        expected.sort_by_key(|s| (s.start_us, s.trace));
        assert_eq!(rebuilt, expected, "round-trip must preserve every field");
    }
}
