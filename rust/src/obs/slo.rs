//! Per-class SLO engine (DESIGN.md §12): typed latency targets per
//! [`RequestClass`] with a rolling error-budget **burn rate** computed
//! from the per-class queue-wait histograms the QoS plane already
//! maintains.
//!
//! The vocabulary is the standard SRE one: an *objective* (e.g. "99% of
//! interactive waits under 250ms") grants an error budget of
//! `1 - objective` violations; the burn rate is the measured violation
//! fraction divided by that budget.  Burn 0 = no violations at all,
//! burn 1 = consuming the budget exactly as fast as it accrues, burn >1
//! = over-burning (the class will miss its SLO if sustained).  Burn
//! rates are published as gauges (`slo_burn_*`) so `[control]` policies
//! and the flight recorder can read them live.
//!
//! The engine is *rolling*: each [`SloEngine::assess`] call diffs the
//! cumulative per-class histograms against the previous call's
//! snapshots, so the burn reflects only the observations of the last
//! assessment window, not the whole run.  An empty window holds the
//! previous burn (no data is not the same as no violations).

use std::sync::Mutex;
use std::time::Duration;

use crate::qos::{RequestClass, CLASS_COUNT};

use super::hist::HistSnapshot;

/// Typed per-class latency targets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// Latency target per class, indexed by `RequestClass::index()`;
    /// `Duration::ZERO` = the class is untracked (burn stays 0).
    pub targets: [Duration; CLASS_COUNT],
    /// Fraction of observations that must meet the target (e.g. 0.99).
    /// The error budget is `1 - objective`.
    pub objective: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig { targets: [Duration::ZERO; CLASS_COUNT], objective: 0.99 }
    }
}

impl SloConfig {
    /// True when at least one class has a target — the scheduler only
    /// builds an engine (and pays the per-publish diff) in that case.
    pub fn any_target(&self) -> bool {
        self.targets.iter().any(|t| !t.is_zero())
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if !self.objective.is_finite() || !(0.0..1.0).contains(&self.objective) {
            anyhow::bail!("slo objective must be in [0, 1), got {}", self.objective);
        }
        Ok(())
    }
}

#[derive(Debug, Default)]
struct SloState {
    /// Cumulative per-class snapshots as of the previous assessment.
    last: [HistSnapshot; CLASS_COUNT],
    /// Burn rates as of the previous assessment (held through empty
    /// windows).
    burn: [f64; CLASS_COUNT],
}

/// Rolling error-budget accountant over cumulative class histograms.
pub struct SloEngine {
    cfg: SloConfig,
    state: Mutex<SloState>,
}

impl SloEngine {
    pub fn new(cfg: SloConfig) -> SloEngine {
        SloEngine { cfg, state: Mutex::new(SloState::default()) }
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Diff `waits` (cumulative per-class queue-wait snapshots, indexed
    /// by `RequestClass::index()`) against the previous call and return
    /// the per-class burn rates for the window in between.
    pub fn assess(&self, waits: &[HistSnapshot; CLASS_COUNT]) -> [f64; CLASS_COUNT] {
        let mut st = self.state.lock().unwrap();
        for class in RequestClass::ALL {
            let i = class.index();
            let target = self.cfg.targets[i];
            if target.is_zero() {
                st.burn[i] = 0.0;
                st.last[i] = waits[i];
                continue;
            }
            let window = window_delta(&waits[i], &st.last[i]);
            if window.count > 0 {
                let violations = window.fraction_over(target.as_secs_f64());
                let budget = (1.0 - self.cfg.objective).max(f64::EPSILON);
                st.burn[i] = violations / budget;
            }
            // empty window: hold the previous burn
            st.last[i] = waits[i];
        }
        st.burn
    }

    /// The burn rates of the latest assessment (all zeros before the
    /// first).
    pub fn burns(&self) -> [f64; CLASS_COUNT] {
        self.state.lock().unwrap().burn
    }
}

/// `current - last`, per bucket, saturating — the observations that
/// arrived since the previous assessment.  Saturation (instead of
/// wrapping) keeps a restarted metrics source from poisoning the burn.
fn window_delta(current: &HistSnapshot, last: &HistSnapshot) -> HistSnapshot {
    let mut out = HistSnapshot::default();
    for (o, (c, l)) in out.counts.iter_mut().zip(current.counts.iter().zip(last.counts.iter())) {
        *o = c.saturating_sub(*l);
    }
    out.count = current.count.saturating_sub(last.count);
    out.sum_s = (current.sum_s - last.sum_s).max(0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Histogram;

    fn targets(train: f64, eval: f64, interactive: f64) -> [Duration; CLASS_COUNT] {
        [train, eval, interactive].map(Duration::from_secs_f64)
    }

    #[test]
    fn burn_goes_positive_only_for_the_violated_class() {
        let engine = SloEngine::new(SloConfig {
            targets: targets(10.0, 0.0, 0.010),
            objective: 0.9,
        });
        let hists: [Histogram; CLASS_COUNT] = Default::default();
        // train waits comfortably under its 10s target; interactive
        // blows through its 10ms target on half its requests
        for _ in 0..20 {
            hists[RequestClass::TrainRollout.index()].observe(0.005);
        }
        for _ in 0..10 {
            hists[RequestClass::Interactive.index()].observe(0.001);
            hists[RequestClass::Interactive.index()].observe(0.200);
        }
        let snaps = std::array::from_fn(|i| hists[i].snapshot());
        let burn = engine.assess(&snaps);
        assert_eq!(burn[RequestClass::TrainRollout.index()], 0.0, "{burn:?}");
        assert_eq!(burn[RequestClass::Eval.index()], 0.0, "untracked class: {burn:?}");
        // 50% violations against a 10% budget = burn 5
        let i = RequestClass::Interactive.index();
        assert!((burn[i] - 5.0).abs() < 1e-9, "{burn:?}");
        assert_eq!(engine.burns(), burn);
    }

    #[test]
    fn assessment_is_rolling_not_cumulative() {
        let engine = SloEngine::new(SloConfig {
            targets: targets(0.010, 0.0, 0.0),
            objective: 0.5,
        });
        let hist = Histogram::new();
        let snap_of = |h: &Histogram| {
            let mut s: [HistSnapshot; CLASS_COUNT] = Default::default();
            s[0] = h.snapshot();
            s
        };
        // window 1: all slow -> burn 2 (100% violations / 50% budget)
        for _ in 0..10 {
            hist.observe(1.0);
        }
        let b1 = engine.assess(&snap_of(&hist));
        assert!((b1[0] - 2.0).abs() < 1e-9, "{b1:?}");
        // window 2: all fast -> burn drops to 0 even though the
        // cumulative histogram still holds the slow observations
        for _ in 0..10 {
            hist.observe(0.0001);
        }
        let b2 = engine.assess(&snap_of(&hist));
        assert_eq!(b2[0], 0.0, "{b2:?}");
        // window 3: nothing new -> the last burn holds
        for _ in 0..3 {
            assert_eq!(engine.assess(&snap_of(&hist))[0], 0.0);
        }
        for _ in 0..5 {
            hist.observe(1.0);
        }
        let b4 = engine.assess(&snap_of(&hist));
        assert!((b4[0] - 2.0).abs() < 1e-9, "{b4:?}");
        let held = engine.assess(&snap_of(&hist));
        assert!((held[0] - 2.0).abs() < 1e-9, "empty window holds: {held:?}");
    }

    #[test]
    fn config_validates_objective_and_reports_targets() {
        assert!(SloConfig::default().validate().is_ok());
        assert!(!SloConfig::default().any_target());
        let cfg = SloConfig { targets: targets(0.0, 1.0, 0.0), objective: 0.99 };
        assert!(cfg.any_target());
        assert!(cfg.validate().is_ok());
        for bad in [1.0, 1.5, -0.1, f64::NAN] {
            let cfg = SloConfig { objective: bad, ..Default::default() };
            assert!(cfg.validate().is_err(), "objective {bad} must be rejected");
        }
    }
}
