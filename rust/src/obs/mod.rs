//! Observability plane (DESIGN.md §8): end-to-end tracing and metrics
//! for the layered serving stack.
//!
//! Three read paths over one write path:
//!
//! * [`span`] — the lock-free [`SpanRecorder`]: per-episode trace IDs
//!   threaded from `WorkflowCtx::chat_turn` through `SamplingArgs` →
//!   service jobs → replica serve/resume → engine prefill/decode, so a
//!   run can answer "where did this episode's latency go?".
//! * [`hist`] — fixed-bucket latency [`Histogram`]s (p50/p95/p99,
//!   mergeable) replacing mean-only accounting for queue wait, rollout
//!   latency, sample wait and per-turn prefill.
//! * [`hub`] — the [`TelemetryHub`]: live gauges sampled on a cadence
//!   and readable by `SyncPolicy` / the scheduler (the adaptive-control
//!   prerequisite from ROADMAP item 2).
//! * [`export`] — Chrome trace-event JSON (`trace.json` for
//!   chrome://tracing / Perfetto) and the `trinity trace` summary.
//!
//! The whole plane is config-gated behind `[observability]`
//! ([`ObsConfig`]); when disabled no recorder exists, spans cost one
//! `Option` check, and existing runs behave byte-identically.

pub mod export;
pub mod hist;
pub mod hub;
pub mod span;

pub use export::{chrome_trace, load_trace, summarize_trace, write_trace, DEVICE_LANE};
pub use hist::{HistSnapshot, Histogram, BUCKETS};
pub use hub::{Gauges, TelemetryHub};
pub use span::{Span, SpanKind, SpanRecorder, NO_REPLICA};

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

/// Typed `[observability]` knobs (`ObservabilitySection` in the run
/// config converts into this).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Master switch: off = no recorder, no hub, zero overhead.
    pub enabled: bool,
    /// Span ring capacity (rounded up to a power of two).
    pub ring_capacity: usize,
    /// Telemetry-hub sampling cadence.
    pub sample_every: Duration,
    /// Where to write `trace.json`; defaults to the monitor dir.
    pub trace_path: Option<PathBuf>,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            ring_capacity: 1 << 16,
            sample_every: Duration::from_millis(250),
            trace_path: None,
        }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.ring_capacity == 0 {
            bail!("observability.ring_capacity must be >= 1");
        }
        if self.sample_every.is_zero() {
            bail!("observability.sample_every_s must be > 0");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_off_and_validate() {
        let d = ObsConfig::default();
        assert!(!d.enabled);
        assert!(d.validate().is_ok());
        let mut on = ObsConfig { enabled: true, ..Default::default() };
        assert!(on.validate().is_ok());
        on.ring_capacity = 0;
        assert!(on.validate().is_err());
        on.ring_capacity = 1024;
        on.sample_every = Duration::ZERO;
        assert!(on.validate().is_err());
    }
}
