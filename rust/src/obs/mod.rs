//! Observability plane (DESIGN.md §8, §12): end-to-end tracing,
//! metrics, and diagnostics for the layered serving stack.
//!
//! Read paths over one write path:
//!
//! * [`span`] — the lock-free [`SpanRecorder`]: per-episode trace IDs
//!   threaded from `WorkflowCtx::chat_turn` through `SamplingArgs` →
//!   service jobs → replica serve/resume → engine prefill/decode, so a
//!   run can answer "where did this episode's latency go?".
//! * [`hist`] — fixed-bucket latency [`Histogram`]s (p50/p95/p99,
//!   mergeable) replacing mean-only accounting for queue wait, rollout
//!   latency, sample wait and per-turn prefill.
//! * [`hub`] — the [`TelemetryHub`]: live gauges sampled on a cadence
//!   and readable by `SyncPolicy` / the scheduler, plus a bounded
//!   gauge-history ring for trend windows.
//! * [`export`] — Chrome trace-event JSON (`trace.json` for
//!   chrome://tracing / Perfetto), the `trinity trace` summary, and the
//!   inverse mapping trace-file → spans used by `trinity doctor`.
//! * [`critical`] — critical-path attribution: partition each episode's
//!   wall time into queue/prefill/resume/decode/sync/retry/migrate.
//! * [`slo`] — per-class latency targets with rolling error-budget burn
//!   rates, published as gauges.
//! * [`flight`] — the flight recorder: anomaly-triggered self-contained
//!   diagnostic dumps (span tail + gauge history + decision ring +
//!   queue state), rate-limited and bounded.
//!
//! The whole plane is config-gated behind `[observability]`
//! ([`ObsConfig`]); when disabled no recorder exists, spans cost one
//! `Option` check, and existing runs behave byte-identically.

pub mod critical;
pub mod export;
pub mod flight;
pub mod hist;
pub mod hub;
pub mod slo;
pub mod span;

pub use critical::{attribute, class_summary, top_k, EpisodeBreakdown, SEGMENT_NAMES};
pub use export::{
    chrome_trace, load_trace, spans_from_trace, summarize_trace, write_trace, DEVICE_LANE,
};
pub use flight::{Anomaly, FlightConfig, FlightRecorder, FlightSource};
pub use hist::{HistSnapshot, Histogram, BUCKETS};
pub use hub::{Gauges, TelemetryHub, DEFAULT_GAUGE_HISTORY};
pub use slo::{SloConfig, SloEngine};
pub use span::{MigrateDetail, Span, SpanKind, SpanRecorder, NO_REPLICA};

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Result};

/// Typed `[observability]` knobs (`ObservabilitySection` in the run
/// config converts into this).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Master switch: off = no recorder, no hub, zero overhead.
    pub enabled: bool,
    /// Span ring capacity (rounded up to a power of two).
    pub ring_capacity: usize,
    /// Telemetry-hub sampling cadence.
    pub sample_every: Duration,
    /// Where to write `trace.json`; defaults to the monitor dir.
    pub trace_path: Option<PathBuf>,
    /// Gauge samples retained for trend windows (0 = no history).
    pub gauge_history: usize,
    /// Flight-recorder knobs (`dir` is filled from the monitor dir at
    /// session build; `max_dumps = 0` disables the recorder entirely).
    pub flight: FlightConfig,
    /// Per-class SLO targets + objective (all-zero targets = no engine).
    pub slo: SloConfig,
    /// Slowest episodes reported with critical-path breakdowns.
    pub critical_top_k: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            ring_capacity: 1 << 16,
            sample_every: Duration::from_millis(250),
            trace_path: None,
            gauge_history: DEFAULT_GAUGE_HISTORY,
            flight: FlightConfig::default(),
            slo: SloConfig::default(),
            critical_top_k: 5,
        }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.ring_capacity == 0 {
            bail!("observability.ring_capacity must be >= 1");
        }
        if self.sample_every.is_zero() {
            bail!("observability.sample_every_s must be > 0");
        }
        self.flight.validate()?;
        self.slo.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_off_and_validate() {
        let d = ObsConfig::default();
        assert!(!d.enabled);
        assert!(d.validate().is_ok());
        let mut on = ObsConfig { enabled: true, ..Default::default() };
        assert!(on.validate().is_ok());
        on.ring_capacity = 0;
        assert!(on.validate().is_err());
        on.ring_capacity = 1024;
        on.sample_every = Duration::ZERO;
        assert!(on.validate().is_err());
        on.sample_every = Duration::from_millis(10);
        on.slo.objective = 1.5;
        assert!(on.validate().is_err(), "bad slo objective rejected when enabled");
        on.slo.objective = 0.99;
        on.flight.burn_threshold = f64::NAN;
        assert!(on.validate().is_err(), "bad burn threshold rejected when enabled");
    }
}
