//! Lock-free span recorder: the tracing substrate (DESIGN.md §8).
//!
//! A [`SpanRecorder`] is a bounded power-of-two ring of atomic slots.
//! Writers claim a ticket with one `fetch_add` and publish the span's
//! fields with relaxed stores followed by a release store of the
//! sequence word — no locks, no allocation, no syscalls on the record
//! path, so it is safe to call from inside the service workers and the
//! engine hot loop.  The ring overwrites oldest-first under pressure
//! (tracing is telemetry, not an audit log); [`SpanRecorder::drain`] at
//! quiescence returns the surviving spans sorted by start time.
//!
//! Every span carries the episode **trace id** threaded from
//! `WorkflowCtx::chat_turn` through `SamplingArgs` into service jobs, so
//! an exported trace reconstructs each episode end-to-end: queue wait →
//! cold prefill or cache resume → decode, plus retries, reroutes and
//! weight-sync stalls.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Lane marker for spans not tied to a replica (coordinator, device).
pub const NO_REPLICA: u32 = u32::MAX;

/// What a span measures.  The discriminants are stable: they are packed
/// into the ring's atomic words and decoded on drain.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum SpanKind {
    /// Request sat in the service queue (enqueue → claim).
    QueueWait = 1,
    /// Cold prefill of a prompt (no reusable prefix).
    Prefill = 2,
    /// Parked-session resume: only the prompt delta was prefilled
    /// (`detail` = prefix tokens reused).
    Resume = 3,
    /// Token generation for one request (`detail` = tokens generated).
    Decode = 4,
    /// A failed attempt re-queued on the same worker pass
    /// (`detail` = attempt number).
    Retry = 5,
    /// A job pushed to a peer replica's queue (`detail` = target replica).
    Reroute = 6,
    /// Trainer-side weight publish (the stall explorers sync against).
    SyncStall = 7,
    /// Device-lane prefill execution inside `ModelEngine`.
    DevicePrefill = 8,
    /// Device-lane decode step inside `ModelEngine`.
    DeviceDecode = 9,
    /// Device-lane train step inside `ModelEngine`.
    DeviceTrain = 10,
    /// A control-plane controller changed its output (`detail` packs
    /// controller id and new value; see `control::Decision::detail`).
    ControlDecision = 11,
    /// A parked session moved to a healthy replica (QoS live migration;
    /// `detail` packs destination replica and prefill tokens saved).
    Migrate = 12,
    /// Queued-to-claimed wait of a non-default-class job, mirrored from
    /// its QueueWait span so per-class waits are separable in the trace
    /// (`detail` = `RequestClass::index()`).
    ClassWait = 13,
}

impl SpanKind {
    /// Every kind, in discriminant order — sized by the same table
    /// [`from_u8`](Self::from_u8) decodes, so round-trip tests can
    /// enumerate the full set without hand-maintaining a second list.
    pub const ALL: [SpanKind; 13] = [
        SpanKind::QueueWait,
        SpanKind::Prefill,
        SpanKind::Resume,
        SpanKind::Decode,
        SpanKind::Retry,
        SpanKind::Reroute,
        SpanKind::SyncStall,
        SpanKind::DevicePrefill,
        SpanKind::DeviceDecode,
        SpanKind::DeviceTrain,
        SpanKind::ControlDecision,
        SpanKind::Migrate,
        SpanKind::ClassWait,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Prefill => "prefill",
            SpanKind::Resume => "resume",
            SpanKind::Decode => "decode",
            SpanKind::Retry => "retry",
            SpanKind::Reroute => "reroute",
            SpanKind::SyncStall => "weight_sync",
            SpanKind::DevicePrefill => "device_prefill",
            SpanKind::DeviceDecode => "device_decode",
            SpanKind::DeviceTrain => "device_train",
            SpanKind::ControlDecision => "control_decision",
            SpanKind::Migrate => "migrate",
            SpanKind::ClassWait => "class_wait",
        }
    }

    /// Decode a packed discriminant (the inverse of `kind as u8`).
    /// Public so trace files round-trip: `export::spans_from_trace`
    /// rebuilds `Span`s from Chrome trace events by name and packed id.
    pub fn from_u8(v: u8) -> Option<SpanKind> {
        Some(match v {
            1 => SpanKind::QueueWait,
            2 => SpanKind::Prefill,
            3 => SpanKind::Resume,
            4 => SpanKind::Decode,
            5 => SpanKind::Retry,
            6 => SpanKind::Reroute,
            7 => SpanKind::SyncStall,
            8 => SpanKind::DevicePrefill,
            9 => SpanKind::DeviceDecode,
            10 => SpanKind::DeviceTrain,
            11 => SpanKind::ControlDecision,
            12 => SpanKind::Migrate,
            13 => SpanKind::ClassWait,
            _ => return None,
        })
    }

    /// Inverse of [`as_str`](Self::as_str): parse a trace-event name.
    pub fn parse(name: &str) -> Option<SpanKind> {
        SpanKind::ALL.iter().copied().find(|k| k.as_str() == name)
    }
}

/// Typed view of the packed [`SpanKind::Migrate`] span detail.  The ring
/// stores one `u64` per span, so a migration packs its destination
/// replica and the prefill tokens the move saved into that word; this
/// helper is the single owner of the layout — the service packs with it
/// and the trace export / doctor unpack with it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrateDetail {
    /// Replica the parked session moved to.
    pub dest_replica: u32,
    /// Prefill tokens the migration saved vs a cold re-serve.
    pub saved_tokens: u32,
}

impl MigrateDetail {
    /// Pack into the span's `detail` word (`dest << 32 | saved`).
    pub fn pack(self) -> u64 {
        ((self.dest_replica as u64) << 32) | self.saved_tokens as u64
    }

    /// Unpack a `Migrate` span's `detail` word.
    pub fn unpack(detail: u64) -> MigrateDetail {
        MigrateDetail { dest_replica: (detail >> 32) as u32, saved_tokens: detail as u32 }
    }
}

/// One recorded interval.  `trace` is the episode id (0 = untraced
/// plumbing such as device-lane spans); times are microseconds relative
/// to the recorder's origin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub trace: u64,
    pub kind: SpanKind,
    /// Replica lane, or [`NO_REPLICA`] for coordinator/device spans.
    pub replica: u32,
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific payload (tokens reused/generated, attempt, target).
    pub detail: u64,
}

/// One ring slot: `seq` (0 = empty, else ticket+1) plus the span words.
/// The writer stores the payload relaxed and publishes with a release
/// store of `seq`; a quiescent drain reads everything back consistently.
struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    kind_replica: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    detail: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace: AtomicU64::new(0),
            kind_replica: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
            detail: AtomicU64::new(0),
        }
    }
}

pub struct SpanRecorder {
    origin: Instant,
    mask: usize,
    head: AtomicUsize,
    slots: Box<[Slot]>,
}

impl SpanRecorder {
    /// A recorder holding up to `capacity` spans (rounded up to a power
    /// of two, minimum 64); oldest spans are overwritten under pressure.
    pub fn new(capacity: usize) -> SpanRecorder {
        let cap = capacity.max(64).next_power_of_two();
        SpanRecorder {
            origin: Instant::now(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            slots: (0..cap).map(|_| Slot::empty()).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Microseconds elapsed since the recorder's origin.
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// `t` as microseconds relative to the origin (0 if `t` predates it).
    pub fn rel_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.origin).as_micros() as u64
    }

    /// Record one span (lock-free; overwrites the oldest under pressure).
    pub fn record(&self, span: Span) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[ticket & self.mask];
        slot.trace.store(span.trace, Ordering::Relaxed);
        slot.kind_replica
            .store(((span.kind as u64) << 32) | span.replica as u64, Ordering::Relaxed);
        slot.start_us.store(span.start_us, Ordering::Relaxed);
        slot.dur_us.store(span.dur_us, Ordering::Relaxed);
        slot.detail.store(span.detail, Ordering::Relaxed);
        slot.seq.store(ticket as u64 + 1, Ordering::Release);
    }

    /// Record a closed interval `[start_us, now]`.
    pub fn close(&self, trace: u64, kind: SpanKind, replica: u32, start_us: u64, detail: u64) {
        let dur_us = self.now_us().saturating_sub(start_us);
        self.record(Span { trace, kind, replica, start_us, dur_us, detail });
    }

    /// Record a zero-duration marker at the current time.
    pub fn mark(&self, trace: u64, kind: SpanKind, replica: u32, detail: u64) {
        self.record(Span { trace, kind, replica, start_us: self.now_us(), dur_us: 0, detail });
    }

    /// Spans recorded over the recorder's lifetime (including any later
    /// overwritten).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed) as u64
    }

    /// Spans lost to ring overwrites so far.
    pub fn overwritten(&self) -> u64 {
        (self.head.load(Ordering::Relaxed).saturating_sub(self.capacity())) as u64
    }

    /// Snapshot the surviving spans, sorted by start time.  Meant for
    /// quiescent points (run end); a concurrent writer can tear an
    /// in-flight slot, which at worst yields one garbled span, never UB.
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.capacity().min(self.recorded() as usize));
        for slot in self.slots.iter() {
            if slot.seq.load(Ordering::Acquire) == 0 {
                continue;
            }
            let kr = slot.kind_replica.load(Ordering::Relaxed);
            let Some(kind) = SpanKind::from_u8((kr >> 32) as u8) else { continue };
            out.push(Span {
                trace: slot.trace.load(Ordering::Relaxed),
                kind,
                replica: kr as u32,
                start_us: slot.start_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
                detail: slot.detail.load(Ordering::Relaxed),
            });
        }
        out.sort_by_key(|s| (s.start_us, s.trace));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn span(trace: u64, start_us: u64) -> Span {
        Span { trace, kind: SpanKind::Decode, replica: 0, start_us, dur_us: 5, detail: 2 }
    }

    #[test]
    fn record_and_drain_roundtrip_sorted() {
        let r = SpanRecorder::new(64);
        r.record(span(2, 30));
        r.record(span(1, 10));
        r.mark(3, SpanKind::Prefill, 1, 7);
        let spans = r.drain();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].trace, 1);
        assert_eq!(spans[1].trace, 2);
        assert!(spans.windows(2).all(|w| w[0].start_us <= w[1].start_us));
        let mark = spans.iter().find(|s| s.kind == SpanKind::Prefill).unwrap();
        assert_eq!((mark.dur_us, mark.detail, mark.replica), (0, 7, 1));
        assert_eq!(r.recorded(), 3);
        assert_eq!(r.overwritten(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_under_pressure() {
        let r = SpanRecorder::new(64); // min capacity
        for i in 0..100u64 {
            r.record(span(i, i));
        }
        let spans = r.drain();
        assert_eq!(spans.len(), 64);
        assert_eq!(r.recorded(), 100);
        assert_eq!(r.overwritten(), 36);
        // the survivors are the newest 64
        assert!(spans.iter().all(|s| s.trace >= 36));
    }

    #[test]
    fn close_measures_elapsed() {
        let r = SpanRecorder::new(64);
        let t0 = r.now_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        r.close(9, SpanKind::QueueWait, NO_REPLICA, t0, 0);
        let s = r.drain().remove(0);
        assert!(s.dur_us >= 1_000, "expected >= 1ms, got {}us", s.dur_us);
        assert_eq!(s.replica, NO_REPLICA);
    }

    #[test]
    fn concurrent_recording_loses_nothing_within_capacity() {
        let r = Arc::new(SpanRecorder::new(4096));
        let mut handles = vec![];
        for t in 0..4u64 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..512u64 {
                    r.record(span(t * 1000 + i, i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.recorded(), 2048);
        assert_eq!(r.drain().len(), 2048);
        assert_eq!(r.overwritten(), 0);
    }

    #[test]
    fn span_kind_from_u8_roundtrips_every_variant() {
        for kind in SpanKind::ALL {
            assert_eq!(SpanKind::from_u8(kind as u8), Some(kind), "{kind:?}");
            assert_eq!(SpanKind::parse(kind.as_str()), Some(kind), "{kind:?}");
        }
        // the discriminant table is dense over 1..=ALL.len() and closed:
        // anything outside decodes to None (guards hand-maintained rows
        // as kinds are added)
        assert_eq!(SpanKind::ALL.len(), 13);
        for v in 0..=u8::MAX {
            let decoded = SpanKind::from_u8(v);
            if (1..=SpanKind::ALL.len() as u8).contains(&v) {
                assert_eq!(decoded.map(|k| k as u8), Some(v));
            } else {
                assert_eq!(decoded, None, "stray discriminant {v}");
            }
        }
        assert_eq!(SpanKind::parse("no_such_kind"), None);
    }

    #[test]
    fn migrate_detail_packs_and_unpacks() {
        let d = MigrateDetail { dest_replica: 3, saved_tokens: 417 };
        assert_eq!(d.pack(), (3u64 << 32) | 417);
        assert_eq!(MigrateDetail::unpack(d.pack()), d);
        // extremes survive the round-trip without cross-contamination
        let max = MigrateDetail { dest_replica: u32::MAX, saved_tokens: u32::MAX };
        assert_eq!(MigrateDetail::unpack(max.pack()), max);
        assert_eq!(MigrateDetail::unpack(0), MigrateDetail { dest_replica: 0, saved_tokens: 0 });
    }

    #[test]
    fn rel_us_saturates_before_origin() {
        let earlier = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(1));
        let r = SpanRecorder::new(64);
        assert_eq!(r.rel_us(earlier), 0);
        assert!(r.rel_us(Instant::now()) <= r.now_us());
    }
}
