//! The telemetry hub (DESIGN.md §8): live service/cache/buffer gauges
//! made *readable* by `SyncPolicy` and the scheduler.
//!
//! Before this, serving telemetry was write-only — counters snapshotted
//! at publish boundaries, invisible to admission decisions.  The
//! [`TelemetryHub`] closes the loop: the scheduler publishes a
//! [`Gauges`] sample on a cadence (see [`TelemetryHub::due`]), and any
//! policy holding the hub reads the latest sample lock-free from its
//! `admit` / `publish_after` hooks.  Gauges are stored as f64 bit
//! patterns in atomics, so readers never block a publisher.
//!
//! The hub also keeps a bounded **gauge history**: each published sample
//! is appended to a ring of the last N samples, so trend-reading
//! consumers (the flight recorder, predictive `[control]` policies) can
//! ask "what did the last few seconds look like" instead of only "what
//! is true right now".  History uses a mutex — appends happen only on
//! the publish cadence, never on the serving path.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One gauge sample: the live control-plane view a policy can act on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Gauges {
    /// Monotonic publish tick (1 for the first sample).  Controllers
    /// compare ticks to act at most once per fresh sample.
    pub tick: f64,
    /// Seconds since the hub was created when this sample was taken.
    pub at_s: f64,
    /// Requests waiting in service queues.
    pub queued: f64,
    /// Requests being served right now.
    pub inflight: f64,
    /// Rows per session (continuous-batching packing efficiency).
    pub occupancy: f64,
    /// Quarantined replicas.
    pub quarantined: f64,
    /// Queue-wait p95, seconds (tail pressure, not the mean).
    pub queue_wait_p95_s: f64,
    /// Prefix-cache hit rate in `[0, 1]` (0 when the cache is off).
    pub cache_hit_rate: f64,
    /// Parked KV sessions across replicas.
    pub parked: f64,
    /// Ready experiences sitting in the buffer.
    pub buffer_depth: f64,
    /// Minimum weight version across serving replicas.
    pub weight_version: f64,
    /// Trainer sample-wait p95, seconds (starvation signal).
    pub sample_wait_p95_s: f64,
    /// End-to-end rollout latency p95, seconds.
    pub rollout_p95_s: f64,
    /// Queued eval-class requests (QoS plane; 0 when qos is off).
    pub eval_queued: f64,
    /// Queued interactive-class requests (QoS plane; 0 when qos is off).
    pub interactive_queued: f64,
    /// Interactive-class queue-wait p95, seconds (the latency band the
    /// fair scheduler defends).
    pub interactive_wait_p95_s: f64,
    /// Sessions live-migrated off overloaded/quarantined replicas.
    pub migrations: f64,
    /// Train-class SLO error-budget burn rate (0 = within budget; 1 =
    /// burning exactly the allowed violation budget; >1 = over-burning).
    pub slo_burn_train: f64,
    /// Eval-class SLO burn rate (see [`Gauges::slo_burn_train`]).
    pub slo_burn_eval: f64,
    /// Interactive-class SLO burn rate (see [`Gauges::slo_burn_train`]).
    pub slo_burn_interactive: f64,
}

macro_rules! gauge_fields {
    ($($field:ident),* $(,)?) => {
        /// Lock-free gauge store: one atomic f64 cell per field.
        #[derive(Debug)]
        struct Cells {
            $($field: AtomicU64,)*
        }

        impl Cells {
            fn new() -> Cells {
                Cells { $($field: AtomicU64::new(0),)* }
            }
            fn store(&self, g: &Gauges) {
                $(self.$field.store(g.$field.to_bits(), Ordering::Relaxed);)*
            }
            fn load(&self) -> Gauges {
                Gauges { $($field: f64::from_bits(self.$field.load(Ordering::Relaxed)),)* }
            }
        }

        impl Gauges {
            /// Every gauge as a `(name, value)` pair, in field order —
            /// the serialization view flight dumps and the monitor use.
            /// Generated alongside the atomic cells so a new gauge field
            /// can never be silently missing from either.
            pub fn fields(&self) -> Vec<(&'static str, f64)> {
                vec![$((stringify!($field), self.$field),)*]
            }
        }
    };
}

gauge_fields!(
    tick,
    at_s,
    queued,
    inflight,
    occupancy,
    quarantined,
    queue_wait_p95_s,
    cache_hit_rate,
    parked,
    buffer_depth,
    weight_version,
    sample_wait_p95_s,
    rollout_p95_s,
    eval_queued,
    interactive_queued,
    interactive_wait_p95_s,
    migrations,
    slo_burn_train,
    slo_burn_eval,
    slo_burn_interactive,
);

/// Default number of gauge samples the history ring retains (256
/// samples at the default 250ms cadence ≈ the last minute of the run).
pub const DEFAULT_GAUGE_HISTORY: usize = 256;

pub struct TelemetryHub {
    origin: Instant,
    cadence_us: u64,
    /// Origin-relative µs of the last `due` grant; `u64::MAX` = never.
    last_sample_us: AtomicU64,
    samples: AtomicU64,
    cells: Cells,
    /// Ring of the last `history_cap` published samples (0 = disabled).
    history_cap: usize,
    history: Mutex<VecDeque<Gauges>>,
}

impl TelemetryHub {
    /// A hub whose [`due`](Self::due) gate opens every `sample_every`,
    /// retaining [`DEFAULT_GAUGE_HISTORY`] samples of history.
    pub fn new(sample_every: Duration) -> TelemetryHub {
        TelemetryHub::with_history(sample_every, DEFAULT_GAUGE_HISTORY)
    }

    /// A hub retaining up to `history` published samples (0 disables the
    /// history ring; the live cells always work).
    pub fn with_history(sample_every: Duration, history: usize) -> TelemetryHub {
        TelemetryHub {
            origin: Instant::now(),
            cadence_us: sample_every.as_micros().max(1) as u64,
            last_sample_us: AtomicU64::new(u64::MAX),
            samples: AtomicU64::new(0),
            cells: Cells::new(),
            history_cap: history,
            history: Mutex::new(VecDeque::with_capacity(history.min(4096))),
        }
    }

    /// Publish a gauge sample (any thread; readers never block).
    /// `at_s` and the monotonic `tick` are stamped by the hub.
    pub fn publish(&self, mut g: Gauges) {
        g.at_s = self.origin.elapsed().as_secs_f64();
        g.tick = (self.samples.fetch_add(1, Ordering::Relaxed) + 1) as f64;
        self.cells.store(&g);
        if self.history_cap > 0 {
            let mut h = self.history.lock().unwrap();
            if h.len() == self.history_cap {
                h.pop_front();
            }
            h.push_back(g);
        }
    }

    /// The retained history, oldest first (empty when history is off).
    pub fn history(&self) -> Vec<Gauges> {
        self.history.lock().unwrap().iter().copied().collect()
    }

    /// History samples taken within `window_s` seconds of the newest
    /// retained sample, oldest first.  `f64::INFINITY` returns all.
    pub fn trend(&self, window_s: f64) -> Vec<Gauges> {
        let h = self.history.lock().unwrap();
        let Some(latest) = h.back().map(|g| g.at_s) else { return Vec::new() };
        h.iter().filter(|g| latest - g.at_s <= window_s).copied().collect()
    }

    /// The latest published sample (all zeros before the first publish).
    pub fn gauges(&self) -> Gauges {
        self.cells.load()
    }

    /// Samples published so far.
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    /// Age of the latest sample in seconds; `f64::INFINITY` before the
    /// first publish.  Controllers treat an old sample as *stale* and
    /// hold their last output instead of acting on dead data.
    pub fn age_s(&self) -> f64 {
        let g = self.cells.load();
        if g.tick == 0.0 {
            return f64::INFINITY;
        }
        (self.origin.elapsed().as_secs_f64() - g.at_s).max(0.0)
    }

    /// Cadence gate: returns true at most once per `sample_every`,
    /// racing callers resolved by CAS — exactly one wins each window.
    /// The first call always passes.
    pub fn due(&self, now: Instant) -> bool {
        let rel = now.saturating_duration_since(self.origin).as_micros() as u64;
        loop {
            let last = self.last_sample_us.load(Ordering::Relaxed);
            if last != u64::MAX && rel < last.saturating_add(self.cadence_us) {
                return false;
            }
            if self
                .last_sample_us
                .compare_exchange(last, rel, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_then_read_roundtrips() {
        let hub = TelemetryHub::new(Duration::from_millis(100));
        assert_eq!(hub.gauges(), Gauges::default());
        assert_eq!(hub.samples(), 0);
        hub.publish(Gauges {
            queued: 7.0,
            inflight: 3.0,
            cache_hit_rate: 0.5,
            queue_wait_p95_s: 0.02,
            ..Default::default()
        });
        let g = hub.gauges();
        assert_eq!(g.queued, 7.0);
        assert_eq!(g.inflight, 3.0);
        assert_eq!(g.cache_hit_rate, 0.5);
        assert!((g.queue_wait_p95_s - 0.02).abs() < 1e-12);
        assert!(g.at_s >= 0.0);
        assert_eq!(hub.samples(), 1);
        assert_eq!(g.tick, 1.0);
    }

    #[test]
    fn tick_is_monotonic_and_age_tracks_the_latest_sample() {
        let hub = TelemetryHub::new(Duration::from_millis(1));
        assert_eq!(hub.age_s(), f64::INFINITY, "no sample yet");
        hub.publish(Gauges::default());
        hub.publish(Gauges { queued: 1.0, ..Default::default() });
        let g = hub.gauges();
        assert_eq!(g.tick, 2.0);
        assert!(hub.age_s().is_finite());
        assert!(hub.age_s() < 60.0);
    }

    #[test]
    fn history_ring_is_bounded_and_ordered() {
        let hub = TelemetryHub::with_history(Duration::from_millis(1), 4);
        for i in 0..10u64 {
            hub.publish(Gauges { queued: i as f64, ..Default::default() });
        }
        let h = hub.history();
        assert_eq!(h.len(), 4, "ring bounded at capacity");
        let queued: Vec<f64> = h.iter().map(|g| g.queued).collect();
        assert_eq!(queued, vec![6.0, 7.0, 8.0, 9.0], "oldest first, newest kept");
        assert!(h.windows(2).all(|w| w[0].tick < w[1].tick));
        // trend(∞) returns everything retained; trend(0) at least the
        // newest sample (it is always within 0s of itself)
        assert_eq!(hub.trend(f64::INFINITY).len(), 4);
        let newest = hub.trend(0.0);
        assert!(!newest.is_empty());
        assert_eq!(newest.last().unwrap().queued, 9.0);
    }

    #[test]
    fn zero_capacity_disables_history() {
        let hub = TelemetryHub::with_history(Duration::from_millis(1), 0);
        hub.publish(Gauges { queued: 3.0, ..Default::default() });
        assert!(hub.history().is_empty());
        assert!(hub.trend(f64::INFINITY).is_empty());
        assert_eq!(hub.gauges().queued, 3.0, "live cells unaffected");
    }

    #[test]
    fn fields_view_covers_every_gauge() {
        let g = Gauges { queued: 2.0, slo_burn_interactive: 1.5, ..Default::default() };
        let fields = g.fields();
        // one pair per struct field, in declaration order
        assert_eq!(fields[0].0, "tick");
        assert!(fields.iter().any(|&(k, v)| k == "queued" && v == 2.0));
        assert!(fields.iter().any(|&(k, v)| k == "slo_burn_interactive" && v == 1.5));
        let names: std::collections::HashSet<&str> = fields.iter().map(|&(k, _)| k).collect();
        assert_eq!(names.len(), fields.len(), "no duplicate field names");
    }

    #[test]
    fn due_gates_on_cadence() {
        let hub = TelemetryHub::new(Duration::from_secs(3600));
        let now = Instant::now();
        assert!(hub.due(now), "first sample always due");
        assert!(!hub.due(now), "same instant gated");
        assert!(!hub.due(now + Duration::from_secs(1)), "inside the window");
        assert!(hub.due(now + Duration::from_secs(7200)), "past the window");
    }

    #[test]
    fn due_fast_cadence_reopens() {
        let hub = TelemetryHub::new(Duration::from_micros(1));
        let now = Instant::now();
        assert!(hub.due(now));
        assert!(hub.due(now + Duration::from_millis(5)));
    }

    #[test]
    fn concurrent_readers_see_a_consistent_latest_write() {
        let hub = std::sync::Arc::new(TelemetryHub::new(Duration::from_millis(1)));
        let w = {
            let hub = std::sync::Arc::clone(&hub);
            std::thread::spawn(move || {
                for i in 0..2000u64 {
                    hub.publish(Gauges { queued: i as f64, ..Default::default() });
                }
            })
        };
        for _ in 0..2000 {
            let g = hub.gauges();
            assert!(g.queued >= 0.0 && g.queued < 2000.0);
        }
        w.join().unwrap();
        assert_eq!(hub.gauges().queued, 1999.0);
        assert_eq!(hub.samples(), 2000);
    }
}
