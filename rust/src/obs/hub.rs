//! The telemetry hub (DESIGN.md §8): live service/cache/buffer gauges
//! made *readable* by `SyncPolicy` and the scheduler.
//!
//! Before this, serving telemetry was write-only — counters snapshotted
//! at publish boundaries, invisible to admission decisions.  The
//! [`TelemetryHub`] closes the loop: the scheduler publishes a
//! [`Gauges`] sample on a cadence (see [`TelemetryHub::due`]), and any
//! policy holding the hub reads the latest sample lock-free from its
//! `admit` / `publish_after` hooks.  Gauges are stored as f64 bit
//! patterns in atomics, so readers never block a publisher.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// One gauge sample: the live control-plane view a policy can act on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Gauges {
    /// Monotonic publish tick (1 for the first sample).  Controllers
    /// compare ticks to act at most once per fresh sample.
    pub tick: f64,
    /// Seconds since the hub was created when this sample was taken.
    pub at_s: f64,
    /// Requests waiting in service queues.
    pub queued: f64,
    /// Requests being served right now.
    pub inflight: f64,
    /// Rows per session (continuous-batching packing efficiency).
    pub occupancy: f64,
    /// Quarantined replicas.
    pub quarantined: f64,
    /// Queue-wait p95, seconds (tail pressure, not the mean).
    pub queue_wait_p95_s: f64,
    /// Prefix-cache hit rate in `[0, 1]` (0 when the cache is off).
    pub cache_hit_rate: f64,
    /// Parked KV sessions across replicas.
    pub parked: f64,
    /// Ready experiences sitting in the buffer.
    pub buffer_depth: f64,
    /// Minimum weight version across serving replicas.
    pub weight_version: f64,
    /// Trainer sample-wait p95, seconds (starvation signal).
    pub sample_wait_p95_s: f64,
    /// End-to-end rollout latency p95, seconds.
    pub rollout_p95_s: f64,
    /// Queued eval-class requests (QoS plane; 0 when qos is off).
    pub eval_queued: f64,
    /// Queued interactive-class requests (QoS plane; 0 when qos is off).
    pub interactive_queued: f64,
    /// Interactive-class queue-wait p95, seconds (the latency band the
    /// fair scheduler defends).
    pub interactive_wait_p95_s: f64,
    /// Sessions live-migrated off overloaded/quarantined replicas.
    pub migrations: f64,
}

macro_rules! gauge_fields {
    ($($field:ident),* $(,)?) => {
        /// Lock-free gauge store: one atomic f64 cell per field.
        #[derive(Debug)]
        struct Cells {
            $($field: AtomicU64,)*
        }

        impl Cells {
            fn new() -> Cells {
                Cells { $($field: AtomicU64::new(0),)* }
            }
            fn store(&self, g: &Gauges) {
                $(self.$field.store(g.$field.to_bits(), Ordering::Relaxed);)*
            }
            fn load(&self) -> Gauges {
                Gauges { $($field: f64::from_bits(self.$field.load(Ordering::Relaxed)),)* }
            }
        }
    };
}

gauge_fields!(
    tick,
    at_s,
    queued,
    inflight,
    occupancy,
    quarantined,
    queue_wait_p95_s,
    cache_hit_rate,
    parked,
    buffer_depth,
    weight_version,
    sample_wait_p95_s,
    rollout_p95_s,
    eval_queued,
    interactive_queued,
    interactive_wait_p95_s,
    migrations,
);

pub struct TelemetryHub {
    origin: Instant,
    cadence_us: u64,
    /// Origin-relative µs of the last `due` grant; `u64::MAX` = never.
    last_sample_us: AtomicU64,
    samples: AtomicU64,
    cells: Cells,
}

impl TelemetryHub {
    /// A hub whose [`due`](Self::due) gate opens every `sample_every`.
    pub fn new(sample_every: Duration) -> TelemetryHub {
        TelemetryHub {
            origin: Instant::now(),
            cadence_us: sample_every.as_micros().max(1) as u64,
            last_sample_us: AtomicU64::new(u64::MAX),
            samples: AtomicU64::new(0),
            cells: Cells::new(),
        }
    }

    /// Publish a gauge sample (any thread; readers never block).
    /// `at_s` and the monotonic `tick` are stamped by the hub.
    pub fn publish(&self, mut g: Gauges) {
        g.at_s = self.origin.elapsed().as_secs_f64();
        g.tick = (self.samples.fetch_add(1, Ordering::Relaxed) + 1) as f64;
        self.cells.store(&g);
    }

    /// The latest published sample (all zeros before the first publish).
    pub fn gauges(&self) -> Gauges {
        self.cells.load()
    }

    /// Samples published so far.
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    /// Age of the latest sample in seconds; `f64::INFINITY` before the
    /// first publish.  Controllers treat an old sample as *stale* and
    /// hold their last output instead of acting on dead data.
    pub fn age_s(&self) -> f64 {
        let g = self.cells.load();
        if g.tick == 0.0 {
            return f64::INFINITY;
        }
        (self.origin.elapsed().as_secs_f64() - g.at_s).max(0.0)
    }

    /// Cadence gate: returns true at most once per `sample_every`,
    /// racing callers resolved by CAS — exactly one wins each window.
    /// The first call always passes.
    pub fn due(&self, now: Instant) -> bool {
        let rel = now.saturating_duration_since(self.origin).as_micros() as u64;
        loop {
            let last = self.last_sample_us.load(Ordering::Relaxed);
            if last != u64::MAX && rel < last.saturating_add(self.cadence_us) {
                return false;
            }
            if self
                .last_sample_us
                .compare_exchange(last, rel, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_then_read_roundtrips() {
        let hub = TelemetryHub::new(Duration::from_millis(100));
        assert_eq!(hub.gauges(), Gauges::default());
        assert_eq!(hub.samples(), 0);
        hub.publish(Gauges {
            queued: 7.0,
            inflight: 3.0,
            cache_hit_rate: 0.5,
            queue_wait_p95_s: 0.02,
            ..Default::default()
        });
        let g = hub.gauges();
        assert_eq!(g.queued, 7.0);
        assert_eq!(g.inflight, 3.0);
        assert_eq!(g.cache_hit_rate, 0.5);
        assert!((g.queue_wait_p95_s - 0.02).abs() < 1e-12);
        assert!(g.at_s >= 0.0);
        assert_eq!(hub.samples(), 1);
        assert_eq!(g.tick, 1.0);
    }

    #[test]
    fn tick_is_monotonic_and_age_tracks_the_latest_sample() {
        let hub = TelemetryHub::new(Duration::from_millis(1));
        assert_eq!(hub.age_s(), f64::INFINITY, "no sample yet");
        hub.publish(Gauges::default());
        hub.publish(Gauges { queued: 1.0, ..Default::default() });
        let g = hub.gauges();
        assert_eq!(g.tick, 2.0);
        assert!(hub.age_s().is_finite());
        assert!(hub.age_s() < 60.0);
    }

    #[test]
    fn due_gates_on_cadence() {
        let hub = TelemetryHub::new(Duration::from_secs(3600));
        let now = Instant::now();
        assert!(hub.due(now), "first sample always due");
        assert!(!hub.due(now), "same instant gated");
        assert!(!hub.due(now + Duration::from_secs(1)), "inside the window");
        assert!(hub.due(now + Duration::from_secs(7200)), "past the window");
    }

    #[test]
    fn due_fast_cadence_reopens() {
        let hub = TelemetryHub::new(Duration::from_micros(1));
        let now = Instant::now();
        assert!(hub.due(now));
        assert!(hub.due(now + Duration::from_millis(5)));
    }

    #[test]
    fn concurrent_readers_see_a_consistent_latest_write() {
        let hub = std::sync::Arc::new(TelemetryHub::new(Duration::from_millis(1)));
        let w = {
            let hub = std::sync::Arc::clone(&hub);
            std::thread::spawn(move || {
                for i in 0..2000u64 {
                    hub.publish(Gauges { queued: i as f64, ..Default::default() });
                }
            })
        };
        for _ in 0..2000 {
            let g = hub.gauges();
            assert!(g.queued >= 0.0 && g.queued < 2000.0);
        }
        w.join().unwrap();
        assert_eq!(hub.gauges().queued, 1999.0);
        assert_eq!(hub.samples(), 2000);
    }
}
